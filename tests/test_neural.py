"""Engine-native neural FedZO tasks (DESIGN.md §11).

The differential matrix the bridge must satisfy, for every registered
model track (softmax regression, the trainable LeNet-style SmallCNN, the
tiny transformer head):

- host loop ≡ engine, BITWISE, across the aggregation paths — the
  {flat_params, weight_by_size, channel_schedule} flag cube on softmax,
  spot combinations on the conv/transformer tracks (both drivers share one
  round step and one key chain, so equality is exact, not approximate);
- sharded (1-device clients mesh) ≡ unsharded round to ~1 ulp;
- the batched-direction (wide) phase ≡ the loop estimator's trajectory
  under direction_conv="tree";
- the in-scan top-1 accuracy eval lands on the right rounds and the
  softmax track actually trains.

Plus the slow-marked full paper-figure grids (benchmarks/paper_figures.py)
with their qualitative-ordering acceptance.
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sim
from repro.core import fedzo
from repro.fed.server import FedServer
from repro.workloads import neural

BR = 4  # small kernel blocks for CPU interpret mode

TASK_KW = {
    "softmax": dict(n_train=240, n_test=64, n_clients=6, n_features=24,
                    n_classes=4),
    "cnn": dict(n_train=180, n_test=48, n_clients=6, n_classes=4,
                image_shape=(10, 10, 1), width=4),
    "transformer": dict(n_train=180, n_test=48, n_clients=6, n_features=24,
                        n_classes=4, n_patches=4, d_model=16, d_ff=32,
                        n_heads=2),
}


def _task(name):
    return neural.make_task(name, **TASK_KW[name])


def _cfg(task, **kw):
    base = dict(n_participating=3, local_iters=2, b1=6, b2=3, lr=2e-2,
                mu=1e-3, seed=7, weight_by_size=False)
    base.update(kw)
    return neural.default_config(task, **base)


def _flag_kw(flat, weighted, sched):
    kw = {}
    if flat:
        kw.update(flat_params=True, flat_block_rows=BR)
    if weighted:
        kw.update(weight_by_size=True)
    if sched:
        kw.update(aircomp=True, snr_db=10.0, channel_schedule=True)
    return kw


def _assert_trees_bitequal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# host ≡ engine, bitwise, across the aggregation-path flag cube


# softmax sweeps the full {flat_params, weight_by_size, channel_schedule}
# cube; the heavier conv/transformer tracks pin the corners (plain, flat,
# everything-on) so the matrix stays CI-sized
CASES = [("softmax",) + flags
         for flags in itertools.product((False, True), repeat=3)]
CASES += [("cnn", False, False, False), ("cnn", True, True, True),
          ("transformer", False, False, False),
          ("transformer", True, True, True)]


@pytest.mark.parametrize("model,flat,weighted,sched", CASES)
def test_host_bitmatches_engine(model, flat, weighted, sched):
    """3 host-driven rounds == 3 in-scan rounds, bit for bit, for every
    neural track × aggregation path."""
    task = _task(model)
    cfg = _cfg(task, **_flag_kw(flat, weighted, sched))
    p0 = neural.params_init(task, cfg.seed)
    host = FedServer(task.loss, p0, task.clients, cfg, store=task.store)
    for t in range(3):
        host.run_round(t)
    scanned = FedServer(task.loss, p0, task.clients, cfg, store=task.store)
    scanned.run(3)
    _assert_trees_bitequal(host.params, scanned.params)
    for hm, sm in zip(host.history, scanned.history):
        assert hm["mean_local_loss"] == sm["mean_local_loss"], (hm, sm)


# strategy layer (core/strategy.py): every new algorithm must hold host ≡
# engine bitwise across the aggregation paths, exactly like fedzo — same
# round step, same key chain, plus the strategy state threading the carry
STRATEGY_CASES = [("fedprox", {"prox_mu": 0.1}),
                  ("feddyn", {"dyn_alpha": 0.1}),
                  ("scaffold", {})]
STRATEGY_PATHS = [
    ("plain", {}),
    ("flat", dict(flat_params=True, flat_block_rows=BR)),
    ("wide", dict(batch_directions=True, direction_conv="block",
                  prng_impl="unsafe_rbg")),
    ("air_weighted", dict(aircomp=True, snr_db=10.0, channel_schedule=True,
                          weight_by_size=True)),
]


@pytest.mark.parametrize("pname,pkw", STRATEGY_PATHS)
@pytest.mark.parametrize("sname,skw", STRATEGY_CASES)
def test_strategy_host_bitmatches_engine(sname, skw, pname, pkw):
    """3 host-driven rounds == 3 in-scan rounds, bit for bit, for every
    strategy × {pytree, flat, wide, AirComp+scheduled+weighted} path —
    including the per-client strategy state at the end."""
    task = _task("softmax")
    cfg = _cfg(task, strategy=sname, **skw, **pkw)
    p0 = neural.params_init(task, cfg.seed)
    host = FedServer(task.loss, p0, task.clients, cfg, store=task.store)
    for t in range(3):
        host.run_round(t)
    scanned = FedServer(task.loss, p0, task.clients, cfg, store=task.store)
    scanned.run(3)
    _assert_trees_bitequal(host.params, scanned.params)
    _assert_trees_bitequal(host._zstate, scanned._zstate)
    for hm, sm in zip(host.history, scanned.history):
        assert hm["mean_local_loss"] == sm["mean_local_loss"], (hm, sm)


def test_wide_engine_bitmatches_host():
    """The engine's fast execution plan (wide phases, rbg PRNG) also stays
    host ≡ engine on a neural conv task."""
    task = _task("cnn")
    cfg = sim.fast_sim_config(_cfg(task))
    p0 = neural.params_init(task, cfg.seed)
    host = FedServer(task.loss, p0, task.clients, cfg, store=task.store)
    for t in range(2):
        host.run_round(t)
    scanned = FedServer(task.loss, p0, task.clients, cfg, store=task.store)
    scanned.run(2)
    _assert_trees_bitequal(host.params, scanned.params)


# ---------------------------------------------------------------------------
# sharded (1-device mesh) ≡ unsharded round


@pytest.mark.parametrize("model", ["softmax", "cnn", "transformer"])
def test_sharded_round_matches_unsharded(model):
    """The clients-mesh round on a 1-device mesh equals the plain round to
    ~1 ulp for every neural track (psum changes XLA fusion, not math)."""
    task = _task(model)
    cfg = _cfg(task, batch_directions=True, direction_conv="block")
    p0 = neural.params_init(task, cfg.seed)
    mesh = sim.make_clients_mesh()
    rf = sim.make_sharded_round(task.loss, cfg, mesh)
    batches = sim.sample_batches(task.store, jnp.arange(3), jax.random.key(2),
                                 cfg.local_iters, cfg.b1)
    rngs = jax.random.split(jax.random.key(1), 3)
    kc = jax.random.key(3)
    ref = jax.jit(lambda p, b, r, c: fedzo.round_simulated(
        task.loss, p, b, r, cfg, channel_rng=c))(p0, batches, rngs, kc)
    got = jax.jit(lambda p, b, r, c: rf(
        task.loss, p, b, r, cfg, channel_rng=c))(p0, batches, rngs, kc)
    for la, lb in zip(jax.tree.leaves(ref[0]), jax.tree.leaves(got[0])):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-6, rtol=1e-5)


def test_sharded_experiment_inside_engine():
    """neural.run(mesh=...) drives a whole sharded experiment as one scan
    and matches the unsharded engine on a 1-device mesh."""
    task = _task("softmax")
    cfg = _cfg(task, batch_directions=True, direction_conv="block")
    mesh = sim.make_clients_mesh()
    res_s = neural.run(task, cfg, 3, mesh=mesh, donate=False)
    res_u = neural.run(task, cfg, 3, donate=False)
    for la, lb in zip(jax.tree.leaves(res_s.params),
                      jax.tree.leaves(res_u.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# wide (batched-direction) phase ≡ loop estimator on a neural task


def test_wide_phase_matches_loop_on_cnn():
    """direction_conv="tree" makes the wide phase walk the loop estimator's
    exact directions through the conv net — one round agrees to the fp32
    reassociation of the batched forwards."""
    task = _task("cnn")
    cfg_loop = _cfg(task)
    cfg_wide = dataclasses.replace(cfg_loop, batch_directions=True)
    p0 = neural.params_init(task, cfg_loop.seed)
    batches = sim.sample_batches(task.store, jnp.arange(3), jax.random.key(5),
                                 cfg_loop.local_iters, cfg_loop.b1)
    rngs = jax.random.split(jax.random.key(6), 3)
    p_l, m_l = fedzo.round_simulated(task.loss, p0, batches, rngs, cfg_loop)
    p_w, m_w = fedzo.round_simulated(task.loss, p0, batches, rngs, cfg_wide)
    np.testing.assert_allclose(float(m_w["mean_local_loss"]),
                               float(m_l["mean_local_loss"]), rtol=1e-5)
    for la, lb in zip(jax.tree.leaves(p_l), jax.tree.leaves(p_w)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# training + eval behavior


def test_softmax_trains_with_in_scan_accuracy():
    """The bridge actually optimizes: softmax test accuracy improves over
    an 8-round engine run, and evals land on the configured cadence."""
    task = _task("softmax")
    cfg = _cfg(task, lr=5e-2, b2=8, n_participating=4)
    res = neural.run(task, cfg, 8, eval_every=2, eval_rows=64)
    hist = sim.history(res)
    assert [h["round"] for h in hist] == list(range(8))
    evs = [(h["round"], h["test_acc"]) for h in hist if "test_acc" in h]
    assert [r for r, _ in evs] == [0, 2, 4, 6]
    assert all(0.0 <= a <= 1.0 for _, a in evs)
    assert evs[-1][1] > evs[0][1]
    assert hist[-1]["mean_local_loss"] < hist[0]["mean_local_loss"]


def test_make_task_validates_name_and_patching():
    with pytest.raises(ValueError, match="unknown neural task"):
        neural.make_task("mlp")
    with pytest.raises(ValueError, match="patch tokens"):
        neural.make_task("transformer", n_features=30, n_patches=4,
                         n_train=40, n_test=8, n_clients=2)


def test_make_task_rejects_unknown_model_kwargs():
    """A misspelled model kwarg must fail loudly, not silently build (and
    lru-cache) a default-model task."""
    with pytest.raises(ValueError, match="unknown model kwargs"):
        neural.make_task("cnn", widht=4, n_train=40, n_test=8, n_clients=2)
    with pytest.raises(ValueError, match="unknown model kwargs"):
        neural.make_task("softmax", image_shape=(8, 8, 1), n_train=40,
                         n_test=8, n_clients=2)


def test_make_task_accepts_list_image_shape():
    """image_shape is normalized before the cache layer — a list must hit
    the same cache slot as the equivalent tuple, not crash lru_cache."""
    kw = dict(TASK_KW["cnn"])
    as_tuple = neural.make_task("cnn", **kw)
    kw["image_shape"] = list(kw["image_shape"])
    assert neural.make_task("cnn", **kw) is as_tuple


# ---------------------------------------------------------------------------
# full paper-figure grids (slow job)


@pytest.mark.slow
def test_paper_figures_full_grid(tmp_path):
    """The full-scale figure grids reproduce the paper's qualitative
    orderings: larger H and larger M converge faster at equal rounds, lower
    SNR degrades AirComp convergence."""
    from benchmarks.paper_figures import run_figures

    rows = dict((name, val) for name, _, val in
                run_figures("softmax", smoke=False, outdir=str(tmp_path)))
    assert rows["fig1/fedzo_trains"] == 1.0, rows
    assert rows["fig2/larger_H_converges_faster"] == 1.0, rows
    assert rows["fig3/larger_M_converges_faster"] == 1.0, rows
    assert rows["fig4/lower_SNR_degrades_aircomp"] == 1.0, rows
    assert rows["table1/monotone_in_MH"] == 1.0, rows
    csvs = list(tmp_path.glob("*.csv"))
    assert len(csvs) == 5
    for p in csvs:
        assert p.read_text().startswith("scenario,round,metric,value")
