"""Workload layer (repro.workloads, DESIGN.md §10) + the partitioner /
store / history edge-case fixes that make its heterogeneous variants safe.

- regression tests: ``random_partition`` uneven renormalization,
  ``noniid_shards`` remainder preservation, ``build_store`` dtype
  validation, ``history`` keeping ring-evicted eval rounds (each fails on
  the pre-fix code).
- size-weighted aggregation: the weighted ``mask_stats`` contract, the
  exact n_i/n aggregation identity, and engine ≡ host under
  ``cfg.weight_by_size``.
- both gradient-free workloads: engine-vs-host bit-match, eval curves,
  convergence smoke, and the attack SNR-sweep CSV.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sim
from repro.configs.base import FedZOConfig
from repro.core import fedzo
from repro.core.aircomp import aircomp_aggregate, mask_stats, size_weights
from repro.data.synthetic import (dirichlet_partition, make_classification,
                                  noniid_shards, random_partition)
from repro.fed.server import FedServer
from repro.models.simple import softmax_init, softmax_loss
from repro.workloads import attack, hypertune


def _assert_trees_bitequal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _partition_covers(clients, x, y):
    """Union of client rows == the full dataset (as multisets of rows)."""
    assert sum(len(c["y"]) for c in clients) == len(y)
    got = np.sort(np.concatenate([c["x"][:, 0] for c in clients]))
    np.testing.assert_array_equal(got, np.sort(x[:, 0]))


def _tagged(n):
    """Rows identifiable by value so coverage is checkable after shuffles."""
    x = np.arange(n, dtype=np.float32)[:, None].repeat(2, 1)
    y = (np.arange(n) % 3).astype(np.int32)
    return x, y


# ---------------------------------------------------------------------------
# partitioner regressions


def test_random_partition_uneven_counts_exact():
    """Pre-fix, the clamp-then-subtract count assignment could hand the
    last client 0 (or negative) rows — (n=12, 8 clients, seed=135) is such
    a draw. Every client must get ≥ 1 row and the union must be exact."""
    x, y = _tagged(12)
    clients = random_partition(x, y, 8, seed=135, uneven=True)
    sizes = [len(c["y"]) for c in clients]
    assert min(sizes) >= 1, sizes
    _partition_covers(clients, x, y)


def test_random_partition_uneven_invariants_grid():
    for n, nc, seed in [(12, 12, 3), (20, 10, 7), (40, 8, 0), (200, 10, 1)]:
        x, y = _tagged(n)
        clients = random_partition(x, y, nc, seed=seed, uneven=True)
        assert min(len(c["y"]) for c in clients) >= 1
        _partition_covers(clients, x, y)


def test_random_partition_even_keeps_remainder_rows():
    """The even path used to silently drop len(y) % n_clients tail rows."""
    x, y = _tagged(103)
    clients = random_partition(x, y, 10, seed=0, uneven=False)
    _partition_covers(clients, x, y)


def test_random_partition_rejects_more_clients_than_rows():
    x, y = _tagged(4)
    with pytest.raises(ValueError, match="at least one row"):
        random_partition(x, y, 5, uneven=True)


def test_noniid_shards_keeps_remainder_rows():
    """103 rows over 10 shards used to silently drop the 3 tail rows."""
    x, y = _tagged(103)
    clients = noniid_shards(x, y, 5, shards_per_client=2, seed=0)
    _partition_covers(clients, x, y)


def test_noniid_shards_even_split_unchanged():
    """Divisible datasets keep the original equal-shard protocol."""
    x, y = _tagged(120)
    clients = noniid_shards(x, y, 6, shards_per_client=2, seed=0)
    assert [len(c["y"]) for c in clients] == [20] * 6
    _partition_covers(clients, x, y)


def test_dirichlet_partition_covers_and_skews():
    x, y = make_classification(600, 8, 4, seed=0)
    x = np.concatenate([np.arange(600, dtype=np.float32)[:, None], x], 1)
    skew = dirichlet_partition(x, y, 6, alpha=0.1, seed=0)
    iid = dirichlet_partition(x, y, 6, alpha=1000.0, seed=0)
    for clients in (skew, iid):
        assert min(len(c["y"]) for c in clients) >= 1
        _partition_covers(clients, x, y)

    def mean_label_share(clients):
        # mean max-class share per client: 1/n_classes for iid, → 1 skewed
        shares = []
        for c in clients:
            counts = np.bincount(c["y"], minlength=4)
            shares.append(counts.max() / counts.sum())
        return np.mean(shares)

    assert mean_label_share(skew) > mean_label_share(iid) + 0.2


# ---------------------------------------------------------------------------
# store + history regressions


def test_build_store_rejects_mismatched_dtypes():
    with pytest.raises(ValueError, match="dtype"):
        sim.build_store([
            {"x": np.zeros((3, 2), np.float32), "y": np.zeros(3, np.int32)},
            {"x": np.zeros((4, 2), np.float64), "y": np.zeros(4, np.int32)},
        ])


def test_history_keeps_ring_evicted_eval_rounds():
    """rounds=8 with ring_size=3 keeps metric rows 5..7 only, but the
    in-scan evals of rounds 0/2/4 live in their own buffer — history must
    emit them as eval-only rows instead of dropping the curve's head."""
    x, y = make_classification(240, 12, 3, seed=0)
    clients = noniid_shards(x, y, 6)
    store = sim.build_store(clients)
    cfg = FedZOConfig(n_devices=6, n_participating=3, local_iters=2,
                      lr=1e-2, mu=1e-3, b1=8, b2=4, seed=3)
    p0 = softmax_init(None, 12, 3)
    ev = lambda p: {"probe": jnp.mean(p["w"])}  # noqa: E731
    ringed = sim.run_experiment(softmax_loss, p0, store, cfg, 8, eval_fn=ev,
                                eval_every=2, ring_size=3, donate=False)
    full = sim.run_experiment(softmax_loss, p0, store, cfg, 8, eval_fn=ev,
                              eval_every=2, donate=False)
    h_ring = sim.history(ringed)
    h_full = {h["round"]: h for h in sim.history(full)}
    assert [h["round"] for h in h_ring] == [0, 2, 4, 5, 6, 7]
    for h in h_ring:
        if h["round"] < 5:                  # evicted: eval-only rows
            assert set(h) == {"round", "probe", "strategy"}
        else:
            assert "mean_local_loss" in h
        if "probe" in h:
            assert h["probe"] == h_full[h["round"]]["probe"]


# ---------------------------------------------------------------------------
# size-weighted aggregation


def test_mask_stats_weighted_contract():
    mask = jnp.asarray([True, False, True, True])
    w = jnp.asarray([2.0, 1.0, 0.5, 0.5])
    maskf, m_div, m_sched = mask_stats(mask, 4, w)
    np.testing.assert_allclose(np.asarray(maskf), [2.0, 0.0, 0.5, 0.5])
    assert float(m_div) == 3.0
    assert float(m_sched) == 3.0            # UNWEIGHTED scheduled count
    # all-ones weights reproduce the unweighted path bit for bit
    mf_u, md_u, _ = mask_stats(mask, 4)
    mf_w, md_w, _ = mask_stats(mask, 4, jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(mf_u), np.asarray(mf_w))
    assert float(md_u) == float(md_w)


def test_size_weights_mean_one():
    w = size_weights(jnp.asarray([10, 30, 20, 40]))
    np.testing.assert_allclose(np.asarray(w), [0.4, 1.2, 0.8, 1.6])
    assert abs(float(jnp.mean(w)) - 1.0) < 1e-6
    # uniform sizes are EXACTLY all-ones (the bit-for-bit fallback), even
    # where 1/s is inexact in fp32
    for s in (41, 77, 138):
        np.testing.assert_array_equal(
            np.asarray(size_weights(jnp.full((3,), s))), np.ones(3))


def test_weighted_aggregate_excludes_masked_and_weights_rest():
    rng = np.random.default_rng(0)
    base = rng.normal(size=(3, 64)).astype(np.float32)
    deltas = {"w": jnp.asarray(base)}
    mask = jnp.asarray([True, True, False])
    w = jnp.asarray([2.0, 1.0, 3.0])
    agg, stats = aircomp_aggregate(deltas, jax.random.key(0), snr_db=200.0,
                                   h_min=0.8, mask=mask, weights=w)
    expect = (2.0 * base[0] + 1.0 * base[1]) / 3.0
    np.testing.assert_allclose(np.asarray(agg["w"]), expect, atol=1e-4)
    assert float(stats["m_effective"]) == 2.0


def test_round_weighted_aggregation_identity():
    """round_simulated(weights=w) == x + Σ w_i Δ_i / Σ w_i with the Δ_i of
    the exact same local phases (client_delta replays them)."""
    cfg = FedZOConfig(n_devices=2, n_participating=2, local_iters=2,
                      lr=1e-2, mu=1e-3, b1=4, b2=3, seed=0)
    params = {"x": jnp.zeros((20,))}

    def quad(p, batch):
        return 0.5 * jnp.sum((p["x"] - batch["t"]) ** 2)

    batches = {"t": jnp.stack([jnp.ones((2, 20)), -jnp.ones((2, 20))])}
    rngs = jax.random.split(jax.random.key(1), 2)
    w = jnp.asarray([1.5, 0.5])
    newp, _ = fedzo.round_simulated(quad, params, batches, rngs, cfg,
                                    weights=w)
    d0, _ = fedzo.client_delta(quad, params, jax.tree.map(lambda b: b[0],
                                                          batches), rngs[0],
                               cfg)
    d1, _ = fedzo.client_delta(quad, params, jax.tree.map(lambda b: b[1],
                                                          batches), rngs[1],
                               cfg)
    expect = (1.5 * d0["x"] + 0.5 * d1["x"]) / 2.0
    np.testing.assert_allclose(np.asarray(newp["x"]), np.asarray(expect),
                               atol=1e-6)


def _uneven_setup(n=300, n_clients=6, n_features=16, n_classes=4):
    x, y = make_classification(n, n_features, n_classes, seed=0)
    clients = random_partition(x, y, n_clients, seed=2, uneven=True)
    return clients, sim.build_store(clients)


@pytest.mark.parametrize("kw,algo", [
    ({}, "fedzo"),
    ({"aircomp": True, "snr_db": 10.0, "channel_schedule": True}, "fedzo"),
    ({"batch_directions": True, "direction_conv": "block",
      "prng_impl": "unsafe_rbg"}, "fedzo"),
    ({}, "fedavg"),
])
def test_weight_by_size_engine_bitmatches_host(kw, algo):
    """cfg.weight_by_size threads identically through the scan engine and
    the host-driven store rounds on every aggregation path."""
    clients, store = _uneven_setup()
    cfg = FedZOConfig(n_devices=6, n_participating=3, local_iters=2,
                      lr=1e-2, mu=1e-3, b1=8, b2=4, seed=5,
                      weight_by_size=True, **kw)
    p0 = softmax_init(None, 16, 4)
    host = FedServer(softmax_loss, p0, clients, cfg, algo=algo, store=store)
    for t in range(3):
        host.run_round(t)
    scanned = FedServer(softmax_loss, p0, clients, cfg, algo=algo,
                        store=store)
    scanned.run(3)
    _assert_trees_bitequal(host.params, scanned.params)


def test_weight_by_size_host_loop_without_store():
    """The per-round Python driver (clients list, no store) computes the
    same n_i/n weights from the host datasets — weighted runs complete and
    diverge from uniform ones on an uneven split."""
    clients, _ = _uneven_setup()

    def final(wbs):
        cfg = FedZOConfig(n_devices=6, n_participating=3, local_iters=2,
                          lr=1e-2, mu=1e-3, b1=8, b2=4, seed=5,
                          weight_by_size=wbs)
        srv = FedServer(softmax_loss, softmax_init(None, 16, 4), clients,
                        cfg)
        srv.run(2, driver="host")
        return np.asarray(srv.params["w"])

    assert np.abs(final(True) - final(False)).max() > 1e-8


def test_weight_by_size_changes_trajectory_on_uneven_split():
    clients, store = _uneven_setup()
    assert len(set(int(s) for s in store.sizes)) > 1
    p0 = softmax_init(None, 16, 4)

    def final(wbs):
        cfg = FedZOConfig(n_devices=6, n_participating=3, local_iters=2,
                          lr=1e-2, mu=1e-3, b1=8, b2=4, seed=5,
                          weight_by_size=wbs)
        res = sim.run_experiment(softmax_loss, p0, store, cfg, 3,
                                 donate=False)
        return np.asarray(res.params["w"])

    assert np.abs(final(True) - final(False)).max() > 1e-8


# ---------------------------------------------------------------------------
# attack workload

ATTACK_KW = dict(n_train=400, n_attack=96, n_clients=5, train_steps=120)


def test_attack_engine_bitmatches_host_rounds():
    task = attack.make_task(**ATTACK_KW)
    cfg = attack.default_config(task, local_iters=2, b2=4, b1=8,
                                n_participating=3, seed=7)
    loss = attack.attack_loss(task)
    p0 = attack.pert_init()
    host = FedServer(loss, p0, task.clients, cfg, store=task.store)
    for t in range(2):
        host.run_round(t)
    scanned = FedServer(loss, p0, task.clients, cfg, store=task.store)
    scanned.run(2)
    _assert_trees_bitequal(host.params, scanned.params)


def test_attack_workload_descends_with_inscan_eval_curve():
    task = attack.make_task(**ATTACK_KW)
    assert 0.5 < task.clean_accuracy <= 1.0
    cfg = sim.fast_sim_config(
        attack.default_config(task, local_iters=3, b2=6, b1=8))
    res = attack.run(task, cfg, 6, eval_every=2, donate=False)
    hist = sim.history(res)
    assert [h["round"] for h in hist] == [0, 1, 2, 3, 4, 5]
    evs = [h for h in hist if "attack_success" in h]
    assert [h["round"] for h in evs] == [0, 2, 4]
    assert all(0.0 <= h["attack_success"] <= 1.0 for h in evs)
    # the pooled CW objective descends (per-round minibatch loss is noisy)
    assert evs[-1]["eval_cw_loss"] < evs[0]["eval_cw_loss"]


def test_attack_sweep_emits_snr_curve_csv(tmp_path):
    task = attack.make_task(**ATTACK_KW)
    cfg = sim.fast_sim_config(
        attack.default_config(task, local_iters=2, b2=4, b1=8))
    out = tmp_path / "attack_snr.csv"
    recs = attack.run_sweep(task, cfg, snr_dbs=(-5.0, 15.0), seeds=(0, 1),
                            rounds=3, eval_every=2, out_csv=str(out))
    assert len(recs) == 4
    for r in recs:
        assert r["evals"]["attack_success"].shape == (2,)
        assert np.isfinite(r["metrics"]["mean_local_loss"]).all()
    lines = out.read_text().splitlines()
    assert lines[0] == "scenario,round,metric,value"
    assert any("attack_success" in ln for ln in lines[1:])
    # the vmapped snr axis reaches the channel
    lo = [r for r in recs if r["scenario"]["snr_db"] == -5.0]
    hi = [r for r in recs if r["scenario"]["snr_db"] == 15.0]
    assert (np.mean([r["metrics"]["aircomp_noise_std"].mean() for r in lo])
            > np.mean([r["metrics"]["aircomp_noise_std"].mean() for r in hi]))


# ---------------------------------------------------------------------------
# hypertune workload


def test_hypertune_engine_bitmatches_host_rounds():
    task = hypertune.make_task()
    cfg = hypertune.default_config(task, seed=11)
    loss = hypertune.tune_loss(task)
    p0 = hypertune.hp_init()
    host = FedServer(loss, p0, task.clients, cfg, store=task.store)
    for t in range(3):
        host.run_round(t)
    scanned = FedServer(loss, p0, task.clients, cfg, store=task.store)
    scanned.run(3)
    _assert_trees_bitequal(host.params, scanned.params)


def test_hypertune_converges_on_synthetic_task():
    """The tuner must improve the inner-trained validation loss from the
    deliberately mis-tuned start (and move the inner lr up toward useful
    magnitudes) — the convergence smoke of the acceptance criteria."""
    task = hypertune.make_task()
    cfg = sim.fast_sim_config(hypertune.default_config(task))
    res = hypertune.run(task, cfg, 10, eval_every=2, donate=False)
    evs = [h for h in sim.history(res) if "val_loss" in h]
    assert len(evs) == 5
    assert evs[-1]["val_loss"] < evs[0]["val_loss"] * 0.8
    assert evs[-1]["log_lr"] > evs[0]["log_lr"]
    assert np.isfinite([h["val_loss"] for h in evs]).all()


def test_hypertune_transform_clips_to_sane_band():
    lr, lam = hypertune.transform(jnp.asarray([50.0, -50.0]))
    assert float(lr) == pytest.approx(np.exp(hypertune.LOG_LR_RANGE[1]))
    assert float(lam) == pytest.approx(np.exp(hypertune.LOG_LAM_RANGE[0]))
