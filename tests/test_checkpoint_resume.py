"""Durable engine checkpoints + resumable runs (DESIGN.md §12).

Pins the durability contracts:

- the chunked segment runner (``checkpoint_every=k``) is BITWISE identical
  to the single-shot scan, faults included;
- a run killed between segments resumes from the latest atomic snapshot
  and finishes bit-identical to the committed golden fixtures (pytree AND
  flat/AirComp paths — the ISSUE acceptance matrix);
- snapshots are atomic: tmp-dir staging, ``LATEST`` pointer swap, stale
  tmp debris ignored and swept, bounded retention;
- ``checkpoint.restore`` fails loudly: missing keys / shape mismatches
  name the exact pytree leaf, and sidecars carry jax version + config
  hash (version / config drift warns on restore).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sim
from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import FedZOConfig
from repro.data.synthetic import make_classification, noniid_shards
from repro.models.simple import softmax_init, softmax_loss

BR = 4


def _setup(n=640, n_clients=8, seed=0):
    x, y = make_classification(n, 24, 4, seed=seed)
    return sim.build_store(noniid_shards(x, y, n_clients))


def _cfg(**kw):
    base = dict(n_devices=8, n_participating=4, local_iters=2, lr=1e-2,
                mu=1e-3, b1=8, b2=4, seed=3)
    base.update(kw)
    return FedZOConfig(**base)


def _assert_trees_bitequal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_results_bitequal(a, b):
    _assert_trees_bitequal(a.params, b.params)
    np.testing.assert_array_equal(jax.random.key_data(a.key),
                                  jax.random.key_data(b.key))
    assert sorted(a.metrics) == sorted(b.metrics)
    for k in a.metrics:
        np.testing.assert_array_equal(np.asarray(a.metrics[k]),
                                      np.asarray(b.metrics[k]), err_msg=k)
    for k in a.evals:
        np.testing.assert_array_equal(np.asarray(a.evals[k]),
                                      np.asarray(b.evals[k]), err_msg=k)


# ---------------------------------------------------------------------------
# restore error quality + sidecar provenance (satellites 1 & 2)


def test_restore_shape_mismatch_names_leaf(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, {"w": jnp.zeros((3, 2)), "b": jnp.zeros((3,))})
    bad_like = {"w": jnp.zeros((5, 2)), "b": jnp.zeros((3,))}
    with pytest.raises(ValueError, match=r"\['w'\].*\(3, 2\).*\(5, 2\)"):
        ckpt.restore(d, bad_like)


def test_restore_missing_key_names_leaf(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, {"w": jnp.zeros((3, 2))})
    with pytest.raises(ValueError, match=r"no entry.*\['extra'\]"):
        ckpt.restore(d, {"w": jnp.zeros((3, 2)), "extra": jnp.zeros((2,))})


def test_sidecar_provenance_fields(tmp_path):
    d = str(tmp_path / "ck")
    cfg = _cfg()
    ckpt.save(d, {"w": jnp.ones((2,))}, step=7, meta=cfg)
    with open(os.path.join(d, "meta.json")) as f:
        md = json.load(f)
    assert md["jax_version"] == jax.__version__
    assert md["step"] == 7
    assert md["config_hash"] == ckpt.config_hash(cfg)
    assert "created_at" in md
    params, step = ckpt.restore(d, {"w": jnp.zeros((2,))})
    assert step == 7
    np.testing.assert_array_equal(np.asarray(params["w"]), np.ones(2))


def test_jax_version_mismatch_warns(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, {"w": jnp.ones((2,))})
    mp = os.path.join(d, "meta.json")
    with open(mp) as f:
        md = json.load(f)
    md["jax_version"] = "0.0.1"
    with open(mp, "w") as f:
        json.dump(md, f)
    with pytest.warns(UserWarning, match="jax 0.0.1"):
        ckpt.restore(d, {"w": jnp.zeros((2,))})


# ---------------------------------------------------------------------------
# atomic run-state snapshots


def _tiny_state(v=0.0):
    return {"params": {"w": np.full((2,), v, np.float32)},
            "ring": {"loss": np.zeros((4,), np.float32)}}


def test_save_run_state_pointer_and_retention(tmp_path):
    d = str(tmp_path / "ck")
    for t in (0, 2, 4, 6):
        ckpt.save_run_state(d, _tiny_state(float(t)), round_idx=t,
                            meta={"lr": 0.1}, keep=3)
    assert ckpt.latest_run_state(d) == os.path.join(d, "round_00000006")
    # retention: only the newest `keep` snapshots survive the sweep
    kept = sorted(e for e in os.listdir(d) if e.startswith("round_"))
    assert kept == ["round_00000002", "round_00000004", "round_00000006"]
    state, meta = ckpt.restore_run_state(ckpt.latest_run_state(d),
                                         _tiny_state())
    assert meta["round"] == 6 and meta["lr"] == 0.1
    np.testing.assert_array_equal(state["params"]["w"], np.full(2, 6.0))


def test_stale_tmp_debris_is_ignored_and_swept(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save_run_state(d, _tiny_state(1.0), round_idx=1)
    # a writer SIGKILLed mid-stage leaves a tmp dir behind
    stale = os.path.join(d, "round_00000099.tmp.12345")
    os.makedirs(stale)
    with open(os.path.join(stale, "meta.json"), "w") as f:
        f.write("{}")
    assert ckpt.latest_run_state(d) == os.path.join(d, "round_00000001")
    ckpt.save_run_state(d, _tiny_state(2.0), round_idx=2)
    assert not os.path.exists(stale)


def test_latest_pointer_fallback_to_highest_snapshot(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save_run_state(d, _tiny_state(1.0), round_idx=1)
    ckpt.save_run_state(d, _tiny_state(3.0), round_idx=3)
    os.remove(os.path.join(d, "LATEST"))
    assert ckpt.latest_run_state(d) == os.path.join(d, "round_00000003")


def test_latest_run_state_empty_dir_is_none(tmp_path):
    assert ckpt.latest_run_state(str(tmp_path / "nothing")) is None


# ---------------------------------------------------------------------------
# chunked segment runner ≡ single-shot scan (bitwise)


ENGINE_CASES = [
    ("pytree", {}, None),
    ("flat_aircomp", {"flat_params": True, "flat_block_rows": BR,
                      "aircomp": True, "snr_db": 10.0,
                      "channel_schedule": True}, None),
    ("pytree_faults", {}, sim.FaultModel(p_fail=0.3, p_recover=0.5,
                                         deadline=1.5, p_corrupt=0.3)),
]


@pytest.mark.parametrize("name,kw,faults", ENGINE_CASES)
def test_chunked_matches_single_shot(name, kw, faults, tmp_path):
    store = _setup()
    cfg = _cfg(**kw)
    p0 = softmax_init(None, 24, 4)
    single = sim.run_experiment(softmax_loss, p0, store, cfg, 6,
                                faults=faults, donate=False)
    chunked = sim.run_experiment(
        softmax_loss, p0, store, cfg, 6, faults=faults, donate=False,
        checkpoint_every=4, checkpoint_dir=str(tmp_path / name))
    assert chunked.rounds == 6
    _assert_results_bitequal(single, chunked)
    if faults is not None:
        np.testing.assert_array_equal(np.asarray(single.fault_state),
                                      np.asarray(chunked.fault_state))


@pytest.mark.parametrize("name,kw,faults", ENGINE_CASES)
def test_kill_between_segments_then_resume_is_bitexact(name, kw, faults,
                                                       tmp_path):
    """The preemption drill at the engine level: stop after ONE segment
    (the carry survives only on disk), then a FRESH call with resume=True
    finishes the run bit-identical to the uninterrupted one."""
    store = _setup()
    cfg = _cfg(**kw)
    p0 = softmax_init(None, 24, 4)
    d = str(tmp_path / name)
    single = sim.run_experiment(softmax_loss, p0, store, cfg, 6,
                                faults=faults, donate=False)
    part = sim.run_experiment(softmax_loss, p0, store, cfg, 6, faults=faults,
                              donate=False, checkpoint_every=2,
                              checkpoint_dir=d, max_segments=1)
    assert part.rounds == 2
    resumed = sim.run_experiment(softmax_loss, p0, store, cfg, 6,
                                 faults=faults, donate=False,
                                 checkpoint_every=2, checkpoint_dir=d,
                                 resume=True)
    assert resumed.rounds == 6
    _assert_results_bitequal(single, resumed)


def test_resume_on_fresh_dir_is_a_fresh_start(tmp_path):
    store = _setup()
    cfg = _cfg()
    p0 = softmax_init(None, 24, 4)
    plain = sim.run_experiment(softmax_loss, p0, store, cfg, 4, donate=False,
                               checkpoint_every=2,
                               checkpoint_dir=str(tmp_path / "a"))
    fresh = sim.run_experiment(softmax_loss, p0, store, cfg, 4, donate=False,
                               checkpoint_every=2,
                               checkpoint_dir=str(tmp_path / "b"),
                               resume=True)
    _assert_results_bitequal(plain, fresh)


def test_resume_already_complete_is_a_noop(tmp_path):
    store = _setup()
    cfg = _cfg()
    p0 = softmax_init(None, 24, 4)
    d = str(tmp_path / "ck")
    done = sim.run_experiment(softmax_loss, p0, store, cfg, 4, donate=False,
                              checkpoint_every=2, checkpoint_dir=d)
    again = sim.run_experiment(softmax_loss, p0, store, cfg, 4, donate=False,
                               checkpoint_every=2, checkpoint_dir=d,
                               resume=True)
    _assert_results_bitequal(done, again)


def test_resume_under_different_config_warns(tmp_path):
    store = _setup()
    p0 = softmax_init(None, 24, 4)
    d = str(tmp_path / "ck")
    sim.run_experiment(softmax_loss, p0, store, _cfg(), 4, donate=False,
                       checkpoint_every=2, checkpoint_dir=d, max_segments=1)
    with pytest.warns(UserWarning, match="DIFFERENT config"):
        sim.run_experiment(softmax_loss, p0, store, _cfg(lr=5e-3), 4,
                           donate=False, checkpoint_every=2,
                           checkpoint_dir=d, resume=True)


def test_run_state_meta_records_run_context(tmp_path):
    store = _setup()
    cfg = _cfg()
    d = str(tmp_path / "ck")
    sim.run_experiment(softmax_loss, softmax_init(None, 24, 4), store, cfg,
                       4, donate=False, checkpoint_every=2,
                       checkpoint_dir=d)
    with open(os.path.join(ckpt.latest_run_state(d), "meta.json")) as f:
        md = json.load(f)
    assert md["meta"]["round"] == 4
    assert md["meta"]["rounds_total"] == 4
    assert md["meta"]["config_hash"] == ckpt.config_hash(cfg)
    assert md["meta"]["lr"] == cfg.lr
    assert md["jax_version"] == jax.__version__


def test_checkpoint_every_requires_dir():
    store = _setup()
    with pytest.raises(ValueError, match="checkpoint_dir"):
        sim.run_experiment(softmax_loss, softmax_init(None, 24, 4), store,
                           _cfg(), 2, checkpoint_every=1)


# ---------------------------------------------------------------------------
# kill-and-resume vs the committed golden fixtures (ISSUE acceptance)


@pytest.mark.parametrize("name", ["softmax_counter", "softmax_aircomp"])
def test_kill_and_resume_matches_golden_fixture(name, tmp_path):
    """A run preempted mid-experiment and resumed from disk must land on
    the EXACT committed golden trajectory — pytree reference and
    flat/AirComp kernel paths."""
    import importlib.util

    regen_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "golden", "regen.py")
    spec = importlib.util.spec_from_file_location("golden_regen_ckpt",
                                                  regen_path)
    regen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(regen)

    from repro.workloads import neural

    gspec = regen.GOLDEN[name]
    with open(regen.fixture_path(name)) as f:
        want = json.load(f)
    task_kw = dict(gspec["task"])
    task = neural.make_task(task_kw.pop("name"), **task_kw)
    cfg = neural.default_config(task, **gspec["cfg"])
    d = str(tmp_path / name)
    part = neural.run(task, cfg, gspec["rounds"], eval_every=2,
                      eval_rows=gspec["task"]["n_test"], donate=False,
                      checkpoint_every=3, checkpoint_dir=d, max_segments=1)
    assert part.rounds == 3  # "killed" with the run mid-flight
    res = neural.run(task, cfg, gspec["rounds"], eval_every=2,
                     eval_rows=gspec["task"]["n_test"], donate=False,
                     checkpoint_every=3, checkpoint_dir=d, resume=True)
    assert res.rounds == gspec["rounds"]
    buf = np.concatenate([np.asarray(l, np.float32).ravel()
                          for l in jax.tree.leaves(res.params)])
    assert buf.tobytes().hex() == want["final_params_hex"], (
        f"{name}: resumed run drifted from the golden trajectory")
    mets = jax.device_get(res.metrics)
    evals = jax.device_get(res.evals)
    for group, got in (("metrics", mets), ("evals", evals)):
        for k, hexes in want[group].items():
            got_hex = [np.float32(v).tobytes().hex()
                       for v in np.asarray(got[k]).ravel()]
            assert got_hex == hexes, f"{name}: {group}[{k}] drifted"
