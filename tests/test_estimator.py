"""Properties of the zeroth-order gradient estimator (paper Eq. 2-4).

Key invariants, checked with hypothesis-driven problem instances:
  1. Unbiasedness up to smoothing: E[∇̃F] = ∇f^μ ≈ ∇f with bias O(μ) on
     smooth quadratics (Eq. 4 / [10, Lemma 2]).
  2. Variance shrinks like 1/b2 (the mini-batch estimator's reason to exist).
  3. Seed replay is exact: the update applied by apply_coefficients equals
     the materialized estimate — bit-equal trees.
  4. Direction law: sphere directions have unit global norm; gaussian
     directions have E‖v‖² = d.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import hypothesis, st

from repro.core import estimator
from repro.utils.tree import (sphere_like_tree, tree_axpy, tree_norm,
                              tree_size, tree_sub, tree_zeros_like)

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=10,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


def quad_problem(seed, d=24):
    """f(x) = 0.5 x^T A x + b^T x with known gradient, as a 2-leaf pytree."""
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(d, d)).astype(np.float32)
    a = q @ q.T / d + np.eye(d, dtype=np.float32)
    b = rng.normal(size=(d,)).astype(np.float32)

    def loss(params, batch):
        x = jnp.concatenate([params["p1"], params["p2"]])
        return 0.5 * x @ jnp.asarray(a) @ x + jnp.asarray(b) @ x

    x0 = rng.normal(size=d).astype(np.float32)
    params = {"p1": jnp.asarray(x0[: d // 2]), "p2": jnp.asarray(x0[d // 2:])}
    grad = a @ x0 + b
    return loss, params, grad


@hypothesis.given(st.integers(0, 1000))
def test_sphere_direction_unit_norm(seed):
    params = {"a": jnp.zeros((13,)), "b": jnp.zeros((7, 3))}
    v = sphere_like_tree(jax.random.key(seed), params)
    assert abs(float(tree_norm(v)) - 1.0) < 1e-5


def test_gaussian_direction_norm():
    params = {"a": jnp.zeros((500,))}
    norms = [float(tree_norm(estimator.sample_direction(
        jax.random.key(s), params, "gaussian")) ** 2) for s in range(64)]
    assert abs(np.mean(norms) / 500 - 1.0) < 0.15


@hypothesis.given(st.integers(0, 50))
def test_estimator_unbiased_on_quadratic(seed):
    """Mean over many directions approaches the true gradient (bias O(μ))."""
    loss, params, grad = quad_problem(seed)
    est = estimator.estimate(loss, params, None, jax.random.key(seed),
                             mu=1e-4, b2=4096, kind="sphere")
    est_flat = np.concatenate([np.asarray(l).ravel()
                               for l in jax.tree.leaves(est)])
    cos = est_flat @ grad / (np.linalg.norm(est_flat) * np.linalg.norm(grad))
    rel = np.linalg.norm(est_flat - grad) / np.linalg.norm(grad)
    assert cos > 0.95, cos
    assert rel < 0.4, rel


def test_bias_scales_with_mu():
    """On a cubic-perturbed objective the smoothing bias grows with μ."""
    d = 16
    rng = np.random.default_rng(0)
    b = rng.normal(size=d).astype(np.float32)

    def loss(params, batch):
        x = params["x"]
        return jnp.sum(x ** 3) / 3 + jnp.asarray(b) @ x

    params = {"x": jnp.asarray(rng.normal(size=d).astype(np.float32))}
    grad = 3 * np.asarray(params["x"]) ** 2 / 3 + b

    errs = []
    for mu in (1e-3, 3e-1):
        est = estimator.estimate(loss, params, None, jax.random.key(1),
                                 mu=mu, b2=8192, kind="sphere")
        e = np.concatenate([np.asarray(l).ravel()
                            for l in jax.tree.leaves(est)])
        errs.append(np.linalg.norm(e - grad))
    assert errs[1] > errs[0]


def test_variance_shrinks_with_b2():
    loss, params, grad = quad_problem(3)

    def est_err(b2, seed):
        e = estimator.estimate(loss, params, None, jax.random.key(seed),
                               mu=1e-4, b2=b2)
        ef = np.concatenate([np.asarray(l).ravel()
                             for l in jax.tree.leaves(e)])
        return np.sum((ef - grad) ** 2)

    small = np.mean([est_err(8, s) for s in range(8)])
    large = np.mean([est_err(256, s) for s in range(8)])
    assert large < small / 4, (small, large)


@hypothesis.given(st.integers(0, 20), st.integers(1, 6))
def test_seed_replay_exact(seed, b2):
    """apply_coefficients(zeros) reconstructs the materialized estimate."""
    loss, params, _ = quad_problem(seed)
    rng = jax.random.key(seed)
    coeffs, _ = estimator.coefficients(loss, params, None, rng, mu=1e-3,
                                       b2=b2)
    est = estimator.apply_coefficients(tree_zeros_like(params), rng, coeffs)
    est2 = estimator.estimate(loss, params, None, rng, mu=1e-3, b2=b2)
    for a, c in zip(jax.tree.leaves(est), jax.tree.leaves(est2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_coordinate_estimator_is_basis_aligned():
    loss, params, grad = quad_problem(7)
    v = estimator.sample_direction(jax.random.key(0), params, "coordinate")
    flat = np.concatenate([np.asarray(l).ravel()
                           for l in jax.tree.leaves(v)])
    assert np.sum(flat != 0) == 1 and np.isclose(np.abs(flat).sum(), 1.0)


def test_gaussian_estimator_unbiased():
    loss, params, grad = quad_problem(11)
    est = estimator.estimate(loss, params, None, jax.random.key(2),
                             mu=1e-4, b2=8192, kind="gaussian")
    e = np.concatenate([np.asarray(l).ravel()
                        for l in jax.tree.leaves(est)])
    cos = e @ grad / (np.linalg.norm(e) * np.linalg.norm(grad))
    assert cos > 0.95


def test_rademacher_estimator_unbiased():
    loss, params, grad = quad_problem(13)
    est = estimator.estimate(loss, params, None, jax.random.key(3),
                             mu=1e-4, b2=4096, kind="rademacher")
    e = np.concatenate([np.asarray(l).ravel()
                        for l in jax.tree.leaves(est)])
    cos = e @ grad / (np.linalg.norm(e) * np.linalg.norm(grad))
    assert cos > 0.95


def test_central_difference_reduces_variance():
    """Both one-sided and central differences estimate the same smoothed
    gradient ∇f^μ; the central form cancels the even-order terms pathwise,
    so at large μ on a curved objective its per-sample estimates have
    smaller spread (classic ZO variance reduction at +1 query/direction)."""
    loss, params, grad = quad_problem(17)
    mu = 0.5

    def spread(central):
        es = []
        for s in range(8):
            coeffs, _ = estimator.coefficients(
                loss, params, None, jax.random.key(s), mu=mu, b2=64,
                central=central)
            e = estimator.apply_coefficients(
                tree_zeros_like(params), jax.random.key(s), coeffs)
            es.append(np.concatenate([np.asarray(l).ravel()
                                      for l in jax.tree.leaves(e)]))
        es = np.stack(es)
        return np.mean(np.var(es, axis=0))

    assert spread(True) < spread(False), (spread(True), spread(False))


def test_server_momentum_accelerates_quadratic():
    from repro.configs.base import FedZOConfig
    from repro.core import fedzo
    from repro.utils.tree import tree_zeros_like

    def qloss(params, batch):
        return 0.5 * jnp.sum((params["x"] - 1.0) ** 2)

    batches = {"target": jnp.ones((4, 2, 1))}  # [M, H, dummy]
    rngs = jax.random.split(jax.random.key(0), 4)

    def run(mom):
        cfg = FedZOConfig(local_iters=2, lr=0.02, mu=1e-3, b2=8,
                          server_momentum=mom)
        p = {"x": jnp.zeros((16,))}
        m = tree_zeros_like(p)
        for t in range(10):
            p, _, m = fedzo.round_simulated(
                qloss, p, batches, jax.random.split(jax.random.key(t), 4),
                cfg, momentum=m)
        return float(qloss(p, None))

    assert run(0.6) < run(0.0)
