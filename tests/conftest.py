import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet with a fake multi-device CPU (XLA flags must be
    set before jax init, so multi-device tests run out-of-process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:{res.stdout[-4000:]}\n"
            f"STDERR:{res.stderr[-4000:]}")
    return res.stdout


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.key(0)
