"""Per-architecture smoke tests (deliverable f): every assigned architecture,
REDUCED variant of the same family, one forward/train step on CPU asserting
output shapes + no NaNs; plus decode-vs-prefill consistency and a FedZO train
step on the reduced model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import FedZOConfig, ShapeConfig
from repro.core import fedzo
from repro.models.api import build, make_batch

S, B = 32, 2
SHAPE = ShapeConfig("smoke", S, B, "train")


@pytest.fixture(scope="module")
def built():
    out = {}
    for a in ARCH_IDS:
        cfg = get_config(a).reduced()
        m = build(cfg)
        params = m.init(jax.random.key(0))
        out[a] = (cfg, m, params)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch, built):
    cfg, m, params = built[arch]
    batch = make_batch(m, SHAPE, jax.random.key(1))
    loss = m.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), float(loss)
    assert 0.0 < float(loss) < 3 * np.log(cfg.vocab)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_fedzo_train_step_descends(arch, built):
    """One FedZO iterate must run and keep the model finite on every arch —
    the black-box applicability claim of DESIGN.md §Arch-applicability.
    Marked slow: the 12-arch ZO-trajectory sweep is ~4 min of the suite;
    the fast CI job keeps per-arch coverage via test_forward_loss_finite."""
    cfg, m, params = built[arch]
    batch = make_batch(m, SHAPE, jax.random.key(2))
    fcfg = FedZOConfig(b2=2, lr=1e-4, mu=1e-3)
    step = fedzo.make_train_step(lambda p, b: m.loss(p, b), fcfg)
    new_params, metrics = step(params, batch, jax.random.key(3))
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved
    # and stays finite after the update
    l2 = m.loss(new_params, batch)
    assert bool(jnp.isfinite(l2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch, built):
    cfg, m, params = built[arch]
    pshape = ShapeConfig("p", S, B, "prefill")
    batch = make_batch(m, pshape, jax.random.key(4))
    _, cache = m.prefill(params, batch, S + 4)
    nxt = jax.random.randint(jax.random.key(5), (B, 1), 0, cfg.vocab,
                             jnp.int32)
    db = dict(batch)
    db["tokens"] = nxt
    logits_dec, _ = m.decode(params, db, cache, jnp.asarray(S, jnp.int32))
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], nxt], 1)
    logits_ref, _ = m.prefill(params, b2, S + 5)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_ref), atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("arch", ["rwkv6-7b", "hymba-1.5b"])
def test_recurrent_decode_cache_is_constant_size(arch, built):
    """SSM/hybrid archs decode from O(1)/O(window) state — the reason they
    run long_500k natively."""
    cfg, m, params = built[arch]
    cache = m.init_cache(B, 16)
    leaves = jax.tree.leaves(cache)
    total = sum(l.size for l in leaves)
    cache_big = m.init_cache(B, 64)
    total_big = sum(l.size for l in jax.tree.leaves(cache_big))
    if arch == "rwkv6-7b":
        assert total == total_big  # pure state, no KV width dependence
    else:
        assert total_big < total * 8  # hybrid: ring window + state


def test_moe_aux_loss_present():
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    m = build(cfg)
    params = m.init(jax.random.key(0))
    batch = make_batch(m, SHAPE, jax.random.key(1))
    base = m.loss(params, batch)
    cfg0 = cfg.replace(router_aux_coef=0.0)
    m0 = build(cfg0)
    l0 = m0.loss(params, batch)
    assert float(base) != float(l0)  # aux term contributes


def test_mtp_loss_contributes():
    cfg = get_config("deepseek-v3-671b").reduced()
    m = build(cfg)
    params = m.init(jax.random.key(0))
    batch = make_batch(m, SHAPE, jax.random.key(1))
    with_mtp = float(m.loss(params, batch))
    m0 = build(cfg.replace(mtp=False))
    p0 = {k: v for k, v in params.items() if not k.startswith("mtp")}
    without = float(m0.loss(p0, batch))
    assert with_mtp > without  # extra positive xent term


def test_sliding_window_changes_attention():
    cfg = get_config("qwen2-0.5b").reduced()
    m = build(cfg)
    params = m.init(jax.random.key(0))
    batch = make_batch(m, SHAPE, jax.random.key(1))
    mw = build(cfg.replace(sliding_window=4))
    l_full = float(m.loss(params, batch))
    l_win = float(mw.loss(params, batch))
    assert l_full != l_win
