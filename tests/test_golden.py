"""Golden-trajectory regression suite (DESIGN.md §11).

Re-runs the short counter-convention neural-task experiments whose
trajectories are committed under ``tests/golden/`` and diffs every per-round
metric, the in-scan eval curve, and the full final parameter buffer
BIT-EXACTLY against the fixtures. A kernel or engine refactor that drifts
numerics — by one ulp — fails here loudly instead of silently shifting all
downstream results.

After an INTENTIONAL numerics change, regenerate and review:

    PYTHONPATH=src python tests/golden/regen.py
"""
from __future__ import annotations

import importlib.util
import json
import os

import numpy as np
import pytest

_REGEN = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden",
                      "regen.py")
_spec = importlib.util.spec_from_file_location("golden_regen", _REGEN)
golden_regen = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(golden_regen)

REGEN_HINT = ("bit-exact golden trajectory diverged; if the numerics change "
              "is INTENTIONAL regenerate with "
              "`PYTHONPATH=src python tests/golden/regen.py` and review the "
              "approx-field diff")


@pytest.mark.parametrize("name", sorted(golden_regen.GOLDEN))
def test_golden_trajectory(name):
    path = golden_regen.fixture_path(name)
    assert os.path.exists(path), (
        f"missing fixture {path}; generate it with "
        f"`PYTHONPATH=src python tests/golden/regen.py --only {name}`")
    with open(path) as f:
        want = json.load(f)
    # the fixture's recorded config must match the in-repo definition —
    # otherwise the diff would compare different experiments
    spec = golden_regen.GOLDEN[name]
    assert want["task"] == {k: (list(v) if isinstance(v, tuple) else v)
                            for k, v in spec["task"].items()}, (
        f"{name}: fixture was generated from a different task config — "
        f"regenerate")
    assert want["cfg"] == spec["cfg"], (
        f"{name}: fixture was generated from a different run config — "
        f"regenerate")

    got = golden_regen.run_fixture(name)
    for group in ("metrics", "evals"):
        assert sorted(got[group]) == sorted(want[group]), (
            f"{name}: {group} keys changed: {sorted(got[group])} vs "
            f"{sorted(want[group])}; {REGEN_HINT}")
        for key in want[group]:
            assert len(got[group][key]) == len(want[group][key]), (
                f"{name}: {group}[{key}] length changed "
                f"({len(got[group][key])} vs {len(want[group][key])}); "
                f"{REGEN_HINT}")
            for t, (g, w) in enumerate(zip(got[group][key],
                                           want[group][key])):
                assert g == w, (
                    f"{name}: {group}[{key}][{t}] drifted: "
                    f"{got[group + '_approx'][key][t]} != "
                    f"{want[group + '_approx'][key][t]}; {REGEN_HINT}")
    assert got["n_params"] == want["n_params"], f"{name}: {REGEN_HINT}"
    if got["final_params_hex"] != want["final_params_hex"]:
        g = np.frombuffer(bytes.fromhex(got["final_params_hex"]), np.float32)
        w = np.frombuffer(bytes.fromhex(want["final_params_hex"]),
                          np.float32)
        bad = int((g != w).sum())
        raise AssertionError(
            f"{name}: final params drifted in {bad}/{g.size} scalars "
            f"(max abs diff {np.abs(g - w).max():.3e}); {REGEN_HINT}")
