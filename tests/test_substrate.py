"""Data pipeline, checkpointing, optim, fedavg/baselines, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import restore, save
from repro.configs import get_config
from repro.configs.base import FedZOConfig
from repro.core import baselines, fedavg
from repro.data.synthetic import (lm_batches, lm_token_stream,
                                  make_classification, noniid_shards,
                                  random_partition)
from repro.launch.sharding import leaf_spec
from repro.models.simple import softmax_init, softmax_loss


def test_noniid_shards_label_concentration():
    x, y = make_classification(4000, 16, 10, seed=0)
    clients = noniid_shards(x, y, 50)
    assert len(clients) == 50
    label_counts = [len(np.unique(c["y"])) for c in clients]
    assert max(label_counts) <= 4  # ≤ 2 shards × ≤ 2 boundary labels
    total = sum(len(c["y"]) for c in clients)
    assert total == 50 * (4000 // 100) * 2


def test_random_partition_uneven_sizes():
    x, y = make_classification(1000, 8, 10, seed=1)
    clients = random_partition(x, y, 10, seed=2)
    sizes = [len(c["y"]) for c in clients]
    assert sum(sizes) == 1000 and min(sizes) >= 1
    assert len(set(sizes)) > 1  # 'random number of samples' per device


def test_data_determinism():
    a = make_classification(100, 8, 4, seed=7)[0]
    b = make_classification(100, 8, 4, seed=7)[0]
    np.testing.assert_array_equal(a, b)
    t1 = lm_token_stream(500, 64, seed=3)
    t2 = lm_token_stream(500, 64, seed=3)
    np.testing.assert_array_equal(t1, t2)


def test_lm_batches_are_shifted():
    toks = lm_token_stream(2000, 32, seed=0)
    b = lm_batches(toks, 4, 16, np.random.default_rng(0))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_checkpoint_roundtrip(tmp_path):
    params = softmax_init(jax.random.key(0))
    params = jax.tree.map(
        lambda x: x + jax.random.normal(jax.random.key(1), x.shape), params)
    save(str(tmp_path / "ck"), params, step=7, meta=FedZOConfig())
    restored, step = restore(str(tmp_path / "ck"), params)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fedavg_round_descends():
    x, y = make_classification(2000, 784, 10, seed=0)
    clients = noniid_shards(x, y, 10)
    cfg = FedZOConfig(n_devices=10, n_participating=10, local_iters=5,
                      lr=0.01, b1=32)
    from repro.data.synthetic import sample_local_batches
    rng = np.random.default_rng(0)
    per = [sample_local_batches(c, rng, 5, 32) for c in clients]
    batches = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    params = softmax_init(jax.random.key(0))
    p2, m = fedavg.round_simulated(softmax_loss, params, batches, cfg)
    full = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    assert float(softmax_loss(p2, full)) < float(softmax_loss(params, full))


def test_zone_s_and_dzopa_descend_quadratic():
    def loss(params, batch):
        return 0.5 * jnp.sum((params["x"] - 1.0) ** 2)

    params = {"x": jnp.zeros((16,))}
    p, l0 = baselines.zone_s_round(loss, params, None, jax.random.key(0),
                                   rho=50.0, mu=1e-3, b2=8)
    assert float(loss(p, None)) < float(l0)

    cp = {"x": jnp.zeros((4, 16))}
    batches = jnp.zeros((4, 1))
    rngs = jax.random.split(jax.random.key(1), 4)
    cfg = FedZOConfig(lr=0.05, mu=1e-3, b2=8)
    cp2, l = baselines.dzopa_round(lambda p, b: loss(p, None), cp,
                                   batches, rngs, cfg)
    assert float(loss({"x": cp2["x"][0]}, None)) < float(l)
    # consensus: all agents equal after fully-connected mixing
    np.testing.assert_allclose(np.asarray(cp2["x"][0]),
                               np.asarray(cp2["x"][3]))


class _FakeMesh:
    shape = {"data": 16, "model": 16}
    axis_names = ("data", "model")


def test_leaf_spec_rules():
    mesh = _FakeMesh()
    # vocab-parallel embed
    assert tuple(leaf_spec("['embed']['tok']", (151936, 896), mesh)) == \
        ("model", None)
    # non-divisible vocab -> replicated
    assert tuple(leaf_spec("['embed']['tok']", (256206, 1024), mesh)) == \
        (None, None)
    # expert weights: E over model, ff over data
    spec = leaf_spec("['moe_blocks']['moe']['w_gate']", (58, 256, 7168, 2048),
                     mesh)
    assert tuple(spec) == (None, "model", None, "data")
    spec = leaf_spec("['moe_blocks']['moe']['w_down']", (58, 256, 2048, 7168),
                     mesh)
    assert tuple(spec) == (None, "model", "data", None)
    # stacked dense weight: layer dim never sharded
    spec = leaf_spec("['blocks']['mlp']['w_up']", (24, 896, 4864), mesh)
    assert spec[0] is None and "model" in tuple(spec)
    # awkward heads fall back (40 not divisible by 16): wq [d, 40*128]
    spec = leaf_spec("['blocks']['attn']['wq']", (64, 5120, 5120), mesh)
    assert tuple(spec)[1:] != (None, None)
    # tiny leaves replicated
    assert tuple(leaf_spec("['final_norm']['scale']", (896,), mesh)) == ()


def test_cosine_schedule_monotone_tail():
    from repro.optim.sgd import cosine_lr
    lrs = [float(cosine_lr(s, base_lr=1.0, total_steps=100, warmup=10))
           for s in range(0, 100, 10)]
    assert lrs[1] >= lrs[0] or lrs[0] < 1e-6  # warmup ramps
    assert lrs[-1] < lrs[2]
