"""Property-based invariants (hypothesis via the tests/_hyp shim).

- ``FlatParams`` flatten/unflatten is a bit-exact round trip over arbitrary
  pytrees: mixed dtypes (f32, bf16, int32), scalar leaves, empty leaves,
  nested containers; the buffer geometry (d, n_pad, zeroed pad region)
  always matches the spec.
- Partitioner invariants: every dataset row is assigned to EXACTLY one
  client, client sizes sum to n, and every client gets ≥ 1 row — for the
  Dirichlet label-skew split, the label-sorted shard deal, and the
  uneven/iid random partitions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from _hyp import hypothesis, st

from repro.data.synthetic import (dirichlet_partition, noniid_shards,
                                  random_partition)
from repro.utils.flatparams import flat_spec, flatten, unflatten

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=10,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


# ---------------------------------------------------------------------------
# FlatParams round trip


_SHAPES = [(), (0,), (1,), (3,), (2, 3), (1, 4, 2), (7,), (2, 0, 3)]


def _random_pytree(seed: int, n_leaves: int):
    """Arbitrary nested pytree: mixed dtypes incl. scalars + empty leaves."""
    rng = np.random.default_rng(seed)
    leaves = []
    for _ in range(n_leaves):
        shp = _SHAPES[int(rng.integers(0, len(_SHAPES)))]
        kind = int(rng.integers(0, 3))
        if kind == 0:
            leaf = jnp.asarray(rng.normal(size=shp), jnp.float32)
        elif kind == 1:
            # bf16 values are exactly representable in f32, so the buffer
            # cast round-trips bit-exactly
            leaf = jnp.asarray(rng.normal(size=shp),
                               jnp.float32).astype(jnp.bfloat16)
        else:
            # |v| < 2^24 survives the int32 → f32 → int32 cast exactly
            leaf = jnp.asarray(rng.integers(-10_000, 10_000, shp), jnp.int32)
        leaves.append(leaf)
    # alternate container kinds so treedefs vary, not just leaf lists
    tree = {"head": leaves[0]}
    if len(leaves) > 1:
        tree["rest"] = leaves[1:]
    if len(leaves) > 3:
        tree["nested"] = {"pair": (leaves[2], leaves[3])}
    return tree


@hypothesis.given(st.integers(0, 10_000), st.integers(1, 6))
def test_flatparams_roundtrip_bitexact(seed, n_leaves):
    params = _random_pytree(seed, n_leaves)
    spec = flat_spec(params, block=8)
    buf = flatten(params, spec)
    assert buf.shape == (spec.n_pad,)
    assert spec.d == sum(int(np.prod(s)) for s in spec.shapes)
    assert spec.n_pad % 8 == 0 and spec.n_pad >= spec.d
    # pad region is zero (the kernels stream it; garbage would leak into
    # masked reductions)
    assert not np.asarray(buf[spec.d:]).any()
    back = unflatten(buf, spec)
    la, lb = jax.tree.leaves(params), jax.tree.leaves(back)
    assert jax.tree.structure(params) == jax.tree.structure(back)
    for a, b in zip(la, lb):
        assert a.shape == b.shape and a.dtype == b.dtype, (a, b)
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@hypothesis.given(st.integers(0, 1000))
def test_flatparams_scalar_offsets_follow_traversal_order(seed):
    """The flat index of a scalar is its offset in leaf-traversal order —
    the contract the counter direction convention is keyed on."""
    params = _random_pytree(seed, 4)
    spec = flat_spec(params, block=8)
    buf = np.asarray(flatten(params, spec))
    off = 0
    for leaf in jax.tree.leaves(params):
        flat = np.asarray(leaf, np.float32).ravel()
        np.testing.assert_array_equal(buf[off:off + flat.size], flat)
        off += flat.size
    assert off == spec.d


# ---------------------------------------------------------------------------
# partitioner invariants


def _check_partition(clients, n, n_clients):
    sizes = [len(c["y"]) for c in clients]
    assert len(clients) == n_clients
    assert min(sizes) >= 1
    assert sum(sizes) == n
    # every row exactly once: the x column carries a unique row id
    ids = np.sort(np.concatenate([c["x"][:, 0].astype(np.int64)
                                  for c in clients]))
    np.testing.assert_array_equal(ids, np.arange(n))


def _id_problem(n, n_classes, seed):
    x = np.arange(n, dtype=np.float32)[:, None]   # row id as the feature
    y = (np.random.default_rng(seed).integers(0, n_classes, n)
         .astype(np.int32))
    return x, y


@hypothesis.given(st.integers(2, 12), st.integers(0, 1000),
                  st.floats(0.05, 5.0))
def test_dirichlet_partition_invariants(n_clients, seed, alpha):
    n = n_clients + int(seed) % 70
    x, y = _id_problem(n, 4, seed)
    _check_partition(dirichlet_partition(x, y, n_clients, alpha=alpha,
                                         seed=seed), n, n_clients)


@hypothesis.given(st.integers(2, 12), st.integers(0, 1000))
def test_random_partition_invariants(n_clients, seed):
    n = n_clients + int(seed) % 70
    x, y = _id_problem(n, 4, seed)
    for uneven in (False, True):
        _check_partition(random_partition(x, y, n_clients, seed=seed,
                                          uneven=uneven), n, n_clients)


@hypothesis.given(st.integers(2, 10), st.integers(0, 1000))
def test_noniid_shards_invariants(n_clients, seed):
    n = 2 * n_clients + int(seed) % 70
    x, y = _id_problem(n, 3, seed)
    _check_partition(noniid_shards(x, y, n_clients, seed=seed), n, n_clients)


# ---------------------------------------------------------------------------
# aircomp mask_stats: the one masking convention shared by every
# aggregation path (channel truncation, faults, battery gating)


def _stacked_deltas(seed: int, M: int):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(M, 5)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(M, 2)), jnp.float32)}


@hypothesis.given(st.integers(1, 8), st.integers(0, 255), st.integers(0, 999))
def test_all_ones_weights_are_bitwise_unweighted(M, mask_bits, seed):
    """FedAvg size weighting with all-ones weights (uniform client sizes)
    is bit-for-bit the unweighted path: identical per-row coefficients,
    identical divisor whenever ≥1 client is scheduled, and an identical
    Eq.-17 aggregate for the same noise key."""
    from repro.core.aircomp import aircomp_aggregate, mask_stats

    mask = jnp.asarray([(mask_bits >> i) & 1 for i in range(M)], jnp.bool_)
    ones = jnp.ones((M,), jnp.float32)
    mf_u, div_u, ms_u = mask_stats(mask, M)
    mf_w, div_w, ms_w = mask_stats(mask, M, ones)
    np.testing.assert_array_equal(np.asarray(mf_u), np.asarray(mf_w))
    np.testing.assert_array_equal(np.asarray(ms_u), np.asarray(ms_w))
    if int(ms_u) >= 1:
        np.testing.assert_array_equal(np.asarray(div_u), np.asarray(div_w))
    deltas = _stacked_deltas(seed, M)
    key = jax.random.key(seed)
    agg_u = aircomp_aggregate(deltas, key, snr_db=10.0, h_min=0.3, mask=mask)
    agg_w = aircomp_aggregate(deltas, key, snr_db=10.0, h_min=0.3, mask=mask,
                              weights=ones)
    for a, b in zip(jax.tree.leaves(agg_u), jax.tree.leaves(agg_w)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@hypothesis.given(st.integers(1, 8), st.integers(0, 999))
def test_all_masked_round_is_exact_zero_update(M, seed):
    """A round where nothing transmits (deep fades everywhere, every
    battery drained) degenerates to an EXACT zero aggregate — zero
    numerator and zero Δ_max ⇒ zero Eq.-17 noise — on the unweighted AND
    the size-weighted path, never a NaN from the 0/0."""
    from repro.core.aircomp import aircomp_aggregate

    mask = jnp.zeros((M,), jnp.bool_)
    deltas = _stacked_deltas(seed, M)
    w = jnp.asarray(np.random.default_rng(seed + 1).uniform(0.5, 2.0, M),
                    jnp.float32)
    key = jax.random.key(seed)
    for weights in (None, w):
        agg = aircomp_aggregate(deltas, key, snr_db=10.0, h_min=0.3,
                                mask=mask, weights=weights)
        for leaf in jax.tree.leaves(agg):
            np.testing.assert_array_equal(np.asarray(leaf),
                                          np.zeros_like(np.asarray(leaf)))
