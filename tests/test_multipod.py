"""Multi-pod round semantics on a small fake mesh (subprocess): the
shared-direction pod round equals the equivalent single-device computation,
and the dense-delta aggregation program averages exactly."""
import pytest

from tests.conftest import run_subprocess


@pytest.mark.slow
def test_pod_round_matches_single_device_math():
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.configs.base import FedZOConfig, ShapeConfig
from repro.core import fedzo
from repro.core.estimator import coefficients, apply_coefficients
from repro.launch.mesh import _make_mesh
from repro.models.api import build, make_batch

mesh = _make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_config("qwen2-0.5b").reduced()
m = build(cfg)
params = m.init(jax.random.key(0))
batch = make_batch(m, ShapeConfig("t", 16, 8, "train"), jax.random.key(1))
fcfg = FedZOConfig(b2=2, lr=1e-3, mu=1e-2)

loss_g = lambda p, b: m.loss(p, b, mesh=mesh, n_groups=2)
step = jax.jit(fedzo.make_pod_round_step(loss_g, fcfg, mesh))
newp, metrics = step(params, batch, jax.random.key(5))

# unsharded loss agrees with the sharded grouped loss (ulp-level)
loss_ref = lambda p, b: m.loss(p, b, n_groups=2)
np.testing.assert_allclose(np.asarray(metrics["per_pod_loss"]),
                           np.asarray(loss_ref(params, batch)), rtol=2e-4)
# round-logic reference: manual loop with the SAME grouped loss — the
# coefficient's d/mu factor amplifies even 1-ulp loss differences, so the
# sharded-vs-unsharded check above must not be compounded here
base = loss_g(params, batch)
from repro.utils.tree import tree_axpy, tree_size
from repro.core.estimator import sample_direction, _scale_factor
d = tree_size(params); scale = _scale_factor(d, "sphere")
cs = []
for n in range(2):
    v = sample_direction(jax.random.fold_in(jax.random.key(5), n), params, "sphere")
    lp = loss_g(tree_axpy(fcfg.mu, v, params), batch)
    cs.append(scale * np.mean(np.asarray(lp - base)) / fcfg.mu)
ref_p = apply_coefficients(params, jax.random.key(5), jnp.asarray(cs), scale=-fcfg.lr)
for a, b in zip(jax.tree.leaves(newp), jax.tree.leaves(ref_p)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), atol=3e-4)
print("pod round OK")
""", n_devices=8)


@pytest.mark.slow
def test_delta_agg_program_averages():
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import FedZOConfig
from repro.core import fedzo
deltas = {"w": jnp.stack([jnp.full((64,), 1.0), jnp.full((64,), 3.0)])}
agg = jax.jit(fedzo.make_delta_agg_step(FedZOConfig(aircomp=False), 2))(deltas, jax.random.key(0))
np.testing.assert_allclose(np.asarray(agg["w"]), 2.0)
noisy = jax.jit(fedzo.make_delta_agg_step(FedZOConfig(aircomp=True, snr_db=30.0), 2))(deltas, jax.random.key(0))
assert abs(float(noisy["w"].mean()) - 2.0) < 0.2
print("agg OK")
""", n_devices=8)
