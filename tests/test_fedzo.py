"""Algorithm-level tests for FedZO (paper Algorithm 1 + Theorems 1-2
qualitative behavior) and the seed-compressed delta path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedZOConfig
from repro.core import fedzo, seedcomm
from repro.data.synthetic import make_classification, noniid_shards
from repro.fed.server import FedServer
from repro.models.simple import softmax_accuracy, softmax_init, softmax_loss
from repro.utils.tree import tree_norm, tree_sub


def _quad_loss(params, batch):
    x = params["x"]
    return 0.5 * jnp.sum((x - batch["target"]) ** 2)


def _quad_setup(d=32, h=3):
    params = {"x": jnp.zeros((d,))}
    target = jnp.ones((d,))
    batches = {"target": jnp.tile(target, (h, 1))}
    return params, batches, target


def test_local_phase_descends_quadratic():
    cfg = FedZOConfig(local_iters=3, lr=0.05, mu=1e-3, b2=16)
    params, batches, target = _quad_setup()
    res = fedzo.local_phase(_quad_loss, params, batches, jax.random.key(0), cfg)
    assert res.losses.shape == (3,)
    assert res.coeffs.shape == (3, 16)
    assert float(res.losses[-1]) < float(res.losses[0])


def test_client_delta_matches_seedcomm_reconstruction():
    """Δ_i reconstructed from (seed, coeffs) is bit-exact (seed replay)."""
    cfg = FedZOConfig(local_iters=4, lr=0.02, mu=1e-3, b2=5)
    params, batches, _ = _quad_setup(d=20, h=4)
    rng = jax.random.key(42)
    delta, res = fedzo.client_delta(_quad_loss, params, batches, rng, cfg)
    msg = seedcomm.compress(rng, res.coeffs, cfg)
    recon = seedcomm.reconstruct_delta(msg, params, cfg)
    for a, b in zip(jax.tree.leaves(delta), jax.tree.leaves(recon)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    assert seedcomm.wire_bytes(msg) < 120  # ≪ 4·d bytes


def test_round_simulated_full_vs_partial():
    """Partial participation is unbiased: both modes descend the quadratic."""
    cfg = FedZOConfig(n_devices=8, n_participating=8, local_iters=2, lr=0.05,
                      mu=1e-3, b2=8)
    params, _, target = _quad_setup(d=16, h=2)
    batches = {"target": jnp.ones((8, 2, 16))}
    rngs = jax.random.split(jax.random.key(0), 8)
    p_full, m = fedzo.round_simulated(_quad_loss, params, batches, rngs, cfg)
    err_full = float(tree_norm(tree_sub(p_full, {"x": target})))
    assert err_full < float(tree_norm(tree_sub(params, {"x": target})))


@pytest.mark.slow
def test_softmax_regression_end_to_end_learns():
    """Sec V-B shape of experiment at reduced scale: FedZO reaches high test
    accuracy on a separable non-iid 10-class problem."""
    x, y = make_classification(3500, 784, 10, seed=0)
    xtr, ytr, xt, yt = x[:3000], y[:3000], x[3000:], y[3000:]
    clients = noniid_shards(xtr, ytr, 20)
    test = {"x": jnp.asarray(xt), "y": jnp.asarray(yt)}
    cfg = FedZOConfig(n_devices=20, n_participating=5, local_iters=5,
                      lr=1e-3, mu=1e-3, b1=25, b2=20, seed=1)
    srv = FedServer(softmax_loss, softmax_init(jax.random.key(0)), clients, cfg)
    srv.run(15)
    acc = float(softmax_accuracy(srv.params, test))
    assert acc > 0.8, acc


@pytest.mark.slow
def test_speedup_in_participation():
    """Corollary 2: more participating devices → faster convergence
    (monotone in M on average)."""
    x, y = make_classification(3000, 784, 10, seed=2)
    clients = noniid_shards(x, y, 20)
    test_batch = {"x": jnp.asarray(x[:800]), "y": jnp.asarray(y[:800])}

    def final_loss(m):
        cfg = FedZOConfig(n_devices=20, n_participating=m, local_iters=5,
                          lr=1e-3, mu=1e-3, b1=25, b2=10, seed=3)
        srv = FedServer(softmax_loss, softmax_init(jax.random.key(0)),
                        clients, cfg)
        srv.run(8)
        return float(softmax_loss(srv.params, test_batch))

    assert final_loss(10) < final_loss(2) + 0.05


def test_make_train_step_is_jittable():
    cfg = FedZOConfig(b2=3, lr=0.05, mu=1e-3)
    step = jax.jit(fedzo.make_train_step(_quad_loss, cfg))
    params = {"x": jnp.zeros((16,))}
    batch = {"target": jnp.ones((16,))}
    p, metrics = step(params, batch, jax.random.key(0))
    assert jnp.isfinite(metrics["loss"])
    p2, m2 = step(p, batch, jax.random.key(1))
    assert float(m2["loss"]) < float(metrics["loss"])
