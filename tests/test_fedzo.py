"""Algorithm-level tests for FedZO (paper Algorithm 1 + Theorems 1-2
qualitative behavior), the flat-buffer round engine, channel-truncation
scheduling, and the seed-compressed delta path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedZOConfig
from repro.core import fedzo, seedcomm
from repro.data.synthetic import make_classification, noniid_shards
from repro.fed.server import FedServer, run_seed_compressed_round
from repro.models.simple import softmax_accuracy, softmax_init, softmax_loss
from repro.utils.tree import tree_bytes, tree_norm, tree_sub

BR = 4  # small kernel blocks for CPU interpret mode


def _quad_loss(params, batch):
    x = params["x"]
    return 0.5 * jnp.sum((x - batch["target"]) ** 2)


def _quad_setup(d=32, h=3):
    params = {"x": jnp.zeros((d,))}
    target = jnp.ones((d,))
    batches = {"target": jnp.tile(target, (h, 1))}
    return params, batches, target


def test_local_phase_descends_quadratic():
    cfg = FedZOConfig(local_iters=3, lr=0.05, mu=1e-3, b2=16)
    params, batches, target = _quad_setup()
    res = fedzo.local_phase(_quad_loss, params, batches, jax.random.key(0), cfg)
    assert res.losses.shape == (3,)
    assert res.coeffs.shape == (3, 16)
    assert float(res.losses[-1]) < float(res.losses[0])


def test_client_delta_matches_seedcomm_reconstruction():
    """Δ_i reconstructed from (seed, coeffs) is bit-exact (seed replay)."""
    cfg = FedZOConfig(local_iters=4, lr=0.02, mu=1e-3, b2=5)
    params, batches, _ = _quad_setup(d=20, h=4)
    rng = jax.random.key(42)
    delta, res = fedzo.client_delta(_quad_loss, params, batches, rng, cfg)
    msg = seedcomm.compress(rng, res.coeffs, cfg)
    recon = seedcomm.reconstruct_delta(msg, params, cfg)
    for a, b in zip(jax.tree.leaves(delta), jax.tree.leaves(recon)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    assert seedcomm.wire_bytes(msg) < 120  # ≪ 4·d bytes


def test_round_simulated_full_vs_partial():
    """Partial participation is unbiased: both modes descend the quadratic."""
    cfg = FedZOConfig(n_devices=8, n_participating=8, local_iters=2, lr=0.05,
                      mu=1e-3, b2=8)
    params, _, target = _quad_setup(d=16, h=2)
    batches = {"target": jnp.ones((8, 2, 16))}
    rngs = jax.random.split(jax.random.key(0), 8)
    p_full, m = fedzo.round_simulated(_quad_loss, params, batches, rngs, cfg)
    err_full = float(tree_norm(tree_sub(p_full, {"x": target})))
    assert err_full < float(tree_norm(tree_sub(params, {"x": target})))


def test_flat_round_matches_pytree_round():
    """The flat round engine walks the pytree reference round's directions
    (conv="counter"): one round over M clients lands on the same server
    params up to fp32 reassociation (amplified by the 1/μ quotient)."""
    cfg_tree = FedZOConfig(local_iters=2, lr=0.05, mu=1e-3, b2=6,
                           direction_conv="counter")
    cfg_flat = dataclasses.replace(cfg_tree, flat_params=True,
                                   flat_block_rows=BR)
    params = {"x": jnp.zeros((300,))}
    batches = {"target": jnp.ones((4, 2, 300))}
    rngs = jax.random.split(jax.random.key(0), 4)
    p_t, m_t = fedzo.round_simulated(_quad_loss, params, batches, rngs,
                                     cfg_tree)
    p_f, m_f = fedzo.round_simulated(_quad_loss, params, batches, rngs,
                                     cfg_flat)
    np.testing.assert_allclose(float(m_f["mean_local_loss"]),
                               float(m_t["mean_local_loss"]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p_f["x"]), np.asarray(p_t["x"]),
                               atol=1e-2, rtol=1e-3)


def test_flat_round_seed_replay_exact():
    """Acceptance: a flat-round client delta is reproducible from its
    (key, coeffs) message — the replayed directions are bit-exact (counter
    convention), so the reconstruction matches to the fp32 round-off of
    accumulating onto zeros instead of the live buffer."""
    cfg = FedZOConfig(local_iters=3, lr=0.02, mu=1e-3, b2=5,
                      flat_params=True, flat_block_rows=BR)
    params = {"x": jnp.zeros((40,))}
    batches = {"target": jnp.ones((3, 40))}
    rng = jax.random.key(9)
    delta, res = fedzo.client_delta(_quad_loss, params, batches, rng, cfg)
    msg = seedcomm.compress(rng, res.coeffs, cfg)
    recon = seedcomm.reconstruct_delta(msg, params, cfg)
    np.testing.assert_allclose(np.asarray(delta["x"]),
                               np.asarray(recon["x"]), atol=1e-7)


@pytest.mark.parametrize("flat", [False, True])
def test_channel_schedule_reports_m_effective(flat):
    """cfg.channel_schedule end to end: the round draws a Rayleigh mask,
    reports m_effective ≤ M, and stays finite (both round engines)."""
    cfg = FedZOConfig(local_iters=2, lr=0.05, mu=1e-3, b2=4,
                      aircomp=True, snr_db=20.0, channel_schedule=True,
                      flat_params=flat, flat_block_rows=BR if flat else 0)
    params = {"x": jnp.zeros((64,))}
    batches = {"target": jnp.ones((6, 2, 64))}
    rngs = jax.random.split(jax.random.key(0), 6)
    p, m = fedzo.round_simulated(_quad_loss, params, batches, rngs, cfg,
                                 channel_rng=jax.random.key(5))
    assert 0.0 <= float(m["m_effective"]) <= 6.0
    assert jnp.all(jnp.isfinite(p["x"]))


def test_channel_schedule_through_fedserver():
    """FedServer wires channel-truncation scheduling into its jitted round
    and surfaces m_effective in the per-round metrics."""
    x, y = make_classification(400, 32, 4, seed=0)
    clients = noniid_shards(x, y, 8)
    cfg = FedZOConfig(n_devices=8, n_participating=6, local_iters=2,
                      lr=1e-3, mu=1e-3, b1=8, b2=4, aircomp=True,
                      snr_db=20.0, channel_schedule=True, seed=3)
    srv = FedServer(softmax_loss, softmax_init(None, n_features=32, n_classes=4), clients, cfg)
    hist = srv.run(2)
    for m in hist:
        assert 0.0 <= m["m_effective"] <= 6.0
        assert np.isfinite(m["mean_local_loss"])


def test_server_momentum_threaded_through_fedserver():
    """Regression for the dropped-momentum bug: FedServer used to ignore
    cfg.server_momentum entirely, so a momentum run was bit-identical to a
    momentum-free run. The two must diverge."""
    x, y = make_classification(400, 32, 4, seed=1)
    clients = noniid_shards(x, y, 8)

    def run(mom):
        cfg = FedZOConfig(n_devices=8, n_participating=4, local_iters=2,
                          lr=1e-3, mu=1e-3, b1=8, b2=4,
                          server_momentum=mom, seed=7)
        srv = FedServer(softmax_loss, softmax_init(None, n_features=32, n_classes=4),
                        clients, cfg)
        srv.run(3)
        return srv.params

    p0, p1 = run(0.0), run(0.9)
    diff = float(tree_norm(tree_sub(p0, p1)))
    assert diff > 1e-6, diff  # momentum must actually change the trajectory


def test_wire_and_dense_bytes_exact():
    """Byte accounting is dtype-/size-exact: wire_bytes equals the actual
    nbytes of the message arrays (8 B threefry key, not 16) and
    run_seed_compressed_round's dense_bytes honors leaf dtypes."""
    cfg = FedZOConfig(local_iters=3, lr=0.01, mu=1e-2, b2=4)
    params = {"w": jnp.zeros((10,), jnp.float32),
              "b": jnp.zeros((4,), jnp.bfloat16)}

    def loss(p, batch):
        return (0.5 * jnp.sum((p["w"] - batch["target"][..., :10]) ** 2)
                + 0.5 * jnp.sum(p["b"].astype(jnp.float32) ** 2))

    batches = [{"target": jnp.ones((3, 16))} for _ in range(2)]
    rngs = list(jax.random.split(jax.random.key(0), 2))
    _, wire, dense = run_seed_compressed_round(loss, params, batches, rngs,
                                               cfg)
    msg = seedcomm.compress(rngs[0], jnp.zeros((3, 4), jnp.float32), cfg)
    expect_one = (np.asarray(msg["key"]).nbytes + msg["coeffs"].nbytes
                  + np.asarray(msg["lr"]).nbytes)
    assert seedcomm.wire_bytes(msg) == expect_one == 8 + 3 * 4 * 4 + 4
    assert wire == 2 * expect_one
    # bf16 leaf costs 2 B/param — the old `size * 4` formula overcounted
    assert dense == 2 * tree_bytes(params) == 2 * (10 * 4 + 4 * 2)


def test_batched_aggregate_matches_per_message():
    """seedcomm.aggregate (one batched scan over [M·H, b2]) equals the mean
    of per-message reconstructions on both round engines."""
    params = {"x": jnp.zeros((40,))}
    for cfg in (FedZOConfig(local_iters=2, lr=0.02, mu=1e-3, b2=5),
                FedZOConfig(local_iters=2, lr=0.02, mu=1e-3, b2=5,
                            flat_params=True, flat_block_rows=BR)):
        msgs = []
        for i in range(3):
            rng = jax.random.key(50 + i)
            batches = {"target": (i + 1.0) * jnp.ones((2, 40))}
            _, res = fedzo.client_delta(_quad_loss, params, batches, rng, cfg)
            msgs.append(seedcomm.compress(rng, res.coeffs, cfg))
        agg = seedcomm.aggregate(msgs, params, cfg)
        ref = None
        for m in msgs:
            r = seedcomm.reconstruct_delta(m, params, cfg)
            ref = r if ref is None else jax.tree.map(jnp.add, ref, r)
        ref = jax.tree.map(lambda v: v / 3.0, ref)
        np.testing.assert_allclose(np.asarray(agg["x"]),
                                   np.asarray(ref["x"]), atol=1e-6)


@pytest.mark.slow
def test_softmax_regression_end_to_end_learns():
    """Sec V-B shape of experiment at reduced scale: FedZO reaches high test
    accuracy on a separable non-iid 10-class problem."""
    x, y = make_classification(3500, 784, 10, seed=0)
    xtr, ytr, xt, yt = x[:3000], y[:3000], x[3000:], y[3000:]
    clients = noniid_shards(xtr, ytr, 20)
    test = {"x": jnp.asarray(xt), "y": jnp.asarray(yt)}
    cfg = FedZOConfig(n_devices=20, n_participating=5, local_iters=5,
                      lr=1e-3, mu=1e-3, b1=25, b2=20, seed=1)
    srv = FedServer(softmax_loss, softmax_init(jax.random.key(0)), clients, cfg)
    srv.run(15)
    acc = float(softmax_accuracy(srv.params, test))
    assert acc > 0.8, acc


@pytest.mark.slow
def test_speedup_in_participation():
    """Corollary 2: more participating devices → faster convergence
    (monotone in M on average)."""
    x, y = make_classification(3000, 784, 10, seed=2)
    clients = noniid_shards(x, y, 20)
    test_batch = {"x": jnp.asarray(x[:800]), "y": jnp.asarray(y[:800])}

    def final_loss(m):
        cfg = FedZOConfig(n_devices=20, n_participating=m, local_iters=5,
                          lr=1e-3, mu=1e-3, b1=25, b2=10, seed=3)
        srv = FedServer(softmax_loss, softmax_init(jax.random.key(0)),
                        clients, cfg)
        srv.run(8)
        return float(softmax_loss(srv.params, test_batch))

    assert final_loss(10) < final_loss(2) + 0.05


def test_make_train_step_is_jittable():
    cfg = FedZOConfig(b2=3, lr=0.05, mu=1e-3)
    step = jax.jit(fedzo.make_train_step(_quad_loss, cfg))
    params = {"x": jnp.zeros((16,))}
    batch = {"target": jnp.ones((16,))}
    p, metrics = step(params, batch, jax.random.key(0))
    assert jnp.isfinite(metrics["loss"])
    p2, m2 = step(p, batch, jax.random.key(1))
    assert float(m2["loss"]) < float(metrics["loss"])
