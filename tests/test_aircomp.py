"""AirComp transceiver tests (paper Section IV, Eqs. 14-17 + Remark 4),
including the channel-truncation mask semantics and the fused one-pass
aggregation kernel (kernels/zo_aircomp.py)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import hypothesis, st

from repro.core.aircomp import (aircomp_aggregate, aircomp_aggregate_flat,
                                aircomp_simulate_channel, schedule_by_channel)
from repro.kernels import ops, ref

BR = 4  # small kernel blocks for CPU interpret mode: 4 rows × 128 lanes

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=10,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


def test_high_snr_recovers_mean():
    deltas = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(5, 64)),
                               dtype=jnp.float32)}
    agg, stats = aircomp_aggregate(deltas, jax.random.key(0), snr_db=200.0,
                                   h_min=0.8)
    np.testing.assert_allclose(np.asarray(agg["w"]),
                               np.asarray(jnp.mean(deltas["w"], 0)),
                               atol=1e-5)


def test_noise_variance_matches_eq17():
    """Empirical variance of the recovered update error ≈ σ_w²Δmax/(M²dPh²)."""
    rng = np.random.default_rng(1)
    M, d = 4, 256
    deltas = {"w": jnp.asarray(rng.normal(size=(M, d)), dtype=jnp.float32)}
    sq = np.sum(np.asarray(deltas["w"]) ** 2, axis=1)
    snr_db, h_min = 0.0, 0.8
    expected_var = 1.0 * sq.max() / (M ** 2 * d * 1.0 * h_min ** 2)
    errs = []
    mean = np.mean(np.asarray(deltas["w"]), axis=0)
    for s in range(200):
        agg, _ = aircomp_aggregate(deltas, jax.random.key(s), snr_db=snr_db,
                                   h_min=h_min)
        errs.append(np.asarray(agg["w"]) - mean)
    emp_var = np.var(np.stack(errs))
    assert 0.7 * expected_var < emp_var < 1.4 * expected_var, \
        (emp_var, expected_var)


def test_explicit_channel_matches_closed_form_variance():
    """The complex-channel simulation agrees with the Eq.17 closed form:
    the recovered update is exactly the scheduled-subset mean plus receiver
    noise whose variance matches σ_w²Δmax/(m²dPh²) under the complex→real
    projection (factor 1/2)."""
    rng = np.random.default_rng(2)
    M, d = 5, 512
    deltas = jnp.asarray(rng.normal(size=(M, d)), dtype=jnp.float32)
    errs, pred = [], []
    for s in range(100):
        y, diag = aircomp_simulate_channel(deltas, jax.random.key(s),
                                           snr_db=0.0, h_min=0.8)
        sched = np.abs(np.asarray(diag["h"])) >= 0.8
        if not sched.any():
            continue
        errs.append(np.asarray(y)
                    - np.asarray(deltas)[sched].mean(axis=0))
        pred.append(float(diag["delta_max"])
                    / (sched.sum() ** 2 * d * 0.8 ** 2) / 2.0)
    bias = np.abs(np.mean(np.stack(errs)))
    assert bias < 0.02, bias
    emp = np.var(np.stack(errs))
    expected = np.mean(pred)
    assert 0.7 * expected < emp < 1.4 * expected, (emp, expected)


def test_energy_constraint_for_scheduled_devices():
    """‖α_i Δ_i‖² ≤ dP for EVERY device: scheduled devices stay within the
    Eq.-15 budget, deep-fade devices (|h| < h_min) transmit nothing at all.
    Equal-norm rows make the old behavior unmistakable — any unscheduled
    row that transmitted would need α = h_min/|h| > 1 and blow through the
    budget. Regression: pre-fix the mask was ignored and unscheduled rows
    radiated over-budget energy."""
    rng = np.random.default_rng(3)
    base = rng.normal(size=(8, 128)).astype(np.float32)
    base /= np.linalg.norm(base, axis=1, keepdims=True)   # equal ‖Δ_i‖
    y, diag = aircomp_simulate_channel(jnp.asarray(base), jax.random.key(7),
                                       snr_db=0.0, h_min=0.8)
    scheduled = np.asarray(diag["mask"])
    assert 0 < scheduled.sum() < 8        # both populations present
    energy = np.asarray(diag["tx_energy"])
    assert np.all(energy <= diag["energy_budget"] * (1 + 1e-5)), energy
    np.testing.assert_array_equal(energy[~scheduled], 0.0)
    assert float(diag["m_effective"]) == scheduled.sum()


@hypothesis.given(st.floats(0.2, 1.5))
def test_schedule_rate_matches_rayleigh(h_min):
    """P(|h| ≥ h_min) = exp(-h_min²) for CN(0,1) channels."""
    h, mask = schedule_by_channel(jax.random.key(0), 20000, h_min)
    rate = float(jnp.mean(mask.astype(jnp.float32)))
    assert abs(rate - np.exp(-h_min ** 2)) < 0.02


def test_mask_excludes_rows_from_mean_and_delta_max():
    """Channel-truncation semantics: a masked-out row contributes to
    neither the mean nor Δ_max, and m_effective counts only scheduled
    rows. The masked row here has a huge norm so leakage into Δ_max (and
    hence the noise scale) would be unmistakable."""
    rng = np.random.default_rng(0)
    base = rng.normal(size=(4, 256)).astype(np.float32)
    base[2] *= 1e3                                 # the masked-out row
    deltas = {"w": jnp.asarray(base)}
    mask = jnp.asarray([True, True, False, True])
    agg, stats = aircomp_aggregate(deltas, jax.random.key(0), snr_db=200.0,
                                   h_min=0.8, mask=mask)
    expect = base[[0, 1, 3]].mean(axis=0)
    np.testing.assert_allclose(np.asarray(agg["w"]), expect, atol=1e-4)
    assert float(stats["m_effective"]) == 3.0
    sq = np.sum(base ** 2, axis=1)
    np.testing.assert_allclose(float(stats["delta_max"]),
                               sq[[0, 1, 3]].max(), rtol=1e-5)
    assert float(stats["delta_max"]) < sq[2]


def test_all_masked_round_degenerates_safely():
    """An all-masked round (no device scheduled) must not divide by zero:
    the aggregate is exactly zero (a no-op server update) with zero noise,
    in both the pytree and fused-flat implementations — and m_effective
    truthfully reports 0 (only the internal divisor is clamped)."""
    deltas = jnp.asarray(np.random.default_rng(1).normal(size=(3, 256)),
                         jnp.float32)
    mask = jnp.zeros((3,), bool)
    agg, stats = aircomp_aggregate({"w": deltas}, jax.random.key(0),
                                   snr_db=0.0, h_min=0.8, mask=mask)
    np.testing.assert_array_equal(np.asarray(agg["w"]),
                                  np.zeros_like(deltas[0]))
    assert float(stats["aircomp_noise_std"]) == 0.0
    assert float(stats["m_effective"]) == 0.0
    fagg, fstats = aircomp_aggregate_flat(deltas, jax.random.key(0),
                                          snr_db=0.0, h_min=0.8, mask=mask,
                                          block_rows=BR)
    np.testing.assert_array_equal(np.asarray(fagg),
                                  np.zeros_like(deltas[0]))
    assert float(fstats["aircomp_noise_std"]) == 0.0
    assert float(fstats["m_effective"]) == 0.0


def test_aircomp_reduce_kernel_matches_reference():
    """The fused kernel agrees with its pure-jnp oracle (same per-block,
    row-ascending partial-sum order) including the d-masking of padding."""
    m, blocks = 3, 2
    n = blocks * BR * 128
    d = n - 37                                      # exercise pad masking
    x = jax.random.normal(jax.random.key(0), (m, n), jnp.float32)
    scale = jnp.asarray([0.5, 0.0, 0.25], jnp.float32)
    mean, sq = ops.aircomp_reduce(x, scale, d, block_rows=BR)
    rmean, rsq = ref.aircomp_reduce_ref(x.reshape(m, -1, 128), scale, d,
                                        block_rows=BR)
    np.testing.assert_allclose(np.asarray(mean),
                               np.asarray(rmean).reshape(-1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sq), np.asarray(rsq), rtol=1e-6)
    # and with the direct formula
    direct_sq = np.sum(np.asarray(x[:, :d]) ** 2, axis=1)
    np.testing.assert_allclose(np.asarray(sq), direct_sq, rtol=1e-5)
    direct_mean = np.einsum("mn,m->n", np.asarray(x), np.asarray(scale))
    np.testing.assert_allclose(np.asarray(mean), direct_mean, atol=1e-5)


def test_fused_flat_matches_pytree_aggregate():
    """aircomp_aggregate_flat reproduces aircomp_aggregate exactly on the
    deterministic parts (mean, Δ_max, m_eff, noise_std) under a mask —
    only the noise realization differs (counter convention vs fold_in)."""
    deltas = jnp.asarray(np.random.default_rng(2).normal(size=(5, 640)),
                         jnp.float32)
    mask = jnp.asarray([True, False, True, True, False])
    agg_t, s_t = aircomp_aggregate({"w": deltas}, jax.random.key(1),
                                   snr_db=200.0, h_min=0.8, mask=mask)
    agg_f, s_f = aircomp_aggregate_flat(deltas, jax.random.key(1),
                                        snr_db=200.0, h_min=0.8, mask=mask,
                                        block_rows=BR)
    np.testing.assert_allclose(np.asarray(agg_f), np.asarray(agg_t["w"]),
                               atol=1e-5)
    for k in ("delta_max", "m_effective", "aircomp_noise_std"):
        np.testing.assert_allclose(float(s_f[k]), float(s_t[k]), rtol=1e-5)


def test_fused_flat_noise_variance_matches_closed_form():
    """The fused aggregation's error variance matches the Eq.-17 closed
    form σ_w²Δmax/(M²dPh²) — the same closed form the explicit complex
    simulation (aircomp_simulate_channel) is validated against."""
    rng = np.random.default_rng(3)
    M, d = 4, 512
    deltas = jnp.asarray(rng.normal(size=(M, d)), jnp.float32)
    sq = np.sum(np.asarray(deltas) ** 2, axis=1)
    snr_db, h_min = 0.0, 0.8
    expected_var = 1.0 * sq.max() / (M ** 2 * d * 1.0 * h_min ** 2)
    mean = np.mean(np.asarray(deltas), axis=0)
    f = jax.jit(lambda k: aircomp_aggregate_flat(
        deltas, k, snr_db=snr_db, h_min=h_min, block_rows=BR)[0])
    errs = [np.asarray(f(jax.random.key(s))) - mean for s in range(200)]
    emp_var = np.var(np.stack(errs))
    assert 0.7 * expected_var < emp_var < 1.4 * expected_var, \
        (emp_var, expected_var)


def test_noise_shrinks_as_updates_shrink():
    """Remark 4: the transceiver scales noise with Δmax, so late-training
    (small updates) sees proportionally small absolute noise."""
    big = {"w": 10.0 * jnp.ones((4, 64))}
    small = {"w": 0.1 * jnp.ones((4, 64))}
    _, s_big = aircomp_aggregate(big, jax.random.key(0), snr_db=0.0, h_min=0.8)
    _, s_small = aircomp_aggregate(small, jax.random.key(0), snr_db=0.0,
                                   h_min=0.8)
    ratio = float(s_big["aircomp_noise_std"] / s_small["aircomp_noise_std"])
    assert abs(ratio - 100.0) < 1.0  # ‖Δ‖ ratio is 100×
