"""AirComp transceiver tests (paper Section IV, Eqs. 14-17 + Remark 4)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import hypothesis, st

from repro.core.aircomp import (aircomp_aggregate, aircomp_simulate_channel,
                                schedule_by_channel)

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=10,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


def test_high_snr_recovers_mean():
    deltas = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(5, 64)),
                               dtype=jnp.float32)}
    agg, stats = aircomp_aggregate(deltas, jax.random.key(0), snr_db=200.0,
                                   h_min=0.8)
    np.testing.assert_allclose(np.asarray(agg["w"]),
                               np.asarray(jnp.mean(deltas["w"], 0)),
                               atol=1e-5)


def test_noise_variance_matches_eq17():
    """Empirical variance of the recovered update error ≈ σ_w²Δmax/(M²dPh²)."""
    rng = np.random.default_rng(1)
    M, d = 4, 256
    deltas = {"w": jnp.asarray(rng.normal(size=(M, d)), dtype=jnp.float32)}
    sq = np.sum(np.asarray(deltas["w"]) ** 2, axis=1)
    snr_db, h_min = 0.0, 0.8
    expected_var = 1.0 * sq.max() / (M ** 2 * d * 1.0 * h_min ** 2)
    errs = []
    mean = np.mean(np.asarray(deltas["w"]), axis=0)
    for s in range(200):
        agg, _ = aircomp_aggregate(deltas, jax.random.key(s), snr_db=snr_db,
                                   h_min=h_min)
        errs.append(np.asarray(agg["w"]) - mean)
    emp_var = np.var(np.stack(errs))
    assert 0.7 * expected_var < emp_var < 1.4 * expected_var, \
        (emp_var, expected_var)


def test_explicit_channel_matches_closed_form_variance():
    """The complex-channel simulation agrees with the Eq.17 closed form:
    unbiased mean recovery and matching error variance (up to the complex→
    real projection factor 1/2 ≤ c ≤ 1)."""
    rng = np.random.default_rng(2)
    M, d = 5, 512
    deltas = jnp.asarray(rng.normal(size=(M, d)), dtype=jnp.float32)
    mean = np.mean(np.asarray(deltas), axis=0)
    errs = []
    for s in range(100):
        y, diag = aircomp_simulate_channel(deltas, jax.random.key(s),
                                           snr_db=0.0, h_min=0.8)
        errs.append(np.asarray(y) - mean)
    bias = np.abs(np.mean(np.stack(errs)))
    assert bias < 0.02, bias
    sq = np.sum(np.asarray(deltas) ** 2, axis=1)
    full_var = sq.max() / (M ** 2 * d * 0.8 ** 2)
    emp = np.var(np.stack(errs))
    assert 0.3 * full_var < emp < 1.2 * full_var  # real projection halves it


def test_energy_constraint_for_scheduled_devices():
    """‖α_i Δ_i‖² ≤ dP whenever |h_i| ≥ h_min (the scheduling criterion)."""
    rng = np.random.default_rng(3)
    deltas = jnp.asarray(rng.normal(size=(8, 128)), dtype=jnp.float32)
    y, diag = aircomp_simulate_channel(deltas, jax.random.key(7), snr_db=0.0,
                                       h_min=0.8)
    scheduled = np.abs(np.asarray(diag["h"])) >= 0.8
    if scheduled.any():
        assert np.all(np.asarray(diag["tx_energy"])[scheduled]
                      <= diag["energy_budget"] * (1 + 1e-5))


@hypothesis.given(st.floats(0.2, 1.5))
def test_schedule_rate_matches_rayleigh(h_min):
    """P(|h| ≥ h_min) = exp(-h_min²) for CN(0,1) channels."""
    h, mask = schedule_by_channel(jax.random.key(0), 20000, h_min)
    rate = float(jnp.mean(mask.astype(jnp.float32)))
    assert abs(rate - np.exp(-h_min ** 2)) < 0.02


def test_noise_shrinks_as_updates_shrink():
    """Remark 4: the transceiver scales noise with Δmax, so late-training
    (small updates) sees proportionally small absolute noise."""
    big = {"w": 10.0 * jnp.ones((4, 64))}
    small = {"w": 0.1 * jnp.ones((4, 64))}
    _, s_big = aircomp_aggregate(big, jax.random.key(0), snr_db=0.0, h_min=0.8)
    _, s_small = aircomp_aggregate(small, jax.random.key(0), snr_db=0.0,
                                   h_min=0.8)
    ratio = float(s_big["aircomp_noise_std"] / s_small["aircomp_noise_std"])
    assert abs(ratio - 100.0) < 1.0  # ‖Δ‖ ratio is 100×
