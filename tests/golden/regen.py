"""Golden-trajectory fixtures for the neural FedZO tasks (DESIGN.md §11).

Each fixture pins a short (≤10-round) engine run of a counter-convention
neural task BIT-EXACTLY: per-round metrics, the in-scan eval curve, and the
full final parameter buffer are stored as hex-encoded float32 bytes (plus a
human-readable approximation). ``tests/test_golden.py`` re-runs the same
configs and diffs against these files, so a kernel or engine refactor that
drifts numerics — even by one ulp — fails loudly instead of silently
changing every downstream result.

Regenerate after an INTENTIONAL numerics change (new jax pin, a deliberate
kernel rework) with:

    PYTHONPATH=src python tests/golden/regen.py [--only NAME]

and eyeball the diff: the "approx" fields make an accidental large drift
obvious in review.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))

# every fixture runs the counter direction convention — the one convention
# shared bit-exactly by the pytree reference and the flat Pallas kernels,
# so the same fixture pins both ends (DESIGN.md §7)
_SOFTMAX_TASK = dict(name="softmax", n_train=320, n_test=96, n_clients=6,
                     n_features=24, n_classes=4, alpha=0.5)
_CNN_TASK = dict(name="cnn", n_train=240, n_test=64, n_clients=6,
                 n_classes=4, image_shape=(12, 12, 1), width=4)
_BASE_CFG = dict(n_participating=3, local_iters=2, b1=8, b2=4, lr=5e-2,
                 mu=1e-3, direction_conv="counter", seed=11)

GOLDEN = {
    # pytree reference path
    "softmax_counter": dict(task=_SOFTMAX_TASK, cfg=_BASE_CFG, rounds=8),
    # flat-buffer Pallas hot path (interpret mode on CPU) — same task, so a
    # drift in the kernels alone shows up as THIS fixture diverging
    "softmax_flat": dict(task=_SOFTMAX_TASK,
                         cfg={**_BASE_CFG, "flat_params": True,
                              "flat_block_rows": 4}, rounds=8),
    # channel numerics: Rayleigh scheduling + Eq.-17 AirComp noise on the
    # fused flat aggregation
    "softmax_aircomp": dict(task=_SOFTMAX_TASK,
                            cfg={**_BASE_CFG, "flat_params": True,
                                 "flat_block_rows": 4, "aircomp": True,
                                 "snr_db": 5.0, "channel_schedule": True},
                            rounds=8),
    # the conv track on the pytree counter path
    "cnn_counter": dict(task=_CNN_TASK, cfg={**_BASE_CFG, "lr": 2e-2},
                        rounds=6),
    # strategy layer (DESIGN.md §13): proximal loss wrap on the same task —
    # pins that the wrapped local phase, not just plain FedZO, stays bit-stable
    "softmax_fedprox": dict(task=_SOFTMAX_TASK,
                            cfg={**_BASE_CFG, "strategy": "fedprox",
                                 "prox_mu": 0.1}, rounds=8),
    # stateful strategy: per-client control variates in the scan carry plus
    # the post-phase delta correction and the server control update
    "softmax_scaffold": dict(task=_SOFTMAX_TASK,
                             cfg={**_BASE_CFG, "strategy": "scaffold"},
                             rounds=8),
}


def _hex32(arr) -> list:
    return [np.float32(v).tobytes().hex() for v in np.asarray(arr).ravel()]


def _approx(arr) -> list:
    return [float(np.float32(v)) for v in np.asarray(arr).ravel()]


def run_fixture(name: str) -> dict:
    """Run one golden config and return its bit-exact payload."""
    import jax

    from repro import sim
    from repro.workloads import neural

    spec = GOLDEN[name]
    task_kw = dict(spec["task"])
    task = neural.make_task(task_kw.pop("name"), **task_kw)
    cfg = neural.default_config(task, **spec["cfg"])
    res = neural.run(task, cfg, spec["rounds"], eval_every=2,
                     eval_rows=spec["task"]["n_test"], donate=False)
    mets = jax.device_get(res.metrics)
    evals = jax.device_get(res.evals)
    buf = np.concatenate([np.asarray(l, np.float32).ravel()
                          for l in jax.tree.leaves(res.params)])
    return {
        "task": spec["task"], "cfg": spec["cfg"], "rounds": spec["rounds"],
        "metrics": {k: _hex32(v) for k, v in sorted(mets.items())},
        "metrics_approx": {k: _approx(v) for k, v in sorted(mets.items())},
        "evals": {k: _hex32(v) for k, v in sorted(evals.items())},
        "evals_approx": {k: _approx(v) for k, v in sorted(evals.items())},
        "final_params_hex": buf.tobytes().hex(),
        "final_params_head_approx": _approx(buf[:8]),
        "n_params": int(buf.size),
    }


def fixture_path(name: str) -> str:
    return os.path.join(HERE, f"{name}.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", default="",
                    help="regenerate just this fixture name")
    args = ap.parse_args(argv)
    names = [args.only] if args.only else sorted(GOLDEN)
    for name in names:
        payload = run_fixture(name)
        with open(fixture_path(name), "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"wrote {fixture_path(name)} "
              f"({payload['n_params']} params, {payload['rounds']} rounds)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
