"""FlatParams: flatten/unflatten round-trips, padding, spec caching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.flatparams import flat_spec, flatten, unflatten
from repro.utils.tree import tree_size


def _mixed_tree():
    return {
        "w": jnp.asarray(np.random.default_rng(0).normal(size=(17, 5)),
                         jnp.float32),
        "emb": jnp.asarray(np.random.default_rng(1).normal(size=(3, 4, 2)),
                           jnp.bfloat16),
        "b": jnp.arange(7, dtype=jnp.float32),
        "nested": {"s": jnp.asarray([[2.5]], jnp.float32)},
    }


def test_round_trip_identity_mixed_dtypes():
    tree = _mixed_tree()
    spec = flat_spec(tree, block=256)
    buf = flatten(tree, spec)
    out = unflatten(buf, spec)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype
        # bf16 → fp32 → bf16 is exact, fp32 passes through untouched
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_padding_geometry():
    tree = _mixed_tree()
    d = tree_size(tree)
    spec = flat_spec(tree, block=256)
    assert spec.d == d
    assert spec.n_pad % 256 == 0 and 0 <= spec.n_pad - d < 256
    buf = flatten(tree, spec)
    assert buf.shape == (spec.n_pad,)
    # pad region zeroed
    np.testing.assert_array_equal(np.asarray(buf[spec.d:]), 0.0)


def test_flat_index_convention_matches_leaf_order():
    """buf[offset:offset+size] IS the leaf, in traversal order — the index
    the counter-based direction convention is keyed on."""
    tree = _mixed_tree()
    spec = flat_spec(tree, block=128)
    buf = flatten(tree, spec)
    leaves = jax.tree.leaves(tree)
    for leaf, off, sz in zip(leaves, spec.offsets, spec.sizes):
        np.testing.assert_array_equal(
            np.asarray(buf[off:off + sz]),
            np.asarray(leaf.reshape(-1), np.float32))


def test_spec_is_cached():
    tree = _mixed_tree()
    s1 = flat_spec(tree, block=256)
    s2 = flat_spec(tree, block=256)
    assert s1 is s2
    s3 = flat_spec(tree, block=512)
    assert s3 is not s1 and s3.n_pad % 512 == 0


def test_unflatten_accepts_unpadded_buffer():
    """unflatten only needs the first d elements (reference-path use)."""
    tree = _mixed_tree()
    spec = flat_spec(tree, block=256)
    buf = flatten(tree, spec)[:spec.d]
    out = unflatten(buf, spec)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_flatten_inside_jit():
    tree = _mixed_tree()
    spec = flat_spec(tree, block=256)

    @jax.jit
    def rt(t):
        return unflatten(flatten(t, spec), spec)

    out = rt(tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
