"""repro.sim: the fully-jitted federation engine (DESIGN.md §9).

Pins the subsystem's contracts:
- R in-jit rounds bit-match R host-driven ``FedServer.run_round`` calls
  under identical seeds (fedzo/fedavg, momentum, channel scheduling,
  AirComp, flat and wide local phases) — the two drivers share one round
  step and one key-chain protocol.
- ``ClientStore`` sampling: participation draws are uniform M-of-N without
  replacement; minibatch rows are uniform-with-replacement over each
  client's true size (the host ``sample_local_batches`` distribution) and
  never touch padding.
- The clients-axis shard_map round equals the single-device round on a
  1-device mesh (tight allclose — XLA fuses differently around the psum,
  so 1-ulp wiggle is expected; the math is identical).
- The batched-direction (wide) phase walks the loop estimator's exact
  directions under direction_conv="tree".
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sim
from repro.configs.base import FedZOConfig
from repro.core import aircomp, fedavg, fedzo, seedcomm
from repro.data.synthetic import (make_classification, noniid_shards,
                                  random_partition, sample_local_batches)
from repro.fed.server import FedServer, run_seed_compressed_round
from repro.models.simple import softmax_accuracy, softmax_init, softmax_loss

BR = 4  # small kernel blocks for CPU interpret mode


def _setup(n=640, n_clients=8, n_features=24, n_classes=4, seed=0):
    x, y = make_classification(n, n_features, n_classes, seed=seed)
    clients = noniid_shards(x, y, n_clients)
    return clients, sim.build_store(clients)


def _cfg(**kw):
    base = dict(n_devices=8, n_participating=4, local_iters=2, lr=1e-2,
                mu=1e-3, b1=8, b2=4, seed=3)
    base.update(kw)
    return FedZOConfig(**base)


def _assert_trees_bitequal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# engine ≡ host-driven rounds


@pytest.mark.parametrize("name,kw,algo", [
    ("plain", {}, "fedzo"),
    ("momentum", {"server_momentum": 0.9}, "fedzo"),
    ("aircomp_sched", {"aircomp": True, "snr_db": 10.0,
                       "channel_schedule": True}, "fedzo"),
    ("flat", {"flat_params": True, "flat_block_rows": BR}, "fedzo"),
    ("wide_block", {"batch_directions": True, "direction_conv": "block",
                    "prng_impl": "unsafe_rbg"}, "fedzo"),
    ("fedavg_sched", {"channel_schedule": True}, "fedavg"),
])
def test_engine_bitmatches_host_rounds(name, kw, algo):
    """R rounds inside one lax.scan == R FedServer.run_round calls, bit for
    bit — both drivers run the identical sim round step from the identical
    key chain."""
    clients, store = _setup()
    cfg = _cfg(**kw)
    p0 = softmax_init(None, 24, 4)
    host = FedServer(softmax_loss, p0, clients, cfg, algo=algo, store=store)
    for t in range(3):
        host.run_round(t)
    scanned = FedServer(softmax_loss, p0, clients, cfg, algo=algo,
                        store=store)
    scanned.run(3)
    _assert_trees_bitequal(host.params, scanned.params)
    assert len(scanned.history) == 3
    for hm, sm in zip(host.history, scanned.history):
        assert hm["mean_local_loss"] == sm["mean_local_loss"], (hm, sm)


def test_run_experiment_smoke_and_eval_cadence():
    """Fast-CI smoke for the scan path: a ≤5-round reduced experiment runs
    in one jit, descends, and evals in-scan every k rounds."""
    clients, store = _setup()
    cfg = sim.fast_sim_config(_cfg())
    test = {"x": jnp.asarray(np.concatenate([c["x"] for c in clients])),
            "y": jnp.asarray(np.concatenate([c["y"] for c in clients]))}
    res = sim.run_experiment(
        softmax_loss, softmax_init(None, 24, 4), store, cfg, 5,
        eval_fn=lambda p: {"acc": softmax_accuracy(p, test)}, eval_every=2)
    hist = sim.history(res)
    assert [h["round"] for h in hist] == [0, 1, 2, 3, 4]
    assert all(np.isfinite(h["mean_local_loss"]) for h in hist)
    assert hist[-1]["mean_local_loss"] < hist[0]["mean_local_loss"]
    # eval lands exactly on rounds 0, 2, 4
    assert [h["round"] for h in hist if "acc" in h] == [0, 2, 4]
    assert all(0.0 <= h["acc"] <= 1.0 for h in hist if "acc" in h)


def test_metrics_ring_buffer_keeps_last_rounds():
    clients, store = _setup()
    cfg = sim.fast_sim_config(_cfg())
    res = sim.run_experiment(softmax_loss, softmax_init(None, 24, 4), store,
                             cfg, 7, ring_size=3)
    hist = sim.history(res)
    assert [h["round"] for h in hist] == [4, 5, 6]
    full = sim.run_experiment(softmax_loss, softmax_init(None, 24, 4), store,
                              cfg, 7)
    tail = sim.history(full)[-3:]
    for a, b in zip(hist, tail):
        assert a == b, (a, b)


def test_engine_momentum_changes_trajectory():
    """cfg.server_momentum threads through the scan carry: a momentum run
    must diverge from a momentum-free run of the same seed."""
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)

    def final(mom):
        res = sim.run_experiment(softmax_loss, p0, store,
                                 _cfg(server_momentum=mom), 4, donate=False)
        return np.asarray(res.params["w"])

    assert np.abs(final(0.0) - final(0.9)).max() > 1e-8


def test_weighted_unscheduled_round_reports_m_effective():
    """``weight_by_size`` without channel scheduling runs the masked-mean
    branch with mask=None — it must STILL report ``m_effective`` (= M,
    nothing masked) so history/CSV columns stay consistent across the
    scenarios of one sweep. Regression: pre-fix the column silently
    vanished on exactly this path (fedzo pytree + flat, and fedavg)."""
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)
    cfg = _cfg()
    batches = sim.sample_batches(store, jnp.arange(4), jax.random.key(7),
                                 cfg.local_iters, cfg.b1)
    rngs = jax.random.split(jax.random.key(1), 4)
    w = aircomp.size_weights(store.sizes[:4])
    _, m_tree = fedzo.round_simulated(softmax_loss, p0, batches, rngs, cfg,
                                      weights=w)
    assert float(m_tree["m_effective"]) == 4.0
    cfgf = _cfg(flat_params=True, flat_block_rows=BR)
    _, m_flat = fedzo.round_simulated(softmax_loss, p0, batches, rngs, cfgf,
                                      weights=w)
    assert float(m_flat["m_effective"]) == 4.0
    _, m_avg = fedavg.round_simulated(softmax_loss, p0, batches, cfg,
                                      weights=w)
    assert float(m_avg["m_effective"]) == 4.0


# ---------------------------------------------------------------------------
# ClientStore sampling


def test_store_sampling_never_touches_padding():
    """Uneven clients → padded store; every gathered row must decode to a
    real (client, row<size) pair."""
    rng = np.random.default_rng(0)
    n, n_clients = 400, 5
    x = np.zeros((n, 1), np.float32)
    y = (np.arange(n) % 3).astype(np.int32)
    clients = random_partition(x, y, n_clients, seed=1, uneven=True)
    for i, c in enumerate(clients):       # encode (client, row) in the value
        c["x"] = np.asarray([[i * 10_000 + j] for j in range(len(c["y"]))],
                            np.float32)
    store = sim.build_store(clients)
    sizes = np.asarray(store.sizes)
    assert len(set(sizes.tolist())) > 1   # the split really is uneven

    idx = jnp.asarray([4, 0, 2])
    batches = jax.jit(lambda k: sim.sample_batches(store, idx, k, 3, 16))(
        jax.random.key(7))
    vals = np.asarray(batches["x"]).reshape(3, -1)
    for m, i in enumerate([4, 0, 2]):
        cl = (vals[m] // 10_000).astype(int)
        row = (vals[m] % 10_000).astype(int)
        assert (cl == i).all()
        assert (row < sizes[i]).all()


def test_store_minibatch_distribution_matches_host():
    """In-jit row sampling is uniform with replacement over the client's
    true size — the host sample_local_batches distribution."""
    clients, store = _setup(n=240, n_clients=4)
    n_i = int(store.sizes[1])
    draws = 400
    keys = jax.random.split(jax.random.key(0), draws)
    rows = jax.vmap(lambda k: jax.random.randint(k, (3, 8), 0,
                                                 store.sizes[1]))(keys)
    dev = np.bincount(np.asarray(rows).ravel(), minlength=n_i)
    host_rng = np.random.default_rng(0)
    host = np.zeros(n_i, np.int64)
    for _ in range(draws):
        b = sample_local_batches(clients[1], host_rng, 3, 8)
        # recover indices by matching row identity is overkill — the host
        # sampler IS rng.integers(0, n, (h, b1)); draw the same count
        host += np.bincount(host_rng.integers(0, n_i, (3, 8)).ravel(),
                            minlength=n_i)
        del b
    for counts in (dev, host):
        freq = counts / counts.sum()
        # all rows hit, no row wildly over-represented (uniform ±5 σ)
        p = 1.0 / n_i
        sigma = np.sqrt(p * (1 - p) / counts.sum())
        assert np.abs(freq - p).max() < 5 * sigma, np.abs(freq - p).max()


def test_participation_draw_uniform_without_replacement():
    clients, store = _setup()
    n, m = 8, 3
    draws = 600
    keys = jax.random.split(jax.random.key(1), draws)
    idx = np.asarray(jax.vmap(
        lambda k: sim.sample_participants(k, n, m))(keys))
    assert idx.shape == (draws, m)
    for row in idx[:50]:
        assert len(set(row.tolist())) == m      # without replacement
    freq = np.bincount(idx.ravel(), minlength=n) / (draws * m)
    assert np.abs(freq - 1 / n).max() < 0.05    # uniform marginals


def test_build_store_validates_ragged_clients():
    with pytest.raises(ValueError, match="mismatched row counts"):
        sim.build_store([{"x": np.zeros((4, 2)), "y": np.zeros((3,))}])


# ---------------------------------------------------------------------------
# sharded round


@pytest.mark.parametrize("kw", [
    {"batch_directions": True, "direction_conv": "block"},
    {"batch_directions": True, "direction_conv": "block", "aircomp": True,
     "snr_db": 10.0, "channel_schedule": True},
    {"flat_params": True, "flat_block_rows": BR, "aircomp": True,
     "snr_db": 10.0},
])
def test_sharded_round_matches_single_device(kw):
    """shard_map over a 1-device 'clients' mesh == the unsharded round.
    Tight allclose, not bitwise: the psum boundary changes XLA's fusion
    choices by ~1 ulp even though the reduction math is identical."""
    clients, store = _setup()
    cfg = _cfg(**kw)
    p0 = softmax_init(None, 24, 4)
    mesh = sim.make_clients_mesh()
    rf = sim.make_sharded_round(softmax_loss, cfg, mesh)
    batches = sim.sample_batches(store, jnp.arange(4), jax.random.key(7),
                                 cfg.local_iters, cfg.b1)
    rngs = jax.random.split(jax.random.key(1), 4)
    kc = jax.random.key(2)
    ref = jax.jit(lambda p, b, r, c: fedzo.round_simulated(
        softmax_loss, p, b, r, cfg, channel_rng=c))(p0, batches, rngs, kc)
    got = jax.jit(lambda p, b, r, c: rf(
        softmax_loss, p, b, r, cfg, channel_rng=c))(p0, batches, rngs, kc)
    for la, lb in zip(jax.tree.leaves(ref[0]), jax.tree.leaves(got[0])):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-7, rtol=1e-6)
    for k in ref[1]:
        np.testing.assert_allclose(float(ref[1][k]), float(got[1][k]),
                                   rtol=1e-6)


def test_sharded_round_rejects_pytree_cfg():
    mesh = sim.make_clients_mesh()
    with pytest.raises(ValueError, match="flat"):
        sim.make_sharded_round(softmax_loss, _cfg(), mesh)


def test_sharded_round_inside_engine():
    """round_fn plugs into the scan engine: a sharded experiment runs as
    one jit and matches the unsharded engine on a 1-device mesh."""
    clients, store = _setup()
    cfg = _cfg(batch_directions=True, direction_conv="block")
    p0 = softmax_init(None, 24, 4)
    mesh = sim.make_clients_mesh()
    rf = sim.make_sharded_round(softmax_loss, cfg, mesh)
    res_s = sim.run_experiment(softmax_loss, p0, store, cfg, 3, round_fn=rf,
                               donate=False)
    res_u = sim.run_experiment(softmax_loss, p0, store, cfg, 3,
                               donate=False)
    np.testing.assert_allclose(np.asarray(res_s.params["w"]),
                               np.asarray(res_u.params["w"]),
                               atol=1e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# wide (batched-direction) estimator


def test_wide_phase_walks_loop_directions():
    """direction_conv="tree" makes the wide phase regenerate the loop
    estimator's exact direction bits, so one round agrees to the fp32
    reassociation of the batched forwards (amplified by d/μ)."""
    cfg_loop = _cfg(b2=6)
    cfg_wide = dataclasses.replace(cfg_loop, batch_directions=True)
    params = {"x": jnp.zeros((300,))}

    def quad(p, batch):
        return 0.5 * jnp.sum((p["x"] - batch["t"]) ** 2)

    batches = {"t": jnp.ones((4, 2, 300))}
    rngs = jax.random.split(jax.random.key(0), 4)
    p_l, m_l = fedzo.round_simulated(quad, params, batches, rngs, cfg_loop)
    p_w, m_w = fedzo.round_simulated(quad, params, batches, rngs, cfg_wide)
    np.testing.assert_allclose(float(m_w["mean_local_loss"]),
                               float(m_l["mean_local_loss"]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p_w["x"]), np.asarray(p_l["x"]),
                               atol=1e-2, rtol=1e-3)


def test_wide_block_conv_descends():
    """The block convention (one PRNG call per iterate) is statistically
    sound: same quadratic descent as the loop estimator."""
    cfg = _cfg(batch_directions=True, direction_conv="block", b2=16,
               local_iters=3, lr=0.05)
    params = {"x": jnp.zeros((64,))}

    def quad(p, batch):
        return 0.5 * jnp.sum((p["x"] - batch["t"]) ** 2)

    res = fedzo.local_phase(quad, params, {"t": jnp.ones((3, 64))},
                            jax.random.key(0), cfg)
    assert float(res.losses[-1]) < float(res.losses[0])
    assert res.coeffs.shape == (3, 16)


# ---------------------------------------------------------------------------
# satellites: stacked seed compression, FedServer validation


def test_compress_stacked_matches_message_list():
    cfg = _cfg(local_iters=3, b2=5)
    coeffs = jnp.arange(2 * 3 * 5, dtype=jnp.float32).reshape(2, 3, 5)
    rngs = jax.random.split(jax.random.key(4), 2)
    stacked = seedcomm.compress_stacked(rngs, coeffs, cfg)
    singles = [seedcomm.compress(rngs[i], coeffs[i], cfg) for i in range(2)]
    np.testing.assert_array_equal(
        np.asarray(stacked["key"]),
        np.stack([np.asarray(m["key"]) for m in singles]))
    assert seedcomm.wire_bytes(stacked) == sum(
        seedcomm.wire_bytes(m) for m in singles)
    params = {"x": jnp.zeros((40,))}
    _assert_trees_bitequal(seedcomm.aggregate(stacked, params, cfg),
                           seedcomm.aggregate(singles, params, cfg))


def test_seed_compressed_round_has_no_python_message_loop():
    """Behavior pin for the batched compress: same results as before on a
    2-client round, wire bytes still dtype-exact."""
    cfg = _cfg(local_iters=2, b2=3)
    params = {"x": jnp.zeros((24,))}

    def quad(p, batch):
        return 0.5 * jnp.sum((p["x"] - batch["t"]) ** 2)

    batches = [{"t": jnp.ones((2, 24))} for _ in range(2)]
    rngs = list(jax.random.split(jax.random.key(0), 2))
    newp, wire, dense = run_seed_compressed_round(quad, params, batches,
                                                  rngs, cfg)
    assert wire == 2 * (8 + 2 * 3 * 4 + 4)
    assert dense == 2 * 24 * 4
    assert float(jnp.linalg.norm(newp["x"] - params["x"])) > 0


def test_seedcomm_rejects_engine_only_streams():
    """The engine's fast execution plan (block directions, rbg keys) is not
    wire-compatible with seed compression — both incompatibilities must
    fail loudly at the boundary, not replay uncorrelated directions or
    shape-error deep inside the scan."""
    cfg = sim.fast_sim_config(_cfg(local_iters=2, b2=3))
    coeffs = jnp.zeros((2, 3), jnp.float32)
    with pytest.raises(ValueError, match="8-byte threefry key"):
        seedcomm.compress(jax.random.key(0, impl=cfg.prng_impl), coeffs, cfg)
    msg = seedcomm.compress(jax.random.key(0), coeffs,
                            dataclasses.replace(cfg, prng_impl="threefry2x32"))
    with pytest.raises(ValueError, match="not seed-replayable"):
        seedcomm.reconstruct_delta(msg, {"x": jnp.zeros((8,))}, cfg)
    with pytest.raises(ValueError, match="not seed-replayable"):
        seedcomm.aggregate([msg], {"x": jnp.zeros((8,))}, cfg)


def test_sharded_round_rejects_foreign_cfg():
    clients, store = _setup()
    cfg = _cfg(batch_directions=True, direction_conv="block")
    rf = sim.make_sharded_round(softmax_loss, cfg, sim.make_clients_mesh())
    batches = sim.sample_batches(store, jnp.arange(4), jax.random.key(7),
                                 cfg.local_iters, cfg.b1)
    rngs = jax.random.split(jax.random.key(1), 4)
    with pytest.raises(ValueError, match="binds loss_fn and cfg"):
        rf(softmax_loss, softmax_init(None, 24, 4), batches, rngs,
           dataclasses.replace(cfg, snr_db=-3.0))


def test_fedserver_validates_federation_size():
    clients, _ = _setup(n_clients=8)
    with pytest.raises(ValueError, match="n_devices=12 but 8"):
        FedServer(softmax_loss, softmax_init(None, 24, 4), clients,
                  _cfg(n_devices=12))
    with pytest.raises(ValueError, match="n_participating=9 exceeds"):
        FedServer(softmax_loss, softmax_init(None, 24, 4), clients,
                  _cfg(n_participating=9))
    with pytest.raises(ValueError, match="client datasets"):
        FedServer(softmax_loss, softmax_init(None, 24, 4), None, _cfg())


# ---------------------------------------------------------------------------
# sweeps


def test_sweep_groups_static_shapes_and_vmaps_dynamics(tmp_path):
    """A {H} × {snr_db, seed} grid: two compiles (one per H), the snr/seed
    axis vmapped; per-scenario curves come back finite and the CSV lands."""
    clients, store = _setup()
    base = sim.fast_sim_config(_cfg(aircomp=True))
    scen = sim.scenario_grid(local_iters=(1, 2), snr_db=(0.0, 10.0),
                             seed=(0, 1))
    out = tmp_path / "sweep.csv"
    recs = sim.run_sweep(softmax_loss, softmax_init(None, 24, 4), store,
                         base, scen, 3, out_csv=str(out))
    assert len(recs) == 8
    for r in recs:
        assert r["metrics"]["mean_local_loss"].shape == (3,)
        assert np.isfinite(r["metrics"]["mean_local_loss"]).all()
    text = out.read_text().splitlines()
    assert text[0] == "scenario,round,metric,value"
    # every scenario × round × metric row present
    n_metrics = len(recs[0]["metrics"])
    assert len(text) == 1 + 8 * 3 * n_metrics


def test_sweep_split_normalizes_list_valued_statics():
    """A list-valued static override (e.g. a shape) must still produce a
    hashable static signature — the signature is the compile-group dict
    key. Regression: pre-fix this raised an opaque ``TypeError:
    unhashable type: 'list'`` from the group dict."""
    from repro.sim.sweep import _split
    static, dyn = _split({"local_iters": 2, "snr_db": 0.0,
                          "image_shape": [8, 8, 1]})
    assert dyn == {"snr_db": 0.0}
    groups = {}
    groups.setdefault(static, []).append("scenario")  # pre-fix: TypeError
    assert ("image_shape", (8, 8, 1)) in static
    assert ("local_iters", 2) in static
    # non-sequence unhashables get a targeted error naming the field
    with pytest.raises(TypeError, match="image_shape"):
        _split({"image_shape": {"h": 8}})


def test_sweep_scenarios_differ_by_snr():
    """The vmapped config axis really reaches the channel: high-noise and
    low-noise scenarios report different aircomp noise."""
    clients, store = _setup()
    base = sim.fast_sim_config(_cfg(aircomp=True))
    recs = sim.run_sweep(softmax_loss, softmax_init(None, 24, 4), store,
                         base, [{"snr_db": -10.0}, {"snr_db": 20.0}], 2)
    lo = recs[0]["metrics"]["aircomp_noise_std"].mean()
    hi = recs[1]["metrics"]["aircomp_noise_std"].mean()
    assert lo > hi > 0
