"""Tiered ClientStore: host-resident populations behind a cohort stream
(DESIGN.md §15).

The central acceptance proof: a ``HostStore`` run is BITWISE identical to
the device-resident ``ClientStore`` run on the same config —

- the ``CohortStream`` host replay stays in lockstep with the engine's
  carried key chain (and the fault chain) round for round;
- the equivalence matrix covers plain / size-weighted / flat-AirComp /
  fault-injected / SCAFFOLD / FedDyn runs;
- chunked streaming (any ``stream_segment``), checkpointing, and
  SIGKILL-and-resume land on the same bits — and resident snapshots resume
  on the tiered runner (same npz leaf layout), so the tiers interchange
  mid-run;
- two committed golden fixtures re-run on the tiered path byte-for-byte.

Plus the satellites: ``build_store`` stages each leaf through ONE
``jax.device_put`` of ONE preallocated buffer (exact pad bytes pinned),
bucketing partition invariants and sampling-unchanged-by-bucketing as
hypothesis properties, and the staged-bytes/bucket-id history columns.
"""
import importlib.util
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import hypothesis, st

from repro import sim
from repro.configs.base import FedZOConfig
from repro.data.synthetic import make_classification
from repro.models.simple import softmax_init, softmax_loss
from repro.sim.store import stack_padded
from repro.sim.tiered import CohortStream, bucket_caps

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=10,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")

_REGEN = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden",
                      "regen.py")
_spec = importlib.util.spec_from_file_location("golden_regen_tiered", _REGEN)
golden_regen = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(golden_regen)


def _ragged_clients(n_clients=16, lo=10, hi=60, seed=0):
    """Deliberately uneven client sizes so bucketing is non-trivial."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(lo, hi, size=n_clients)
    x, y = make_classification(int(sizes.sum()), 24, 4, seed=seed)
    clients, off = [], 0
    for s in sizes:
        clients.append({"x": x[off:off + s], "y": y[off:off + s]})
        off += s
    return clients


def _cfg(**kw):
    base = dict(n_devices=16, n_participating=5, local_iters=2, lr=1e-2,
                mu=1e-3, b1=8, b2=4, seed=3)
    base.update(kw)
    return FedZOConfig(**base)


def _eval_fn():
    x, y = make_classification(64, 24, 4, seed=9)
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    def ev(params):
        from repro.models.simple import softmax_accuracy
        return {"acc": softmax_accuracy(params, batch)}

    return ev


def _assert_trees_bitequal(a, b):
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_results_bitequal(a, b):
    _assert_trees_bitequal(a.params, b.params)
    np.testing.assert_array_equal(jax.random.key_data(a.key),
                                  jax.random.key_data(b.key))
    assert sorted(a.metrics) == sorted(b.metrics)
    for k in a.metrics:
        np.testing.assert_array_equal(np.asarray(a.metrics[k]),
                                      np.asarray(b.metrics[k]), err_msg=k)
    for k in a.evals:
        np.testing.assert_array_equal(np.asarray(a.evals[k]),
                                      np.asarray(b.evals[k]), err_msg=k)
    if a.fault_state is not None or b.fault_state is not None:
        np.testing.assert_array_equal(np.asarray(a.fault_state),
                                      np.asarray(b.fault_state))
    if a.strategy_state is not None or b.strategy_state is not None:
        _assert_trees_bitequal(a.strategy_state, b.strategy_state)


# ---------------------------------------------------------------------------
# the host key-chain replay stays in lockstep with the engine carry


@pytest.mark.parametrize("faults", [None, sim.FaultModel(p_fail=0.3,
                                                         p_recover=0.5)])
def test_stream_replays_engine_key_chain(faults):
    """After R rounds the CohortStream's key (and fault chain) must equal
    the compiled engine's carried key (and fault state) BITWISE — the
    property that lets staging run arbitrarily far ahead of the device."""
    clients = _ragged_clients()
    store = sim.build_store(clients)
    cfg = _cfg()
    p0 = softmax_init(None, 24, 4)
    res = sim.run_experiment(softmax_loss, p0, store, cfg, 5, faults=faults,
                             donate=False)

    host = sim.build_host_store(clients, n_buckets=3)
    stream = CohortStream(
        host, cfg, sim.experiment_key(cfg), faults=faults,
        fstate=faults.init_state(len(clients)) if faults else None)
    idx, avail, chan_h, chan_mask = stream.plan(5)
    assert idx.shape == (5, cfg.n_participating)
    assert chan_h is None and chan_mask is None
    np.testing.assert_array_equal(jax.random.key_data(stream.key),
                                  jax.random.key_data(res.key))
    if faults is not None:
        assert avail.shape == (5, cfg.n_participating)
        np.testing.assert_array_equal(np.asarray(stream.fstate),
                                      np.asarray(res.fault_state))
    # each round's cohort is the engine's own permutation-prefix draw
    key = sim.experiment_key(cfg)
    for t in range(5):
        n_keys = 6 if faults is not None else 5
        ks = jax.random.split(key, n_keys)
        key = ks[0]
        want = sim.sample_participants(ks[1], len(clients),
                                       cfg.n_participating)
        np.testing.assert_array_equal(idx[t], np.asarray(want))


@pytest.mark.parametrize("faults", [None, sim.FaultModel(p_fail=0.3,
                                                         p_recover=0.5)])
def test_stream_replays_channel_chain(faults):
    """With a ``ChannelModel`` attached the stream's host-replayed fading
    chain, battery ledger, and per-round cohort channel stay BITWISE in
    lockstep with the engine carry — the channel key stream widens the
    round split without perturbing the participation/batch draws."""
    from repro.sim import channel as channel_lib

    clients = _ragged_clients()
    store = sim.build_store(clients)
    cm = sim.ChannelModel(rho=0.8, battery=3.0, tx_cost=1.0)
    cfg = _cfg(channel_model=cm, channel_schedule=True, h_min=0.3)
    p0 = softmax_init(None, 24, 4)
    res = sim.run_experiment(softmax_loss, p0, store, cfg, 5, faults=faults,
                             donate=False)

    host = sim.build_host_store(clients, n_buckets=3)
    key = sim.experiment_key(cfg)
    stream = CohortStream(
        host, cfg, key, faults=faults,
        fstate=faults.init_state(len(clients)) if faults else None,
        cstate=cm.init_state(len(clients), channel_lib.init_key(key)))
    idx, avail, chan_h, chan_mask = stream.plan(5)
    assert chan_h.shape == (5, cfg.n_participating)
    assert chan_mask.shape == (5, cfg.n_participating)
    np.testing.assert_array_equal(jax.random.key_data(stream.key),
                                  jax.random.key_data(res.key))
    for a, b in zip(jax.tree.leaves(stream.cstate),
                    jax.tree.leaves(res.channel_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    if faults is not None:
        np.testing.assert_array_equal(np.asarray(stream.fstate),
                                      np.asarray(res.fault_state))


# ---------------------------------------------------------------------------
# the equivalence matrix: tiered ≡ resident, bitwise


MATRIX = [
    ("plain", {}, None, None),
    ("weighted", {"weight_by_size": True}, None, None),
    ("flat_aircomp", {"flat_params": True, "flat_block_rows": 4,
                      "aircomp": True, "snr_db": 5.0,
                      "channel_schedule": True}, None, None),
    ("faults", {}, sim.FaultModel(p_fail=0.25, p_recover=0.5, deadline=2.0,
                                  p_corrupt=0.1), None),
    ("scaffold", {"strategy": "scaffold"}, None, None),
    ("feddyn", {"strategy": "feddyn", "dyn_alpha": 0.01}, None, None),
]


@pytest.mark.parametrize("name,kw,faults,strategy",
                         MATRIX, ids=[m[0] for m in MATRIX])
def test_tiered_matches_resident_bitwise(name, kw, faults, strategy):
    clients = _ragged_clients()
    cfg = _cfg(**kw)
    p0 = softmax_init(None, 24, 4)
    ev = _eval_fn()
    res = sim.run_experiment(softmax_loss, p0, sim.build_store(clients),
                             cfg, 5, faults=faults, strategy=strategy,
                             eval_fn=ev, eval_every=2, donate=False)
    host = sim.build_host_store(clients, n_buckets=3)
    assert host.n_buckets > 1, "ragged fixture should exercise >1 bucket"
    tier = sim.run_experiment(softmax_loss, p0, host, cfg, 5, faults=faults,
                              strategy=strategy, eval_fn=ev, eval_every=2,
                              donate=False)
    _assert_results_bitequal(res, tier)
    assert tier.prefetch is not None and tier.staging is not None


def test_tiered_chunked_matches_single_shot(tmp_path):
    """Any stream_segment (and prefetch on/off, and checkpoint chunking)
    lands on the single-shot bits — the PR 6 segment-invariance contract
    carried over to the streamed path."""
    clients = _ragged_clients()
    cfg = _cfg()
    p0 = softmax_init(None, 24, 4)
    host = sim.build_host_store(clients, n_buckets=3)
    ev = _eval_fn()
    one = sim.run_tiered_experiment(softmax_loss, p0, host, cfg, 7,
                                    eval_fn=ev, eval_every=3, donate=False,
                                    stream_segment=7)
    for seg, pf in [(1, True), (3, False), (2, True)]:
        got = sim.run_tiered_experiment(softmax_loss, p0, host, cfg, 7,
                                        eval_fn=ev, eval_every=3,
                                        donate=False, stream_segment=seg,
                                        prefetch=pf)
        _assert_results_bitequal(one, got)
    ck = sim.run_experiment(softmax_loss, p0, host, cfg, 7, eval_fn=ev,
                            eval_every=3, checkpoint_every=3,
                            checkpoint_dir=str(tmp_path / "ck"))
    _assert_results_bitequal(one, ck)
    assert ck.manifest["tiered"]["n_buckets"] == host.n_buckets


@pytest.mark.parametrize("kw,faults", [
    ({}, sim.FaultModel(p_fail=0.25, p_recover=0.5)),
    ({"strategy": "scaffold"}, None),
], ids=["faults", "scaffold"])
def test_tiered_kill_and_resume_bitexact(kw, faults, tmp_path):
    """Kill after one checkpoint segment (the host [N] halves survive only
    inside the snapshot), resume in a FRESH call, land on the single-shot
    bits — fault chain and stateful client masters included."""
    clients = _ragged_clients()
    cfg = _cfg(**kw)
    p0 = softmax_init(None, 24, 4)
    host = sim.build_host_store(clients, n_buckets=3)
    single = sim.run_experiment(softmax_loss, p0, host, cfg, 6,
                                faults=faults, donate=False)
    d = str(tmp_path / "ck")
    part = sim.run_experiment(softmax_loss, p0, host, cfg, 6, faults=faults,
                              checkpoint_every=2, checkpoint_dir=d,
                              max_segments=1)
    assert part.rounds == 2
    resumed = sim.run_experiment(softmax_loss, p0, host, cfg, 6,
                                 faults=faults, checkpoint_every=2,
                                 checkpoint_dir=d, resume=True)
    assert resumed.rounds == 6
    _assert_results_bitequal(single, resumed)


def test_resident_snapshot_resumes_on_tiered_runner(tmp_path):
    """Snapshot-layout interchange: a RESIDENT run's checkpoint resumes on
    the tiered runner and still lands on the resident single-shot bits."""
    clients = _ragged_clients()
    cfg = _cfg()
    p0 = softmax_init(None, 24, 4)
    store = sim.build_store(clients)
    single = sim.run_experiment(softmax_loss, p0, store, cfg, 6,
                                donate=False)
    d = str(tmp_path / "ck")
    sim.run_experiment(softmax_loss, p0, store, cfg, 6, checkpoint_every=2,
                       checkpoint_dir=d, max_segments=1)
    host = sim.build_host_store(clients, n_buckets=3)
    resumed = sim.run_experiment(softmax_loss, p0, host, cfg, 6,
                                 checkpoint_every=2, checkpoint_dir=d,
                                 resume=True)
    _assert_results_bitequal(single, resumed)


# ---------------------------------------------------------------------------
# tiered runs vs the committed golden fixtures


@pytest.mark.parametrize("name", ["softmax_counter", "softmax_scaffold"])
def test_tiered_matches_golden_fixture(name):
    from repro.workloads import neural

    path = golden_regen.fixture_path(name)
    with open(path) as f:
        want = json.load(f)
    spec = golden_regen.GOLDEN[name]
    task_kw = dict(spec["task"])
    task = neural.make_task(task_kw.pop("name"), **task_kw)
    cfg = neural.default_config(task, **spec["cfg"])
    host = sim.build_host_store(task.clients, n_buckets=3)
    res = sim.run_experiment(
        task.loss, neural.params_init(task, cfg.seed), host, cfg,
        spec["rounds"],
        eval_fn=neural.task_eval(task, spec["task"]["n_test"]),
        eval_every=2, donate=False)
    mets = jax.device_get(res.metrics)
    for k, hexes in want["metrics"].items():
        assert golden_regen._hex32(mets[k]) == hexes, (name, k)
    evals = jax.device_get(res.evals)
    for k, hexes in want["evals"].items():
        assert golden_regen._hex32(evals[k]) == hexes, (name, k)
    buf = np.concatenate([np.asarray(l, np.float32).ravel()
                          for l in jax.tree.leaves(res.params)])
    assert buf.tobytes().hex() == want["final_params_hex"], name


# ---------------------------------------------------------------------------
# satellite: build_store peak memory — one device_put of one buffer per leaf


def test_build_store_single_device_put_per_leaf(monkeypatch):
    clients = _ragged_clients(n_clients=6)
    sizes = [c["y"].shape[0] for c in clients]
    cap = max(sizes)
    puts = []
    real_put = jax.device_put

    def counting_put(x, *a, **kw):
        puts.append(x)
        return real_put(x, *a, **kw)

    import repro.sim.store as store_mod
    monkeypatch.setattr(store_mod.jax, "device_put", counting_put)
    store = store_mod.build_store(clients)
    # exactly ONE host->device transfer per leaf, each already the full
    # preallocated padded buffer (no transient per-client copies crossing)
    assert len(puts) == len(jax.tree.leaves(clients[0]))
    for buf in puts:
        assert isinstance(buf, np.ndarray)
        assert buf.shape[:2] == (len(clients), cap)
    # exact padded geometry: leaf bytes = N * cap * row_bytes
    x_rows = clients[0]["x"].shape[1]
    x_leaf = jax.tree.leaves({"x": store.data["x"]})[0]
    assert x_leaf.nbytes == len(clients) * cap * x_rows * 4


def test_stack_padded_zero_pad_region():
    clients = _ragged_clients(n_clients=5)
    leaves = [c["x"] for c in clients]
    cap = max(l.shape[0] for l in leaves) + 3
    out = stack_padded(leaves, cap)
    assert out.shape == (5, cap, leaves[0].shape[1])
    for i, l in enumerate(leaves):
        np.testing.assert_array_equal(out[i, :l.shape[0]], l)
        assert not out[i, l.shape[0]:].any()


# ---------------------------------------------------------------------------
# satellite: bucketing properties (hypothesis via the tests/_hyp shim)


@hypothesis.given(st.integers(2, 40), st.integers(1, 6), st.integers(0, 50))
def test_bucketing_partitions_population(n_clients, n_buckets, seed):
    """Every client lands in exactly one bucket, keeps its rows exactly
    once (bit-identical, in order), and fits its bucket's capacity."""
    clients = _ragged_clients(n_clients=n_clients, lo=3, hi=30, seed=seed)
    host = sim.build_host_store(clients, n_buckets=n_buckets)
    caps = [b.cap for b in host.buckets]
    assert caps == sorted(set(caps)), "caps ascending, deduplicated"
    all_ids = np.concatenate([b.ids for b in host.buckets])
    np.testing.assert_array_equal(np.sort(all_ids), np.arange(n_clients))
    for i, c in enumerate(clients):
        b = host.buckets[int(host.bucket_of[i])]
        assert host.sizes[i] <= b.cap
        _assert_trees_bitequal(host.client(i), c)
    # caps come from the size quantiles and always cover the max
    assert caps[-1] == int(host.sizes.max())
    assert set(caps) == set(bucket_caps(host.sizes, n_buckets))


@hypothesis.given(st.integers(1, 5), st.integers(0, 40))
def test_bucket_boundaries_never_change_sampling(n_buckets, seed):
    """The minibatch rows drawn from a bucket-padded staged cohort are
    BITWISE the rows the resident store draws on the same key — for any
    bucket count. (The randint bound is the true client size, so pad
    geometry is unreachable either way.)"""
    clients = _ragged_clients(n_clients=10, lo=4, hi=40, seed=seed)
    store = sim.build_store(clients)
    host = sim.build_host_store(clients, n_buckets=n_buckets)
    key = jax.random.key(seed)
    k_part, k_batch = jax.random.split(key)
    idx = sim.sample_participants(k_part, 10, 4)
    want = sim.sample_batches(store, idx, k_batch, h=3, b1=4)
    data, sizes, _meta = host.stage(np.asarray(idx)[None, :])
    got = sim.sample_cohort_batches(
        jax.tree.map(lambda l: jnp.asarray(l[0]), data),
        jnp.asarray(sizes[0]), k_batch, 3, 4)
    _assert_trees_bitequal(want, got)


# ---------------------------------------------------------------------------
# satellite: staged-bytes / bucket-id history columns


def test_history_rows_carry_staging_columns(tmp_path):
    clients = _ragged_clients()
    cfg = _cfg()
    p0 = softmax_init(None, 24, 4)
    host = sim.build_host_store(clients, n_buckets=3)
    tier = sim.run_experiment(softmax_loss, p0, host, cfg, 4, donate=False)
    rows = [r for r in tier.history() if "mean_local_loss" in r]
    assert len(rows) == 4
    for r in rows:
        assert r["staged_bytes"] > 0
        assert 0 <= r["bucket_id"] < host.n_buckets
        assert "wire_bytes" in r      # the PR 8 ledger columns still ride
    res = sim.run_experiment(softmax_loss, p0, sim.build_store(clients),
                             cfg, 4, donate=False)
    for r in res.history():           # resident rows: contract unchanged
        assert "staged_bytes" not in r and "bucket_id" not in r


# ---------------------------------------------------------------------------
# store mechanics: durability, tier seam, stream clamping


def test_hoststore_save_load_mmap_roundtrip(tmp_path):
    clients = _ragged_clients()
    host = sim.build_host_store(clients, n_buckets=3)
    d = host.save(str(tmp_path / "pop"))
    back = sim.HostStore.load(d, mmap=True)
    assert back.n_buckets == host.n_buckets
    assert all(isinstance(l, np.memmap)
               for b in back.buckets for l in jax.tree.leaves(b.data))
    for i in range(len(clients)):
        _assert_trees_bitequal(back.client(i), clients[i])
    # a staged cohort off the mmap matches the in-RAM stage bitwise
    idx = np.asarray([[0, 3, 7], [2, 2, 9]])
    _assert_trees_bitequal(host.stage(idx)[0], back.stage(idx)[0])


def test_resolve_store_seam():
    clients = _ragged_clients(n_clients=6)
    store = sim.build_store(clients)
    host = sim.build_host_store(clients, n_buckets=2)
    assert sim.resolve_store(store) is store
    assert sim.resolve_store(host, tier="auto") is host
    res = sim.resolve_store(host, tier="resident")
    assert isinstance(res, sim.ClientStore)
    _assert_trees_bitequal(res.data, store.data)
    np.testing.assert_array_equal(np.asarray(res.sizes),
                                  np.asarray(store.sizes))
    assert isinstance(sim.resolve_store(clients, tier="host"),
                      sim.HostStore)
    with pytest.raises(TypeError):
        sim.resolve_store({"not": "a store"})


def test_stateful_strategy_forces_segment_one():
    """SCAFFOLD's [N] client master is read-modify-write between rounds,
    so the stream must clamp to one-round segments regardless of the
    requested stream_segment."""
    clients = _ragged_clients()
    cfg = _cfg(strategy="scaffold")
    p0 = softmax_init(None, 24, 4)
    host = sim.build_host_store(clients, n_buckets=2)
    tier = sim.run_tiered_experiment(softmax_loss, p0, host, cfg, 3,
                                     donate=False, stream_segment=8)
    assert tier.prefetch["stream_segment"] == 1
    assert tier.prefetch["staged_bytes"] > 0


def test_cohort_batch_avail_is_not_a_leaf_when_absent():
    """avail=None must vanish from the pytree so fault-free cohort jits
    keep the two-leaf signature (no retrace against CohortBatch)."""
    cb = sim.CohortBatch(data={"x": jnp.zeros((2, 3))},
                         sizes=jnp.ones((2,), jnp.int32))
    assert len(jax.tree.leaves(cb)) == 2
    cb_f = sim.CohortBatch(data={"x": jnp.zeros((2, 3))},
                           sizes=jnp.ones((2,), jnp.int32),
                           avail=jnp.ones((2,), bool))
    assert len(jax.tree.leaves(cb_f)) == 3
