"""Fault injection + graceful degradation (DESIGN.md §12).

Pins the fault layer's contracts:
- host loop ≡ engine stays BITWISE under faults on every aggregation path
  (the fault draws hang off the shared carried key chain);
- the Gilbert–Elliott availability chain hits its stationary distribution
  (hypothesis property test);
- one all-NaN client leaves the aggregate finite and bit-equal to the same
  round with that client channel-masked (the finite-guard regression);
- an all-faulted round degenerates to a zero update with m_effective == 0;
- guard OFF propagates the poison (the failure mode the guard exists for);
- divergence rollback: FedServer and the checkpointed engine roll a
  non-finite round back with lr backoff, emit structured rollback rows,
  and raise ``DivergenceError`` when retries are exhausted.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import hypothesis, st
from repro import sim
from repro.configs.base import FedZOConfig
from repro.core import fedzo
from repro.data.synthetic import make_classification, noniid_shards
from repro.fed.server import FedServer
from repro.models.simple import softmax_init, softmax_loss
from repro.sim.faults import DivergenceError, FaultModel, RoundFaults

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=10,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")

BR = 4  # small kernel blocks for CPU interpret mode

FAULTS = FaultModel(p_fail=0.3, p_recover=0.5, deadline=1.5,
                    straggler_mean=1.0, p_corrupt=0.3, corrupt_mode="nan")


def _setup(n=640, n_clients=8, n_features=24, n_classes=4, seed=0):
    x, y = make_classification(n, n_features, n_classes, seed=seed)
    clients = noniid_shards(x, y, n_clients)
    return clients, sim.build_store(clients)


def _cfg(**kw):
    base = dict(n_devices=8, n_participating=4, local_iters=2, lr=1e-2,
                mu=1e-3, b1=8, b2=4, seed=3)
    base.update(kw)
    return FedZOConfig(**base)


def _assert_trees_bitequal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# host ≡ engine bitwise, under faults, on every aggregation path


@pytest.mark.parametrize("name,kw,algo", [
    ("plain", {}, "fedzo"),
    ("momentum", {"server_momentum": 0.9}, "fedzo"),
    ("aircomp_sched", {"aircomp": True, "snr_db": 10.0,
                       "channel_schedule": True}, "fedzo"),
    ("flat", {"flat_params": True, "flat_block_rows": BR}, "fedzo"),
    ("wide_weighted", {"batch_directions": True, "direction_conv": "block",
                       "prng_impl": "unsafe_rbg",
                       "weight_by_size": True}, "fedzo"),
    ("fedavg_sched", {"channel_schedule": True}, "fedavg"),
])
def test_engine_bitmatches_host_rounds_with_faults(name, kw, algo):
    """The ISSUE acceptance matrix: with dropout + stragglers + corrupted
    uploads enabled, R scanned rounds == R host-driven rounds bit for bit,
    every aggregation path stays finite, and m_effective reports the
    surviving cohort."""
    clients, store = _setup()
    cfg = _cfg(**kw)
    p0 = softmax_init(None, 24, 4)
    host = FedServer(softmax_loss, p0, clients, cfg, algo=algo, store=store,
                     faults=FAULTS)
    for t in range(3):
        host.run_round(t)
    scanned = FedServer(softmax_loss, p0, clients, cfg, algo=algo,
                        store=store, faults=FAULTS)
    scanned.run(3)
    _assert_trees_bitequal(host.params, scanned.params)
    for leaf in jax.tree.leaves(host.params):
        assert np.all(np.isfinite(np.asarray(leaf)))
    for hm, sm in zip(host.history, scanned.history):
        assert hm["mean_local_loss"] == sm["mean_local_loss"], (hm, sm)
        if algo == "fedzo":
            assert 0.0 <= hm["m_effective"] <= cfg.n_participating
            assert hm["m_corrupt"] == sm["m_corrupt"]


def test_faultfree_model_matches_huge_deadline():
    """The straggler deadline only changes the trajectory through the mask:
    an unreachable deadline is bit-identical to no straggler process (the
    latency draws ride a dead-end key split)."""
    _, store = _setup()
    cfg = _cfg()
    p0 = softmax_init(None, 24, 4)
    off = sim.run_experiment(softmax_loss, p0, store, cfg, 3,
                             faults=FaultModel(), donate=False)
    huge = sim.run_experiment(
        softmax_loss, p0, store, cfg, 3, donate=False,
        faults=FaultModel(deadline=1e9, straggler_mean=1.0))
    _assert_trees_bitequal(off.params, huge.params)


def test_tight_deadline_freezes_model():
    """deadline → 0 masks every sampled client: each round degenerates to
    the zero update and m_effective == 0 throughout."""
    _, store = _setup()
    cfg = _cfg()
    p0 = softmax_init(None, 24, 4)
    res = sim.run_experiment(
        softmax_loss, p0, store, cfg, 3, donate=False,
        faults=FaultModel(deadline=1e-12, straggler_mean=1.0))
    _assert_trees_bitequal(res.params, p0)
    np.testing.assert_array_equal(np.asarray(res.metrics["m_effective"]),
                                  np.zeros(3, np.float32))


# ---------------------------------------------------------------------------
# Gilbert–Elliott availability chain


@hypothesis.given(st.floats(0.05, 0.95), st.floats(0.05, 0.95))
def test_gilbert_elliott_hits_stationary_distribution(p_fail, p_recover):
    """Long-run availability of the up/down chain converges to
    π_up = p_recover / (p_fail + p_recover)."""
    model = FaultModel(p_fail=p_fail, p_recover=p_recover)
    n, t = 1000, 200

    @jax.jit
    def up_fracs():
        idx = jnp.arange(1)

        def body(state, k):
            state, _ = model.step(k, state, idx)
            return state, jnp.mean(state.astype(jnp.float32))

        keys = jax.random.split(jax.random.key(7), t)
        _, fracs = jax.lax.scan(body, model.init_state(n), keys)
        return fracs

    tail = np.asarray(up_fracs())[t // 2:]
    assert abs(float(tail.mean()) - model.stationary_up) < 0.05


def test_fault_state_lives_in_the_carry():
    """Availability is TIME-CORRELATED: the [N] chain state threads through
    the experiment carry and comes back evolved (not reset per round)."""
    _, store = _setup()
    res = sim.run_experiment(
        softmax_loss, softmax_init(None, 24, 4), store, _cfg(), 20,
        donate=False, faults=FaultModel(p_fail=0.9, p_recover=0.05))
    fstate = np.asarray(res.fault_state)
    assert fstate.shape == (8,) and fstate.dtype == bool
    assert not fstate.all()  # p_fail≫p_recover: some clients are down


# ---------------------------------------------------------------------------
# finite-guard: one poisoned client ≡ that client channel-masked


def _one_round_inputs(cfg, seed=5):
    """Deterministic (params, batches, rngs) for direct round calls."""
    clients, store = _setup()
    key = jax.random.key(seed, impl=cfg.prng_impl)
    k_part, k_batch, k_zo = jax.random.split(key, 3)
    idx = sim.sample_participants(k_part, store.n_clients,
                                  cfg.n_participating)
    batches = sim.sample_batches(store, idx, k_batch, cfg.local_iters,
                                 cfg.b1)
    rngs = jax.random.split(k_zo, cfg.n_participating)
    return softmax_init(None, 24, 4), batches, rngs


@pytest.mark.parametrize("name,kw", [
    ("pytree", {}),
    ("flat", {"flat_params": True, "flat_block_rows": BR}),
    ("aircomp", {"flat_params": True, "flat_block_rows": BR,
                 "aircomp": True, "snr_db": 10.0}),
])
def test_nan_client_bitequal_to_masked_client(name, kw):
    """One all-NaN upload, guard ON: the aggregate is finite and BIT-EQUAL
    to the same round with that client channel-masked — the scrub zeroes
    the poisoned row before it can touch the masked mean / Δ_max."""
    cfg = _cfg(**kw)
    params, batches, rngs = _one_round_inputs(cfg)
    M = cfg.n_participating
    poisoned = jnp.zeros((M,), bool).at[1].set(True)
    chan = jax.random.key(9, impl=cfg.prng_impl) if cfg.aircomp else None

    model = FaultModel(p_corrupt=0.5, corrupt_mode="nan")  # guard ON
    inj_nan = RoundFaults(model=model, mask=jnp.ones((M,), bool),
                          corrupt=poisoned)
    p_nan, m_nan = fedzo.round_simulated(softmax_loss, params, batches, rngs,
                                         cfg, channel_rng=chan,
                                         faults=inj_nan)
    inj_masked = RoundFaults(model=FaultModel(), mask=~poisoned,
                             corrupt=jnp.zeros((M,), bool))
    p_masked, m_masked = fedzo.round_simulated(softmax_loss, params, batches,
                                               rngs, cfg, channel_rng=chan,
                                               faults=inj_masked)
    for leaf in jax.tree.leaves(p_nan):
        assert np.all(np.isfinite(np.asarray(leaf)))
    _assert_trees_bitequal(p_nan, p_masked)
    assert float(m_nan["m_effective"]) == float(m_masked["m_effective"]) \
        == M - 1
    assert float(m_nan["m_corrupt"]) == 1.0


def test_guard_off_propagates_poison():
    """guard=False is the counterfactual: the same all-NaN upload NaNs the
    global model — the failure mode the finite-guard exists to stop."""
    cfg = _cfg()
    params, batches, rngs = _one_round_inputs(cfg)
    M = cfg.n_participating
    model = FaultModel(p_corrupt=0.5, corrupt_mode="nan", guard=False)
    inj = RoundFaults(model=model, mask=jnp.ones((M,), bool),
                      corrupt=jnp.zeros((M,), bool).at[1].set(True))
    p_bad, _ = fedzo.round_simulated(softmax_loss, params, batches, rngs,
                                     cfg, faults=inj)
    assert any(not np.all(np.isfinite(np.asarray(leaf)))
               for leaf in jax.tree.leaves(p_bad))


def test_guard_norm_masks_exploded_delta():
    """guard_norm masks a finite-but-exploded upload (scale corruption)
    exactly like a non-finite one."""
    cfg = _cfg(flat_params=True, flat_block_rows=BR)
    params, batches, rngs = _one_round_inputs(cfg)
    M = cfg.n_participating
    poisoned = jnp.zeros((M,), bool).at[2].set(True)
    model = FaultModel(p_corrupt=0.5, corrupt_mode="scale",
                       corrupt_scale=1e12, guard_norm=1e3)
    inj = RoundFaults(model=model, mask=jnp.ones((M,), bool),
                      corrupt=poisoned)
    p_new, m = fedzo.round_simulated(softmax_loss, params, batches, rngs,
                                     cfg, faults=inj)
    inj_masked = RoundFaults(model=FaultModel(), mask=~poisoned,
                             corrupt=jnp.zeros((M,), bool))
    p_masked, _ = fedzo.round_simulated(softmax_loss, params, batches, rngs,
                                        cfg, faults=inj_masked)
    _assert_trees_bitequal(p_new, p_masked)
    assert float(m["m_effective"]) == M - 1


def test_all_faulted_round_is_zero_update():
    """Every client down → the clamped divisor degenerates the round to a
    zero update (params bit-unchanged), exactly like the all-masked channel
    round; m_effective reports 0, not 1."""
    for kw in ({}, {"flat_params": True, "flat_block_rows": BR}):
        cfg = _cfg(**kw)
        params, batches, rngs = _one_round_inputs(cfg)
        M = cfg.n_participating
        inj = RoundFaults(model=FaultModel(), mask=jnp.zeros((M,), bool),
                          corrupt=jnp.zeros((M,), bool))
        p_new, m = fedzo.round_simulated(softmax_loss, params, batches,
                                         rngs, cfg, faults=inj)
        _assert_trees_bitequal(p_new, params)
        assert float(m["m_effective"]) == 0.0


# ---------------------------------------------------------------------------
# sharded round under faults


def test_sharded_round_bitmatches_unsharded_under_faults():
    """On a 1-device mesh the fault-aware shard_map body (scrub per shard,
    psum'd divisor) must reproduce the unsharded fault round bit-for-bit."""
    _, store = _setup()
    cfg = sim.fast_sim_config(_cfg(weight_by_size=True))
    p0 = softmax_init(None, 24, 4)
    mesh = sim.make_clients_mesh()
    rf = sim.make_sharded_round(softmax_loss, cfg, mesh)
    res_s = sim.run_experiment(softmax_loss, p0, store, cfg, 3, round_fn=rf,
                               faults=FAULTS, donate=False)
    res_u = sim.run_experiment(softmax_loss, p0, store, cfg, 3,
                               faults=FAULTS, donate=False)
    _assert_trees_bitequal(res_s.params, res_u.params)
    for k in res_u.metrics:
        np.testing.assert_array_equal(np.asarray(res_s.metrics[k]),
                                      np.asarray(res_u.metrics[k]), err_msg=k)


# ---------------------------------------------------------------------------
# divergence guard: rollback with lr backoff, then structured failure


def _explosive_setup():
    """A loss that overflows to inf within one local phase at large lr but
    descends at small lr — the controlled divergence trigger."""
    def loss(p, batch):
        del batch
        return jnp.exp(jnp.sum(jnp.square(p["x"] - 0.1)))

    x, y = make_classification(320, 4, 2, seed=1)
    clients = noniid_shards(x, y, 8)
    store = sim.build_store(clients)
    params = {"x": jnp.zeros((4,), jnp.float32)}
    return loss, params, clients, store


def test_fedserver_divergence_rollback_recovers():
    loss, p0, clients, store = _explosive_setup()
    cfg = _cfg(lr=1e6, local_iters=2)
    srv = FedServer(loss, p0, clients, cfg, store=store,
                    divergence_guard=True, max_retries=3, lr_backoff=1e-8)
    srv.run(3, driver="host")
    rollbacks = [h for h in srv.history if h.get("event") == "rollback"]
    rounds = [h for h in srv.history if "event" not in h]
    assert rollbacks, "the 1e6-lr first round must have diverged"
    assert rollbacks[0]["round"] == 0 and rollbacks[0]["lr"] < 1.0
    # satellite: rollback rows must NOT re-number the successful rounds
    assert [h["round"] for h in rounds] == [0, 1, 2]
    assert all(np.isfinite(h["mean_local_loss"]) for h in rounds)
    for leaf in jax.tree.leaves(srv.params):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_fedserver_divergence_exhaustion_raises():
    loss, p0, clients, store = _explosive_setup()
    cfg = _cfg(lr=1e6, local_iters=2)
    srv = FedServer(loss, p0, clients, cfg, store=store,
                    divergence_guard=True, max_retries=2, lr_backoff=1.0)
    with pytest.raises(DivergenceError) as ei:
        srv.run(3, driver="host")
    assert ei.value.round == 0 and ei.value.retries == 2
    assert sum(1 for h in srv.history
               if h.get("event") == "rollback") == 2


def test_engine_segment_divergence_rollback(tmp_path):
    """The checkpointed engine loop: a diverging segment rolls back to the
    round-0 snapshot with backed-off lr, records the structured event, and
    completes finitely."""
    loss, p0, _, store = _explosive_setup()
    cfg = _cfg(lr=1e6, local_iters=2)
    res = sim.run_experiment(
        loss, p0, store, cfg, 4, checkpoint_every=2,
        checkpoint_dir=str(tmp_path / "ck"), max_retries=3, lr_backoff=1e-8,
        donate=False)
    assert res.rounds == 4
    assert [e["event"] for e in res.events] == ["rollback"]
    assert res.events[0]["lr"] == pytest.approx(1e6 * 1e-8)
    for leaf in jax.tree.leaves(res.params):
        assert np.all(np.isfinite(np.asarray(leaf)))
    hist = sim.history(res)
    assert any(h.get("event") == "rollback" for h in hist)
    assert [h["round"] for h in hist if "event" not in h] == [0, 1, 2, 3]


def test_engine_segment_divergence_exhaustion_raises(tmp_path):
    loss, p0, _, store = _explosive_setup()
    cfg = _cfg(lr=1e6, local_iters=2)
    with pytest.raises(DivergenceError) as ei:
        sim.run_experiment(loss, p0, store, cfg, 4, checkpoint_every=2,
                           checkpoint_dir=str(tmp_path / "ck"),
                           max_retries=2, lr_backoff=1.0, donate=False)
    assert ei.value.retries == 2 and ei.value.round == 2


# ---------------------------------------------------------------------------
# model validation


def test_fault_model_validation():
    with pytest.raises(ValueError, match="corrupt_mode"):
        FaultModel(corrupt_mode="garbage")
    with pytest.raises(ValueError, match="p_fail"):
        FaultModel(p_fail=1.5)
    with pytest.raises(ValueError, match="store"):
        clients, _ = _setup()
        FedServer(softmax_loss, softmax_init(None, 24, 4), clients, _cfg(),
                  faults=FaultModel())
