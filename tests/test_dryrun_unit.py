"""Dry-run machinery unit tests (subprocess: importing launch.dryrun sets
XLA_FLAGS for 512 host devices, which must not leak into this process)."""
import pytest

from tests.conftest import run_subprocess


def test_parse_collectives_trip_weighting():
    run_subprocess("""
from repro.launch.dryrun import parse_collectives, _shape_bytes
hlo = '''
HloModule m

%scan_body (p: f32[8]) -> f32[8] {
  %x = f32[8]{0} parameter(0)
  %ar = f32[8]{0} all-reduce(%x), replica_groups={}
  ROOT %r = f32[8]{0} add(%ar, %ar)
}

%cond (p: f32[8]) -> pred[] {
  %p = f32[8]{0} parameter(0)
  ROOT %c = pred[] constant(true)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  %ag = f32[16]{0} all-gather(%a), dimensions={0}
  %w = f32[8]{0} while(%a), condition=%cond, body=%scan_body, backend_config={"known_trip_count":{"n":"24"}}
  ROOT %out = f32[8]{0} add(%w, %a)
}
'''
b, n = parse_collectives(hlo)
assert b["all-gather"] == 16 * 4, b
assert b["all-reduce"] == 24 * 8 * 4, b   # trip-weighted
assert n["all-reduce"] == 24, n
assert _shape_bytes("bf16[4,8]") == 64
assert _shape_bytes("(f32[2], s32[3])") == 8 + 12
print("parse OK")
""", n_devices=1)


def test_dryrun_end_to_end_smoke():
    """Tiny-mesh dry-run of the real pipeline: lower+compile qwen2-0.5b
    train on 8 fake devices by monkeypatching the production mesh."""
    run_subprocess("""
import repro.launch.dryrun as dr
import jax
from repro.launch.mesh import _make_mesh
dr.make_production_mesh = lambda multi_pod=False: _make_mesh(
    (2, 2, 2) if multi_pod else (4, 2),
    ("pod", "data", "model") if multi_pod else ("data", "model"))
import repro.launch.dryrun as d2
rec = dr.run_case("qwen2-0.5b", "train_4k", multi_pod=False)
assert rec["hlo_flops_per_device"] > 0
assert rec["collective_total_bytes"] > 0
assert rec["memory"]["total_bytes_per_device"] > 0
rec2 = dr.run_case("qwen2-0.5b", "train_4k", multi_pod=True)
assert "delta_agg_program" in rec2
print("dryrun smoke OK")
""", n_devices=8, timeout=900)


def test_hw_roofline_formula():
    from repro.utils import hw
    r = hw.roofline_seconds(197e12, 819e9, 50e9, chips=1)
    assert abs(r["compute_s"] - 1.0) < 1e-9
    assert abs(r["memory_s"] - 1.0) < 1e-9
    assert abs(r["collective_s"] - 1.0) < 1e-9
