"""Hypothesis import shim: use the real library when installed, otherwise a
minimal deterministic fallback so the tier-1 suite still collects and runs.

The fallback implements just what these tests use:
  - ``st.integers(lo, hi)`` / ``st.floats(lo, hi)`` → a few fixed examples
    (bounds + midpoint)
  - ``@hypothesis.given(...)`` → run the test once per example combination
    (capped, deterministic)
  - ``hypothesis.settings`` / ``hypothesis.HealthCheck`` → no-ops

Property coverage is obviously weaker than real hypothesis — install
requirements-dev.txt for the real thing; CI does.
"""
from __future__ import annotations

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # deterministic fallback
    import itertools
    import types

    _MAX_COMBOS = 8

    class _Examples:
        def __init__(self, examples):
            self.examples = list(examples)

    def _integers(min_value, max_value):
        mid = (min_value + max_value) // 2
        return _Examples(dict.fromkeys([min_value, mid, max_value]))

    def _floats(min_value, max_value):
        mid = 0.5 * (min_value + max_value)
        return _Examples(dict.fromkeys([min_value, mid, max_value]))

    def _given(*strategies):
        def deco(fn):
            def wrapper():
                combos = itertools.islice(
                    itertools.product(*[s.examples for s in strategies]),
                    _MAX_COMBOS)
                for combo in combos:
                    fn(*combo)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    class _Settings:
        @staticmethod
        def register_profile(name, **kw):
            pass

        @staticmethod
        def load_profile(name):
            pass

    hypothesis = types.SimpleNamespace(
        given=_given, settings=_Settings, HealthCheck=())
    st = types.SimpleNamespace(integers=_integers, floats=_floats)

__all__ = ["hypothesis", "st"]
