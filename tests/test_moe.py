"""MoE layer: local-oracle correctness + sharded (expert-parallel) execution
equivalence on a fake multi-device mesh (subprocess)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import _capacity, _route_and_compute, init_moe, moe_fwd
from tests.conftest import run_subprocess


def _setup(T=64, seed=0, cap_factor=8.0):
    cfg = get_config("qwen3-moe-30b-a3b").reduced().replace(
        capacity_factor=cap_factor)
    p = init_moe(jax.random.key(seed), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.key(seed + 1),
                                (2, T // 2, cfg.d_model), jnp.float32)
    return cfg, p, x


def test_moe_output_finite_and_aux_positive():
    cfg, p, x = _setup()
    out, aux = moe_fwd(p, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0


def test_expert_partition_equivalence():
    """Computing experts in two local halves and summing the partial outputs
    equals the single-shot dispatch — the exact invariant the expert-parallel
    psum relies on."""
    cfg, p, x = _setup()
    T = x.shape[0] * x.shape[1]
    x_flat = x.reshape(T, -1)
    cap = _capacity(T, cfg, cfg.n_experts)
    full, (me_f, ce_f) = _route_and_compute(
        x_flat, p["router"], p["w_gate"], p["w_up"], p["w_down"],
        cfg=cfg, e_offset=0, e_local=cfg.n_experts, capacity=cap)
    E2 = cfg.n_experts // 2
    half_sum = 0
    for off in (0, E2):
        part, _ = _route_and_compute(
            x_flat, p["router"], p["w_gate"][off:off + E2],
            p["w_up"][off:off + E2], p["w_down"][off:off + E2],
            cfg=cfg, e_offset=off, e_local=E2, capacity=cap)
        half_sum = half_sum + part
    np.testing.assert_allclose(np.asarray(full), np.asarray(half_sum),
                               atol=1e-5)


def test_capacity_drops_tokens():
    """With capacity 1, overflowing assignments are dropped (outputs differ
    from the ample-capacity run) — deterministic, not an error."""
    cfg, p, x = _setup()
    T = x.shape[0] * x.shape[1]
    x_flat = x.reshape(T, -1)
    ample, _ = _route_and_compute(
        x_flat, p["router"], p["w_gate"], p["w_up"], p["w_down"],
        cfg=cfg, e_offset=0, e_local=cfg.n_experts,
        capacity=_capacity(T, cfg, cfg.n_experts))
    tight, _ = _route_and_compute(
        x_flat, p["router"], p["w_gate"], p["w_up"], p["w_down"],
        cfg=cfg, e_offset=0, e_local=cfg.n_experts, capacity=2)
    assert not np.allclose(np.asarray(ample), np.asarray(tight))


@pytest.mark.slow
def test_sharded_moe_matches_local_oracle():
    """shard_map expert-parallel MoE == unsharded oracle on 8 fake devices."""
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.mesh import _make_mesh
from repro.models.moe import init_moe, moe_fwd
cfg = get_config("qwen3-moe-30b-a3b").reduced().replace(capacity_factor=8.0)
p = init_moe(jax.random.key(0), cfg, jnp.float32)
x = 0.5 * jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model), jnp.float32)
mesh = _make_mesh((4, 2), ("data", "model"))
out_l, aux_l = moe_fwd(p, cfg, x)
out_s, aux_s = jax.jit(lambda p, x: moe_fwd(p, cfg, x, mesh=mesh))(p, x)
np.testing.assert_allclose(np.asarray(out_l), np.asarray(out_s), atol=2e-4)
np.testing.assert_allclose(float(aux_l), float(aux_s), rtol=1e-4)
print("sharded moe OK")
""")
