"""repro.obs: in-scan taps, trace spans, comms ledger, manifests, bench
snapshots (DESIGN.md §14).

Pins the subsystem's contracts:

- **Taps don't perturb the run**: an engine run with ``tap_every=k``
  produces bit-identical final params/metrics to the taps-off run (the
  io_callback only OBSERVES the round's metrics), and the streamed JSONL
  rows bitwise-match the final ring via ``history()``.
- **Spans separate compile from execute**: one ``compile`` span per static
  shape (the checkpointed runner reuses its executable across same-size
  segments), spans nest with correct depth/parent.
- **Ledger columns are deterministic in t**: ring-limited and full runs
  annotate identically; the seed-path byte model equals the measured
  ``seedcomm.wire_bytes`` of an actual compressed message.
- **Manifests cross-check with checkpoints**: the run manifest's
  ``config_hash`` equals the snapshot sidecar's.
- **Bench snapshots accumulate**: re-saving a suite pushes the previous
  snapshot into the same file's bounded history.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, sim
from repro.configs.base import FedZOConfig
from repro.data.synthetic import make_classification, noniid_shards
from repro.models.simple import softmax_init, softmax_loss
from repro.sim import engine


def _setup(n=320, n_clients=4, n_features=12, n_classes=3, seed=0):
    x, y = make_classification(n, n_features, n_classes, seed=seed)
    clients = noniid_shards(x, y, n_clients)
    return sim.build_store(clients)


def _cfg(**kw):
    base = dict(n_devices=4, n_participating=2, local_iters=2, lr=1e-2,
                mu=1e-3, b1=4, b2=2, seed=3)
    base.update(kw)
    return FedZOConfig(**base)


def _params():
    return softmax_init(None, 12, 3)


def _assert_trees_bitequal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# sinks


def test_jsonl_sink_roundtrip(tmp_path):
    path = os.path.join(tmp_path, "rows.jsonl")
    rows = [{"round": 0, "loss": 1.5, "ok": True},
            {"round": 1, "loss": 0.75, "ok": False}]
    with obs.JsonlSink(path) as sink:
        for r in rows:
            sink.write(r)
    assert obs.read_jsonl(path) == rows
    # every line is standalone JSON (tail -f consumable)
    with open(path) as f:
        for line in f:
            json.loads(line)


def test_memory_null_multi_csv_sinks(tmp_path):
    mem, null = obs.MemorySink(), obs.NullSink()
    csv_path = os.path.join(tmp_path, "rows.csv")
    csv = obs.CsvSink(csv_path)
    multi = obs.MultiSink(mem, null, csv)
    multi.write({"round": 0, "loss": 2.0})
    multi.write({"round": 1, "loss": 1.0})
    multi.close()
    assert [r["round"] for r in mem.rows] == [0, 1]
    assert null.count == 2
    lines = open(csv_path).read().splitlines()
    assert lines[0] == "round,loss" and len(lines) == 3


# ---------------------------------------------------------------------------
# in-scan taps


def test_taps_do_not_perturb_and_rows_match_history(tmp_path):
    store, cfg, p0 = _setup(), _cfg(), _params()
    rounds, every = 8, 2
    base = engine.run_experiment(softmax_loss, p0, store, cfg, rounds,
                                 donate=False)
    path = os.path.join(tmp_path, "live.jsonl")
    sink = obs.JsonlSink(path)
    tapped = engine.run_experiment(softmax_loss, p0, store, cfg, rounds,
                                   donate=False, sink=sink,
                                   tap_every=every)
    sink.close()
    # the tap only observes: bit-identical params, key, and metrics ring
    _assert_trees_bitequal(base.params, tapped.params)
    _assert_trees_bitequal(jax.random.key_data(base.key),
                           jax.random.key_data(tapped.key))
    _assert_trees_bitequal(base.metrics, tapped.metrics)

    rows = obs.read_jsonl(path)
    assert len(rows) >= rounds // every                # ≥ R/k acceptance
    assert [r["round"] for r in rows] == list(range(0, rounds, every))
    # streamed rows bitwise-match the final ring (via history)
    hist = {r["round"]: r for r in engine.history(tapped)}
    for row in rows:
        want = hist[row["round"]]
        for k, v in row.items():
            if k == "round":
                continue
            assert v == want[k], (k, v, want[k])
    # manifest landed beside the file sink, hash matches the run config
    man = obs.read_manifest(f"{path}.manifest.json")
    from repro.checkpoint.checkpoint import config_hash
    assert man["config_hash"] == config_hash(cfg)
    assert man["tap_every"] == every
    assert man["comms"]["mode"] == "dense"


def test_tap_requires_sink():
    store, cfg, p0 = _setup(), _cfg(), _params()
    with pytest.raises(ValueError, match="sink"):
        engine.run_experiment(softmax_loss, p0, store, cfg, 2,
                              donate=False, tap_every=1)
    with pytest.raises(ValueError, match="tap_every"):
        obs.RoundTap(obs.NullSink(), 0)


# ---------------------------------------------------------------------------
# tracer spans


def test_spans_nest():
    tr = obs.Tracer()
    with tr.span("outer"):
        with tr.span("inner", tag=1):
            pass
        with tr.span("inner2"):
            pass
    outer, inner, inner2 = tr.spans
    assert (outer.depth, inner.depth, inner2.depth) == (0, 1, 1)
    assert inner.parent == 0 and inner2.parent == 0
    assert outer.duration >= inner.duration + 0.0
    assert tr.totals()["inner"]["count"] == 1
    assert "inner tag=1" not in tr.report()  # meta rendered k=v
    assert "tag=1" in tr.report()


def test_tracer_compile_once_and_execute_span():
    store, cfg, p0 = _setup(), _cfg(), _params()
    tr = obs.Tracer()
    r1 = engine.run_experiment(softmax_loss, p0, store, cfg, 4,
                               donate=False, tracer=tr)
    r2 = engine.run_experiment(softmax_loss, p0, store, cfg, 4,
                               donate=False, tracer=tr)
    # same static shape twice -> exactly ONE compile span, two executes
    assert len(tr.named("compile")) == 1
    assert tr.named("compile")[0].duration > 0
    assert len(tr.named("execute")) == 2
    _assert_trees_bitequal(r1.params, r2.params)
    # the AOT-compiled run equals the plain jit run bit for bit
    plain = engine.run_experiment(softmax_loss, p0, store, cfg, 4,
                                  donate=False)
    _assert_trees_bitequal(plain.params, r1.params)


# ---------------------------------------------------------------------------
# checkpointed runner: segments, manifest/sidecar cross-check


def test_checkpointed_spans_manifest_and_taps(tmp_path):
    store, cfg, p0 = _setup(), _cfg(), _params()
    rounds, every = 8, 2
    base = engine.run_experiment(softmax_loss, p0, store, cfg, rounds,
                                 donate=False)
    tr, ms = obs.Tracer(), obs.MemorySink()
    ckdir = os.path.join(tmp_path, "ck")
    res = engine.run_experiment(softmax_loss, p0, store, cfg, rounds,
                                donate=False, checkpoint_every=4,
                                checkpoint_dir=ckdir, sink=ms,
                                tap_every=every, tracer=tr)
    _assert_trees_bitequal(base.params, res.params)
    # two same-size segments share ONE compiled program -> 1 compile span,
    # 2 segment spans, compile strictly positive
    assert len(tr.named("compile")) == 1
    assert tr.named("compile")[0].duration > 0
    assert len(tr.named("segment")) == 2
    assert [s.meta["t0"] for s in tr.named("segment")] == [0, 4]
    # taps fired across segment boundaries on the global round index
    assert [r["round"] for r in ms.rows] == list(range(0, rounds, every))
    # manifest beside the checkpoints; hash cross-checks with the sidecar
    from repro.checkpoint import checkpoint as ckpt
    man = obs.read_manifest(ckdir)
    side = ckpt.read_sidecar(ckpt.latest_run_state(ckdir))
    assert man["config_hash"] == side["config_hash"]
    assert man["rounds_done"] == rounds
    assert man["strategy"] == "fedzo"
    assert res.manifest["rounds_done"] == rounds


# ---------------------------------------------------------------------------
# comms ledger


def test_wire_bytes_model_matches_measured_message():
    from repro.core import seedcomm
    cfg = _cfg(local_iters=5, b2=20)
    msg = seedcomm.compress(jax.random.key(0),
                            jnp.zeros((5, 20), jnp.float32), cfg)
    assert seedcomm.wire_bytes_model(cfg) == seedcomm.wire_bytes(msg)


def test_ledger_columns_deterministic_ring_vs_full():
    store, cfg, p0 = _setup(), _cfg(), _params()
    rounds = 8
    full = engine.run_experiment(softmax_loss, p0, store, cfg, rounds,
                                 donate=False)
    ringed = engine.run_experiment(softmax_loss, p0, store, cfg, rounds,
                                   donate=False, ring_size=3)
    h_full = {r["round"]: r for r in engine.history(full)}
    for row in engine.history(ringed):
        assert row == h_full[row["round"]]
    # cumulative totals are (t+1)·per-round — a pure function of t
    led = full.ledger
    for t, row in sorted(h_full.items()):
        assert row["wire_bytes"] == led.round_uplink_bytes()
        assert row["wire_bytes_total"] == (t + 1) * led.round_uplink_bytes()
        assert row["downlink_bytes_total"] == \
            (t + 1) * led.round_downlink_bytes()
        assert row["compression_ratio"] == led.compression_ratio()


def test_ledger_seed_mode_and_effective_bytes():
    from repro.core import seedcomm
    from repro.utils.tree import tree_bytes
    cfg = _cfg(delta_compression="seed")
    p0 = _params()
    led = obs.CommsLedger.from_run(cfg, p0)
    assert led.mode == "seed"
    assert led.uplink_client_bytes == seedcomm.wire_bytes_model(cfg)
    assert led.dense_client_bytes == tree_bytes(p0)
    assert led.compression_ratio() > 1.0
    rows = [{"round": 0, "m_effective": 1.0},
            {"round": 1, "event": "rollback"}]
    led.annotate(rows)
    assert rows[0]["wire_bytes_effective"] == led.uplink_client_bytes
    assert "wire_bytes" not in rows[1]         # event rows pass untouched


# ---------------------------------------------------------------------------
# FedServer integration


def test_fedserver_round_ms_and_ledger_parity():
    from repro.fed.server import FedServer
    store, cfg, p0 = _setup(), _cfg(), _params()
    x, y = make_classification(320, 12, 3, seed=0)
    clients = noniid_shards(x, y, 4)
    host = FedServer(softmax_loss, p0, clients, cfg, store=store)
    for t in range(3):
        host.run_round(t)
    tr = obs.Tracer()
    scanned = FedServer(softmax_loss, p0, clients, cfg, store=store,
                        tracer=tr)
    scanned.run(3)
    assert len(tr.named("compile")) == 1 and len(tr.named("execute")) == 1
    for hrow, srow in zip(host.history, scanned.history):
        assert hrow["round"] == srow["round"]
        # host rows carry wall-clock; both drivers agree on the byte model
        assert hrow["round_ms"] > 0
        for k in ("wire_bytes", "wire_bytes_total", "downlink_bytes_total",
                  "dense_bytes", "compression_ratio"):
            assert hrow[k] == srow[k], k


# ---------------------------------------------------------------------------
# sweep tracer


def test_sweep_tracer_one_compile_per_static_group():
    from repro.sim.sweep import run_sweep, scenario_grid
    store, cfg, p0 = _setup(), _cfg(), _params()
    scenarios = scenario_grid(local_iters=(1, 2), lr=(1e-2, 5e-3))
    tr = obs.Tracer()
    recs = run_sweep(softmax_loss, p0, store, cfg, scenarios, 3,
                     tracer=tr)
    assert len(recs) == 4
    # 2 static groups (local_iters) × vmapped lr axis
    assert len(tr.named("compile")) == 2
    assert len(tr.named("execute")) == 2


# ---------------------------------------------------------------------------
# kernel timing harness


def test_kernel_report_measures_and_models():
    reps = obs.kernel_report(n=1024, b2=4, m=4)
    names = [kt.name for kt in reps]
    assert any("zo_walk" in n for n in names)
    assert any("zo_replay" in n for n in names)
    assert any("aircomp_reduce" in n for n in names)
    for kt in reps:
        assert kt.measured_us > 0
        assert kt.model_us > 0
        assert kt.hbm_passes >= 2.0
        rows = kt.rows()
        assert rows[0][0].endswith("_us")
        assert rows[1][0].endswith("_hbm_model_us")


# ---------------------------------------------------------------------------
# bench snapshots


def test_bench_snapshot_accumulates_history(tmp_path):
    d = str(tmp_path)
    rows1 = [("suitex/a_us", 10.0, 1), ("suitex/b_us", 20.0, 2)]
    rows2 = [("suitex/a_us", 11.0, 1), ("suitex/b_us", 19.0, 2)]
    p = obs.save_bench("suitex", rows1, out_dir=d, config={"note": "r1"})
    assert os.path.basename(p) == "BENCH_suitex.json"
    obs.save_bench("suitex", rows2, out_dir=d)
    snap = obs.load_benches(d)["suitex"]
    assert [r["us_per_call"] for r in snap["rows"]] == [11.0, 19.0]
    assert len(snap["history"]) == 1
    assert [r["us_per_call"] for r in snap["history"][0]["rows"]] == \
        [10.0, 20.0]
    assert snap["jax_version"] == jax.__version__


def test_manifest_roundtrip(tmp_path):
    cfg = _cfg()
    led = obs.CommsLedger.from_run(cfg, _params())
    man = obs.build_manifest(cfg, strategy="fedzo", rounds=5, n_clients=4,
                             ledger=led,
                             faults=sim.FaultModel(p_fail=0.1,
                                                   p_recover=0.5),
                             events=[{"round": 2, "event": "rollback"}])
    path = obs.write_manifest(str(tmp_path), man)
    back = obs.read_manifest(path)
    assert back["config_hash"] == man["config_hash"]
    assert back["faults"]["stationary_up"] == pytest.approx(0.5 / 0.6)
    assert back["events"][0]["event"] == "rollback"
    assert back["topology"]["device_count"] >= 1
    assert "git_sha" in back
