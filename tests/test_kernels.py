"""Pallas kernel validation: shape/dtype sweeps, interpret=True vs the
pure-jnp oracles in kernels/ref.py (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import hypothesis, st

from repro.kernels import ops, ref
from repro.kernels.zo_axpy import BLOCK

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=8,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")

DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", [BLOCK, 2 * BLOCK, BLOCK + 12345, 1000])
def test_zo_axpy2_sweep(n, dtype):
    x = jax.random.normal(jax.random.key(0), (n,), dtype)
    u = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (n,), jnp.float32)
    out = ops.axpy2(x, u, v, 0.25, -1.5)
    r = ref.axpy2_ref(x, u, v, jnp.asarray([0.25, -1.5]))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32),
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-6)


@hypothesis.given(st.integers(1, 3 * BLOCK), st.floats(-2, 2), st.floats(-2, 2))
def test_zo_axpy2_property(n, a, b):
    x = jnp.arange(n, dtype=jnp.float32) / n
    u = jnp.ones((n,), jnp.float32)
    v = -0.5 * jnp.ones((n,), jnp.float32)
    out = ops.axpy2(x, u, v, a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) + a - 0.5 * b,
                               atol=1e-4)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(2, 128, 256, 4, 2, 64),
                                   (1, 128, 128, 8, 8, 32),
                                   (1, 300, 300, 4, 1, 128),
                                   (2, 64, 512, 2, 2, 64)])
def test_flash_attention_sweep(shape, dtype):
    B, Sq, Sk, Hq, Hkv, D = shape
    q = jax.random.normal(jax.random.key(0), (B, Sq, Hq, D), dtype)
    k = jax.random.normal(jax.random.key(1), (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(jax.random.key(2), (B, Sk, Hkv, D), dtype)
    out = ops.attention(q, k, v, causal=True, block_q=128, block_k=128)
    r = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3),
                          causal=True).transpose(0, 2, 1, 3)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol)


@pytest.mark.parametrize("window", [16, 64, 1000])
def test_flash_attention_window(window):
    B, S, H, D = 1, 256, 2, 32
    q = jax.random.normal(jax.random.key(0), (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (B, S, H, D), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (B, S, H, D), jnp.float32)
    out = ops.attention(q, k, v, causal=True, window=window, block_q=128,
                        block_k=128)
    r = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True,
                          window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=2e-5)


def test_flash_matches_model_chunked_attention():
    """The model's pure-jnp chunked attention and the Pallas kernel agree —
    they are twins of the same math (DESIGN.md kernels section)."""
    from repro.models.layers import chunked_attention
    B, S, Hq, Hkv, D = 2, 192, 4, 2, 64
    q = jax.random.normal(jax.random.key(0), (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (B, S, Hkv, D), jnp.float32)
    a = ops.attention(q, k, v, causal=True, block_q=64, block_k=64)
    b = chunked_attention(q, k, v, causal=True, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(4, 64), (130, 896), (1, 2048), (999, 64)])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(jax.random.key(0), shape, dtype)
    s = jax.random.normal(jax.random.key(1), (shape[-1],), jnp.float32)
    out = ops.rmsnorm(x, s)
    r = ref.rmsnorm_ref(x, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol)


def test_rmsnorm_matches_model_norm():
    from repro.models.layers import init_norm, norm_fwd
    x = jax.random.normal(jax.random.key(0), (5, 7, 64), jnp.float32)
    p = init_norm(64)
    out_model = norm_fwd(p, x)
    out_kernel = ops.rmsnorm(x, p["scale"])
    np.testing.assert_allclose(np.asarray(out_model), np.asarray(out_kernel),
                               atol=1e-5)
