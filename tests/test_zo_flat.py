"""Flat-buffer ZO hot path (DESIGN.md §7): kernel bit-equivalence against
the interpreted references, and old-vs-new trajectory agreement.

The load-bearing claims pinned here:
  1. zo_replay / zo_walk are bit-identical to the pure-jnp references built
     from the SAME counter convention (per block, both direction kinds).
  2. flat_apply_coefficients == pytree apply_coefficients(conv="counter")
     up to fp32 reassociation.
  3. The fused flat local_iterate walks the same loss trajectory as the
     pytree path with conv="counter" on the softmax-regression model over
     ≥ 20 local iterates (fp32 tolerance) — the perf path changes HBM
     traffic, not the algorithm.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedZOConfig
from repro.core import estimator, fedzo, seedcomm
from repro.data.synthetic import make_classification
from repro.kernels import ops, ref
from repro.models.simple import softmax_init, softmax_loss
from repro.utils.flatparams import flat_spec, flatten, unflatten

BR = 4                      # small kernel blocks: 4 rows × 128 lanes = 512
KEY2 = jax.random.key_data(jax.random.key(1234))


# -- 1. kernel bit-equivalence ---------------------------------------------


@pytest.mark.parametrize("kind", ["normal", "sign"])
@pytest.mark.parametrize("nblocks", [1, 3])
def test_zo_replay_bit_equals_reference(kind, nblocks):
    n = nblocks * BR * 128
    x = jax.random.normal(jax.random.key(0), (n,), jnp.float32)
    coeffs = jnp.asarray(np.random.default_rng(2).normal(size=6), jnp.float32)
    out = ops.zo_replay(x, KEY2, coeffs, kind=kind, block_rows=BR)
    r = jax.jit(functools.partial(ref.zo_replay_ref, kind=kind))(
        x.reshape(-1, 128), KEY2, coeffs).reshape(-1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(r))


@pytest.mark.parametrize("kind", ["normal", "sign"])
def test_zo_walk_bit_equals_reference(kind):
    n = 2 * BR * 128
    x = jax.random.normal(jax.random.key(0), (n,), jnp.float32)
    nn = jnp.asarray([3, 4], jnp.int32)
    ab = jnp.asarray([-0.25, 0.125], jnp.float32)
    out = ops.zo_walk(x, KEY2, nn, ab, kind=kind, block_rows=BR)
    r = jax.jit(functools.partial(ref.zo_walk_ref, kind=kind))(
        x.reshape(-1, 128), KEY2, nn, ab).reshape(-1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(r))


def test_zo_dirnorms_matches_reference_and_direct():
    from repro.kernels.zo_axpy import counter_direction_flat
    d, n_pad, b2 = 900, 2 * BR * 128, 5
    out = ops.zo_dirnorms(KEY2, d, b2=b2, n_pad=n_pad, block_rows=BR)
    r = ref.zo_dirnorms_ref(KEY2, d, b2, n_pad, block_rows=BR)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=1e-6)
    direct = jnp.stack([jnp.sum(counter_direction_flat(KEY2, n, d) ** 2)
                        for n in range(b2)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct), rtol=1e-5)


def test_walk_transition_reaches_fresh_perturbation():
    """x →(+μv0) →(−μv0,+μv1) ... lands where a fresh x+μv_n perturbation
    would, up to fp32 round-off — the MeZO transition introduces no drift
    beyond reassociation."""
    from repro.kernels.zo_axpy import counter_direction_flat
    n = BR * 128
    x = jax.random.normal(jax.random.key(3), (n,), jnp.float32)
    mu = 1e-3
    xp = x
    for k in range(6):
        a = 0.0 if k == 0 else -mu
        xp = ops.zo_walk(xp, KEY2, [max(k - 1, 0), k], [a, mu],
                         kind="normal", block_rows=BR)
    direct = x + mu * counter_direction_flat(KEY2, 5, n)
    np.testing.assert_allclose(np.asarray(xp), np.asarray(direct), atol=1e-6)


# -- 2. flat update == pytree counter-conv update ---------------------------


@pytest.mark.parametrize("kind", ["sphere", "gaussian", "rademacher"])
def test_flat_apply_matches_pytree_counter_conv(kind):
    params = {"a": jax.random.normal(jax.random.key(0), (300,)),
              "b": jax.random.normal(jax.random.key(1), (7, 11))}
    spec = flat_spec(params, block=BR * 128)
    coeffs = jnp.asarray(np.random.default_rng(3).normal(size=9), jnp.float32)
    rng = jax.random.key(77)

    flat = unflatten(estimator.flat_apply_coefficients(
        flatten(params, spec), spec, rng, coeffs, scale=-0.3, kind=kind,
        block_rows=BR), spec)
    tree = estimator.apply_coefficients(params, rng, coeffs, scale=-0.3,
                                        kind=kind, conv="counter")
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(tree)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_flat_rejects_coordinate():
    params = {"a": jnp.zeros((64,))}
    spec = flat_spec(params, block=BR * 128)
    with pytest.raises(ValueError):
        estimator.flat_apply_coefficients(
            flatten(params, spec), spec, jax.random.key(0),
            jnp.ones((2,)), kind="coordinate", block_rows=BR)


def test_seedcomm_wire_format_preserved_on_flat_path():
    """Same (key, coeffs) message; flat receiver reconstructs the flat
    client's delta exactly."""
    cfg = FedZOConfig(local_iters=4, lr=0.02, mu=1e-3, b2=5,
                      flat_params=True, flat_block_rows=BR)
    params = {"x": jnp.zeros((20,))}
    batches = {"target": jnp.ones((4, 20))}

    def loss(p, b):
        return 0.5 * jnp.sum((p["x"] - b["target"]) ** 2)

    rng = jax.random.key(42)
    delta, res = fedzo.client_delta(loss, params, batches, rng, cfg)
    msg = seedcomm.compress(rng, res.coeffs, cfg)
    assert seedcomm.wire_bytes(msg) < 120
    recon = seedcomm.reconstruct_delta(msg, params, cfg)
    for a, b in zip(jax.tree.leaves(delta), jax.tree.leaves(recon)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


# -- 3. trajectory equivalence on softmax regression ------------------------


@pytest.mark.slow
def test_flat_trajectory_matches_pytree_over_20_iterates():
    """Acceptance: the flat fused path's loss trajectory matches the pytree
    path (conv="counter", same directions) within fp32 tolerance over ≥ 20
    local iterates on the softmax-regression model."""
    x, y = make_classification(512, 784, 10, seed=0)
    batch = {"x": jnp.asarray(x[:256]), "y": jnp.asarray(y[:256])}
    params = softmax_init(None)

    base = FedZOConfig(b2=8, lr=1e-2, mu=1e-3, direction_conv="counter")
    cfg_tree = dataclasses.replace(base)
    cfg_flat = dataclasses.replace(base, flat_params=True)

    step_tree = jax.jit(fedzo.make_train_step(softmax_loss, cfg_tree))
    step_flat = jax.jit(fedzo.make_train_step(softmax_loss, cfg_flat))

    p_t, p_f = params, params
    losses_t, losses_f = [], []
    for t in range(22):
        k = jax.random.key(t)
        p_t, m_t = step_tree(p_t, batch, k)
        p_f, m_f = step_flat(p_f, batch, k)
        losses_t.append(float(m_t["loss"]))
        losses_f.append(float(m_f["loss"]))
    losses_t, losses_f = np.asarray(losses_t), np.asarray(losses_f)
    # both descend ...
    assert losses_t[-1] < losses_t[0]
    assert losses_f[-1] < losses_f[0]
    # ... along the same trajectory (fp32 round-off amplified by the 1/μ
    # difference quotient bounds the gap, not algorithmic divergence)
    np.testing.assert_allclose(losses_f, losses_t, rtol=2e-3, atol=2e-4)
    # final parameters agree too (looser: 22 compounded 1/μ amplifications)
    for a, b in zip(jax.tree.leaves(p_f), jax.tree.leaves(p_t)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_pod_step_computes_dirnorms_once(monkeypatch):
    """Regression: the pod-step flat path used to call flat_coefficients
    and flat_apply_coefficients without a shared ``inv``, running the
    zo_dirnorms kernel twice per step (the invariant flat_local_iterate
    documents). The step must compute the inv-norms exactly once."""
    calls = []
    orig = estimator.flat_inv_norms

    def counting(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(estimator, "flat_inv_norms", counting)
    cfg = FedZOConfig(b2=4, lr=0.05, mu=1e-3, flat_params=True,
                      flat_block_rows=BR)
    params = {"x": jnp.zeros((40,))}

    def loss_grouped(p, b):
        l = 0.5 * jnp.sum((p["x"] - b["target"]) ** 2)
        return jnp.stack([l, l * 1.01])

    class FakeMesh:
        shape = {"pod": 2}

    step = fedzo.make_pod_round_step(loss_grouped, cfg, FakeMesh())
    newp, _ = step(params, {"target": jnp.ones((40,))}, jax.random.key(0))
    assert jnp.all(jnp.isfinite(newp["x"]))
    assert len(calls) == 1, f"flat_inv_norms ran {len(calls)}× (want 1)"


def test_flat_local_phase_and_pod_step_run():
    """The flat path is wired through local_phase and make_pod_round_step."""
    cfg = FedZOConfig(local_iters=3, b2=4, lr=0.05, mu=1e-3,
                      flat_params=True, flat_block_rows=BR)
    params = {"x": jnp.zeros((40,))}
    batches = {"target": jnp.ones((3, 40))}

    def loss(p, b):
        return 0.5 * jnp.sum((p["x"] - b["target"]) ** 2)

    res = fedzo.local_phase(loss, params, batches, jax.random.key(0), cfg)
    assert res.coeffs.shape == (3, 4)
    assert float(res.losses[-1]) > 0

    class FakeMesh:
        shape = {"pod": 2}

    def loss_grouped(p, b):
        return jnp.stack([loss(p, b), loss(p, b) * 1.01])

    step = fedzo.make_pod_round_step(loss_grouped, cfg, FakeMesh())
    newp, metrics = step(params, {"target": jnp.ones((40,))},
                         jax.random.key(1))
    assert metrics["per_pod_loss"].shape == (2,)
    assert float(metrics["loss"]) > 0
    assert jnp.all(jnp.isfinite(newp["x"]))
