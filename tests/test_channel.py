"""Wireless scenario engine (sim/channel.py, DESIGN.md §16).

Pins the ``ChannelModel`` contracts:
- the AR(1) chain is CONTEXT-STABLE: the eager host replay (the tiered
  ``CohortStream``'s derivation) bit-matches the in-scan carry for the
  full state (fading, battery), the realized cohort fading, and the
  transmit mask — the invariant the integer fixed-point numerics exist
  for;
- ρ=0 advances are bit-exactly the i.i.d. fresh draw (the paper's
  Sec. IV-A per-round channel law, now as the chain's degenerate case);
- ``channel_model=None`` runs are byte-identical to pre-scenario runs
  (the goldens pin the trajectory; here we pin the key-chain layout);
- engine ≡ tiered ≡ host-driven FedServer bitwise with the channel on,
  including kill-and-resume with the chain + batteries in the carry;
- energy gating drains batteries monotonically, shrinks ``m_effective``,
  and lands in the ledger's ``energy_spent`` column and the manifest's
  ``channel`` block.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import hypothesis, st

from repro import sim
from repro.fed.server import FedServer
from repro.models.simple import softmax_init, softmax_loss
from repro.sim import channel as channel_lib
from repro.sim.channel import ChannelModel

from test_sim import _assert_trees_bitequal, _cfg, _setup

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=10,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


def _chan_cfg(**kw):
    base = dict(channel_schedule=True, h_min=0.3)
    base.update(kw)
    return _cfg(**base)


# ---------------------------------------------------------------------------
# model config


def test_channel_model_validation_and_derived():
    with pytest.raises(ValueError):
        ChannelModel(rho=1.0)
    with pytest.raises(ValueError):
        ChannelModel(rho=-0.1)
    with pytest.raises(ValueError):
        ChannelModel(tx_cost=0.0)
    with pytest.raises(ValueError):
        ChannelModel.from_doppler(-1.0)
    assert not ChannelModel().gated
    assert ChannelModel(battery=2.0).gated
    # from_doppler: slow mover stays correlated, fast mover ≈ i.i.d.;
    # fd_T=0 would be ρ=1 (frozen channel), which the AR(1)
    # parameterization excludes
    assert ChannelModel.from_doppler(0.01).rho == pytest.approx(
        np.exp(-0.02 * np.pi))
    assert ChannelModel.from_doppler(2.0).rho < 1e-5
    with pytest.raises(ValueError):
        ChannelModel.from_doppler(0.0)
    cm = ChannelModel(rho=float(np.exp(-1.0)))
    assert cm.coherence_rounds == pytest.approx(1.0)
    d = cm.describe()
    assert d["rho"] == cm.rho and d["energy_gated"] is False
    assert hash(cm) == hash(ChannelModel(rho=float(np.exp(-1.0))))


# ---------------------------------------------------------------------------
# chain numerics


@hypothesis.given(st.integers(0, 10_000), st.integers(2, 40))
def test_rho0_advance_is_iid_draw_bitwise(seed, n):
    """ρ=0 is the paper's i.i.d. per-round channel, bit for bit: the
    advance returns the fresh CN(0,1) innovation itself."""
    cm = ChannelModel(rho=0.0)
    h = cm._innovation(jax.random.key(seed + 1), n)
    k = jax.random.key(seed)
    np.testing.assert_array_equal(np.asarray(cm.advance(k, h)),
                                  np.asarray(cm._innovation(k, n)))


@hypothesis.given(st.integers(0, 10_000),
                  st.floats(0.0, 0.99), st.integers(1, 12))
def test_host_replay_bitmatches_in_scan_chain(seed, rho, rounds):
    """The tiered path's eager host replay of the chain (advance +
    scheduling + battery debit) is BIT-IDENTICAL to the same chain run
    inside a jitted lax.scan — the central DESIGN.md §16 invariant that
    lets the CohortStream stage realizations arbitrarily ahead of the
    device."""
    cm = ChannelModel(rho=float(rho), battery=3.0, tx_cost=1.0)
    n, m = 10, 4
    state0 = cm.init_state(n, channel_lib.init_key(jax.random.key(seed)))
    idx = jnp.arange(m)
    ks = jax.random.split(jax.random.key(seed + 7), rounds)

    def body(carry, k):
        st, rc = cm.step(k, carry, idx, h_min=0.3, schedule=True)
        return st, (rc.h, rc.mask)

    scan_state, (hs, ms) = jax.jit(
        lambda st, ks: jax.lax.scan(body, st, ks))(state0, ks)
    st = state0
    for t in range(rounds):
        st, rc = cm.step(ks[t], st, idx, h_min=0.3, schedule=True)
        np.testing.assert_array_equal(np.asarray(rc.h), np.asarray(hs[t]))
        np.testing.assert_array_equal(np.asarray(rc.mask), np.asarray(ms[t]))
    _assert_trees_bitequal(st, scan_state)


def test_stationary_law_independent_of_rho():
    """|h| stays Rayleigh for every ρ: the Sec. IV-A scheduling rate
    exp(−h_min²) is preserved, only the round-to-round correlation
    changes."""
    n, h_min = 60_000, 0.6
    for rho in (0.0, 0.9):
        cm = ChannelModel(rho=rho)
        h = cm.init_state(n, channel_lib.init_key(jax.random.key(0)))[0]
        for t in range(4):
            h = cm.advance(jax.random.key(100 + t), h)
        hc = channel_lib.fading((h, None))
        rate = float(jnp.mean((jnp.abs(hc) >= h_min).astype(jnp.float32)))
        assert rate == pytest.approx(np.exp(-h_min ** 2), abs=0.01), rho


def test_correlation_increases_with_rho():
    """Higher ρ ⇒ stronger round-to-round fading correlation (the mobility
    knob actually turns something)."""
    n = 40_000
    corrs = {}
    for rho in (0.0, 0.95):
        cm = ChannelModel(rho=rho)
        h0 = cm.init_state(n, channel_lib.init_key(jax.random.key(1)))[0]
        h1 = cm.advance(jax.random.key(2), h0)
        a = np.asarray(channel_lib.fading((h0, None)).real)
        b = np.asarray(channel_lib.fading((h1, None)).real)
        corrs[rho] = np.corrcoef(a, b)[0, 1]
    assert abs(corrs[0.0]) < 0.05
    assert corrs[0.95] > 0.9


def test_battery_debit_only_on_transmit():
    """Scheduled ∧ charged clients pay tx_cost; masked/unsampled clients
    keep their charge; drained clients are masked out."""
    cm = ChannelModel(rho=0.0, battery=1.5, tx_cost=1.0)
    state = cm.init_state(6, channel_lib.init_key(jax.random.key(0)))
    idx = jnp.asarray([0, 2, 4])
    # schedule=False: every sampled, charged client transmits
    state, rc = cm.step(jax.random.key(1), state, idx, h_min=0.3,
                        schedule=False)
    batt = np.asarray(channel_lib.battery(state))
    np.testing.assert_array_equal(batt[[0, 2, 4]], [0.5, 0.5, 0.5])
    np.testing.assert_array_equal(batt[[1, 3, 5]], [1.5, 1.5, 1.5])
    assert np.asarray(rc.mask).all()
    # second transmission drains them below tx_cost → masked, not debited
    state, rc = cm.step(jax.random.key(2), state, idx, h_min=0.3,
                        schedule=False)
    assert not np.asarray(rc.mask).any()
    batt = np.asarray(channel_lib.battery(state))
    np.testing.assert_array_equal(batt[[0, 2, 4]], [0.5, 0.5, 0.5])
    assert float(rc.m_transmitting) == 0.0


# ---------------------------------------------------------------------------
# engine integration: channel-off key layout unchanged


def test_channel_off_key_layout_unchanged():
    """``split_round_keys`` with channel off reproduces the historical
    5-way (and faults-on 6-way) splits exactly — the property that keeps
    ``channel_model=None`` trajectories (and the golden fixtures)
    byte-identical to pre-scenario builds."""
    from repro.sim import engine
    key = jax.random.key(9)
    legacy = tuple(jax.random.split(key, 5))
    got = engine.split_round_keys(key)
    assert got[5] is None and got[6] is None
    for a, b in zip(legacy, got[:5]):
        np.testing.assert_array_equal(jax.random.key_data(a),
                                      jax.random.key_data(b))
    legacy6 = tuple(jax.random.split(key, 6))
    got_f = engine.split_round_keys(key, faults=True)
    assert got_f[6] is None
    for a, b in zip(legacy6, got_f[:6]):
        np.testing.assert_array_equal(jax.random.key_data(a),
                                      jax.random.key_data(b))
    # channel stream rides LAST, after the fault stream
    got_c = engine.split_round_keys(key, faults=True, channel=True)
    assert got_c[5] is not None and got_c[6] is not None
    got_co = engine.split_round_keys(key, channel=True)
    assert got_co[5] is None and got_co[6] is not None


# ---------------------------------------------------------------------------
# engine ≡ tiered ≡ host-driven, channel on


def _run_all_drivers(cm, rounds=6):
    clients, store = _setup()
    cfg = _chan_cfg(channel_model=cm)
    p0 = softmax_init(None, 24, 4)
    res = sim.run_experiment(softmax_loss, p0, store, cfg, rounds,
                             donate=False)
    host_store = sim.build_host_store(clients, n_buckets=2)
    tier = sim.run_experiment(softmax_loss, p0, host_store, cfg, rounds,
                              donate=False)
    srv = FedServer(softmax_loss, p0, clients, cfg, store=store)
    for t in range(rounds):
        srv.run_round(t)
    return res, tier, srv


def test_engine_tiered_host_bitwise_with_channel():
    """The §16 acceptance triangle: resident engine ≡ tiered stream ≡
    host-driven FedServer rounds, bit for bit — params, metrics, AND the
    final chain state (fading + batteries)."""
    cm = ChannelModel(rho=0.85, battery=4.0, tx_cost=1.0)
    res, tier, srv = _run_all_drivers(cm)
    _assert_trees_bitequal(res.params, tier.params)
    _assert_trees_bitequal(res.channel_state, tier.channel_state)
    _assert_trees_bitequal(res.metrics, tier.metrics)
    _assert_trees_bitequal(res.params, srv.params)
    _assert_trees_bitequal(res.channel_state, srv._cstate)


def test_battery_drain_shrinks_m_effective():
    """With a finite energy budget the surviving cohort shrinks as
    batteries drain — and every transmission is debited, so the drained
    regime is permanent (no recharge in this model)."""
    cm = ChannelModel(rho=0.0, battery=2.0, tx_cost=1.0)
    clients, store = _setup()
    cfg = _chan_cfg(channel_model=cm, n_participating=6)
    p0 = softmax_init(None, 24, 4)
    res = sim.run_experiment(softmax_loss, p0, store, cfg, 10, donate=False)
    m_eff = np.asarray(res.metrics["m_effective"])
    batt = np.asarray(channel_lib.battery(res.channel_state))
    # every client started with 2 transmissions' worth of charge; after 10
    # rounds of 6-of-8 sampling the fleet is largely drained
    assert batt.sum() < 2.0 * store.n_clients
    assert m_eff[-1] < m_eff[0] or batt.sum() == 0.0
    # conservation: total debits == total effective transmissions
    total_tx = 2.0 * store.n_clients - batt.sum()
    assert total_tx == pytest.approx(m_eff.sum())


def test_energy_ledger_and_manifest(tmp_path):
    """The ledger prices each effective transmission at tx_cost and the
    manifest carries the scenario block next to the fault block."""
    cm = ChannelModel(rho=0.5, battery=5.0, tx_cost=2.0)
    clients, store = _setup()
    cfg = _chan_cfg(channel_model=cm)
    p0 = softmax_init(None, 24, 4)
    res = sim.run_experiment(softmax_loss, p0, store, cfg, 4, donate=False,
                             checkpoint_dir=str(tmp_path),
                             checkpoint_every=2)
    rows = sim.history(res)
    for row in rows:
        assert row["energy_spent"] == row["m_effective"] * 2.0
    assert res.ledger.tx_energy_client == 2.0
    man = res.manifest
    assert man["channel"]["rho"] == 0.5
    assert man["channel"]["energy_gated"] is True
    # ungated model: no energy columns (budget accounting off)
    cfg2 = _chan_cfg(channel_model=ChannelModel(rho=0.5))
    res2 = sim.run_experiment(softmax_loss, p0, store, cfg2, 2,
                              donate=False)
    assert "energy_spent" not in sim.history(res2)[0]


def test_checkpoint_resume_with_channel_state(tmp_path):
    """Kill-and-resume: the chain + batteries ride the durable checkpoint
    carry, so a run killed mid-flight resumes to the bit-identical
    trajectory — on the resident AND the tiered path."""
    cm = ChannelModel(rho=0.8, battery=4.0, tx_cost=1.0)
    clients, store = _setup()
    cfg = _chan_cfg(channel_model=cm)
    p0 = softmax_init(None, 24, 4)
    ref = sim.run_experiment(softmax_loss, p0, store, cfg, 6, donate=False)

    d = str(tmp_path / "resident")
    part = sim.run_experiment(softmax_loss, p0, store, cfg, 6, donate=False,
                              checkpoint_dir=d, checkpoint_every=2,
                              max_segments=1)
    assert part.rounds == 2
    resumed = sim.run_experiment(softmax_loss, p0, store, cfg, 6,
                                 donate=False, checkpoint_dir=d,
                                 checkpoint_every=2, resume=True)
    assert resumed.rounds == 6
    _assert_trees_bitequal(ref.params, resumed.params)
    _assert_trees_bitequal(ref.channel_state, resumed.channel_state)
    _assert_trees_bitequal(ref.metrics, resumed.metrics)

    # same drill on the tiered path: chain + batteries are host-resident
    # there, and still land in (and resume from) the durable snapshot
    host_store = sim.build_host_store(clients, n_buckets=2)
    dt = str(tmp_path / "tiered")
    sim.run_experiment(softmax_loss, p0, host_store, cfg, 6, donate=False,
                       checkpoint_dir=dt, checkpoint_every=2,
                       max_segments=1)
    tiered = sim.run_experiment(softmax_loss, p0, host_store, cfg, 6,
                                donate=False, checkpoint_dir=dt,
                                checkpoint_every=2, resume=True)
    _assert_trees_bitequal(ref.params, tiered.params)
    _assert_trees_bitequal(ref.channel_state, tiered.channel_state)


def test_chunked_equals_single_shot_with_channel(tmp_path):
    """checkpoint_every=k segments ≡ one-shot scan with the chain in the
    carry (the PR 7 invariant extended to the channel slot)."""
    cm = ChannelModel(rho=0.7)
    clients, store = _setup()
    cfg = _chan_cfg(channel_model=cm)
    p0 = softmax_init(None, 24, 4)
    one = sim.run_experiment(softmax_loss, p0, store, cfg, 6, donate=False)
    chunked = sim.run_experiment(softmax_loss, p0, store, cfg, 6,
                                 donate=False, checkpoint_dir=str(tmp_path),
                                 checkpoint_every=2)
    _assert_trees_bitequal(one.params, chunked.params)
    _assert_trees_bitequal(one.channel_state, chunked.channel_state)
    _assert_trees_bitequal(one.metrics, chunked.metrics)


def test_faults_compose_with_channel():
    """Fault availability and channel gating stack: both processes ride
    the carry, and the engine ≡ tiered invariant holds with both on."""
    cm = ChannelModel(rho=0.6, battery=5.0, tx_cost=1.0)
    faults = sim.FaultModel(p_fail=0.2, p_recover=0.5)
    clients, store = _setup()
    cfg = _chan_cfg(channel_model=cm)
    p0 = softmax_init(None, 24, 4)
    res = sim.run_experiment(softmax_loss, p0, store, cfg, 5, donate=False,
                             faults=faults)
    host_store = sim.build_host_store(clients, n_buckets=2)
    tier = sim.run_experiment(softmax_loss, p0, host_store, cfg, 5,
                              donate=False, faults=faults)
    _assert_trees_bitequal(res.params, tier.params)
    _assert_trees_bitequal(res.channel_state, tier.channel_state)
    _assert_trees_bitequal(res.fault_state, tier.fault_state)


# ---------------------------------------------------------------------------
# the one-point channel-convention estimator (arXiv 2401.17460)


def test_direction_conv_channel_runs_and_descends():
    """direction_conv="channel" (directions = real baseband projections of
    the fading, gaussian statistics, identity scale) trains on the wide
    path and needs batch_directions."""
    clients, store = _setup()
    cfg = _chan_cfg(batch_directions=True, direction_conv="channel",
                    channel_model=ChannelModel(rho=0.9))
    p0 = softmax_init(None, 24, 4)
    res = sim.run_experiment(softmax_loss, p0, store, cfg, 8, donate=False)
    loss = np.asarray(res.metrics["mean_local_loss"])
    assert np.isfinite(loss).all()
    assert loss[-1] < loss[0]
    with pytest.raises(ValueError, match="batch_directions"):
        bad = _chan_cfg(direction_conv="channel")
        sim.run_experiment(softmax_loss, p0, store, bad, 1, donate=False)
