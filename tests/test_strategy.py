"""The composable ZO algorithm layer (core/strategy.py, DESIGN.md §13).

Pins the strategy-core contracts:

- registry dispatch: ``strategy.get`` fails loudly listing the registered
  names, the engine entry points accept a name / an instance /
  ``cfg.strategy``, and the legacy ``algo=`` kwarg warns deprecation;
- reductions: ZO-FedProx with ``prox_mu=0`` and ZO-FedDyn with
  ``dyn_alpha=0`` are bit-identical to plain FedZO (the hooks are
  statically elided), while positive coefficients change the trajectory;
- stateful strategies ride the durable carry: chunked ≡ single-shot
  bitwise and SIGKILL-and-resume restores every client's control/dual
  state bit-identically;
- the surrogate estimator (direction_conv="surrogate") pays ≤ half the
  fresh ZO queries per iterate and still reaches matched final loss /
  accuracy on the softmax golden task;
- sweeps carry the strategy as a static axis and the CSV rows stay
  distinguishable; ``ExperimentResult.history()`` rows name the strategy;
- the baselines (zo_sgd / DZOPA / ZONE-S) route through the shared
  estimator direction conventions — counter-convention trajectories are
  pinned and differ from the tree convention.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sim
from repro.configs.base import FedZOConfig
from repro.core import baselines, fedzo
from repro.core import strategy as strategy_mod
from repro.data.synthetic import make_classification, noniid_shards
from repro.fed.server import FedServer
from repro.models.simple import softmax_init, softmax_loss
from repro.sim import sweep


def _setup(n=640, n_clients=8, seed=0):
    x, y = make_classification(n, 24, 4, seed=seed)
    clients = noniid_shards(x, y, n_clients)
    return clients, sim.build_store(clients)


def _cfg(**kw):
    base = dict(n_devices=8, n_participating=4, local_iters=2, lr=1e-2,
                mu=1e-3, b1=8, b2=4, seed=3)
    base.update(kw)
    return FedZOConfig(**base)


def _assert_trees_bitequal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_results_bitequal(a, b):
    _assert_trees_bitequal(a.params, b.params)
    np.testing.assert_array_equal(jax.random.key_data(a.key),
                                  jax.random.key_data(b.key))
    assert sorted(a.metrics) == sorted(b.metrics)
    for k in a.metrics:
        np.testing.assert_array_equal(np.asarray(a.metrics[k]),
                                      np.asarray(b.metrics[k]), err_msg=k)


# ---------------------------------------------------------------------------
# registry + resolution


def test_registry_has_the_algorithm_family():
    for name in ("fedzo", "fedavg", "fedprox", "feddyn", "scaffold"):
        assert strategy_mod.get(name).name == name


def test_unknown_strategy_lists_registered_names():
    with pytest.raises(ValueError, match="unknown strategy 'sgd'"):
        strategy_mod.get("sgd")
    with pytest.raises(ValueError, match="fedprox"):
        strategy_mod.get("sgd")


def test_unknown_strategy_fails_at_round_step_build():
    clients, store = _setup()
    with pytest.raises(ValueError, match="registered strategies"):
        sim.make_round_step(softmax_loss, _cfg(strategy="fedsgd"))


def test_deprecated_algo_kwarg_warns():
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)
    with pytest.warns(DeprecationWarning, match="algo= string kwarg is "
                                                "deprecated"):
        sim.make_round_step(softmax_loss, _cfg(), algo="fedavg")
    with pytest.warns(DeprecationWarning):
        sim.run_experiment(softmax_loss, p0, store, _cfg(), 1, algo="fedzo",
                           donate=False)


def test_explicit_strategy_beats_cfg_and_accepts_instances():
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)
    cfg = _cfg(strategy="fedavg")
    res = sim.run_experiment(softmax_loss, p0, store, cfg, 2,
                             strategy="fedzo", donate=False)
    assert res.strategy == "fedzo"
    res2 = sim.run_experiment(softmax_loss, p0, store, cfg, 2,
                              strategy=strategy_mod.get("fedzo"),
                              donate=False)
    _assert_results_bitequal(res, res2)


# ---------------------------------------------------------------------------
# reductions: μ=0 / α=0 are bit-exact FedZO; positive values move


def test_fedprox_mu_zero_is_bitexact_fedzo():
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)
    ref = sim.run_experiment(softmax_loss, p0, store, _cfg(), 4, donate=False)
    got = sim.run_experiment(softmax_loss, p0, store,
                             _cfg(strategy="fedprox"), 4, donate=False)
    _assert_results_bitequal(ref, got)


def test_feddyn_alpha_zero_is_bitexact_fedzo():
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)
    ref = sim.run_experiment(softmax_loss, p0, store, _cfg(), 4, donate=False)
    got = sim.run_experiment(softmax_loss, p0, store,
                             _cfg(strategy="feddyn"), 4, donate=False)
    _assert_results_bitequal(ref, got)
    assert got.strategy_state is None  # α=0 carries no duals


@pytest.mark.parametrize("name,kw", [
    ("fedprox", {"prox_mu": 0.5}),
    ("feddyn", {"dyn_alpha": 0.5}),
    ("scaffold", {}),
])
def test_positive_coefficients_change_the_trajectory(name, kw):
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)
    ref = sim.run_experiment(softmax_loss, p0, store, _cfg(), 3, donate=False)
    got = sim.run_experiment(softmax_loss, p0, store,
                             _cfg(strategy=name, **kw), 3, donate=False)
    assert any(
        (np.asarray(a) != np.asarray(b)).any()
        for a, b in zip(jax.tree.leaves(ref.params),
                        jax.tree.leaves(got.params)))
    for leaf in jax.tree.leaves(got.params):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_fedprox_composes_with_server_momentum():
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)
    cfg = _cfg(strategy="fedprox", prox_mu=0.1, server_momentum=0.9)
    res = sim.run_experiment(softmax_loss, p0, store, cfg, 3, donate=False)
    assert res.momentum is not None
    assert np.isfinite(np.asarray(res.metrics["mean_local_loss"])).all()


@pytest.mark.parametrize("name", ["feddyn", "scaffold"])
def test_stateful_strategies_reject_server_momentum(name):
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)
    cfg = _cfg(strategy=name, dyn_alpha=0.1, server_momentum=0.9)
    with pytest.raises(ValueError, match="does not compose"):
        sim.run_experiment(softmax_loss, p0, store, cfg, 2, donate=False)
    with pytest.raises(ValueError, match="does not compose"):
        FedServer(softmax_loss, p0, clients, cfg, store=store)


# ---------------------------------------------------------------------------
# hook strategies vs custom round_fns / host-only servers


def test_hook_strategies_reject_custom_round_fn():
    def fake_round(*a, **k):  # pragma: no cover - must not be called
        raise AssertionError

    with pytest.raises(ValueError, match="custom round_fn"):
        sim.make_round_step(softmax_loss,
                            _cfg(strategy="fedprox", prox_mu=0.1),
                            round_fn=fake_round)


def test_hook_strategies_need_a_store_on_the_server():
    clients, _ = _setup()
    p0 = softmax_init(None, 24, 4)
    with pytest.raises(ValueError, match="store=ClientStore"):
        FedServer(softmax_loss, p0, clients,
                  _cfg(strategy="scaffold"))


def test_surrogate_requires_wide_phase():
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)
    cfg = _cfg(direction_conv="surrogate")
    with pytest.raises(ValueError, match="batch_directions"):
        sim.run_experiment(softmax_loss, p0, store, cfg, 2, donate=False)


# ---------------------------------------------------------------------------
# durability: strategy state survives chunking and SIGKILL-and-resume


@pytest.mark.parametrize("name,kw", [
    ("scaffold", {}),
    ("feddyn", {"dyn_alpha": 0.1}),
])
def test_chunked_matches_single_shot_with_state(name, kw, tmp_path):
    clients, store = _setup()
    cfg = _cfg(strategy=name, **kw)
    p0 = softmax_init(None, 24, 4)
    single = sim.run_experiment(softmax_loss, p0, store, cfg, 6,
                                donate=False)
    chunked = sim.run_experiment(
        softmax_loss, p0, store, cfg, 6, donate=False, checkpoint_every=4,
        checkpoint_dir=str(tmp_path / name))
    _assert_results_bitequal(single, chunked)
    _assert_trees_bitequal(single.strategy_state, chunked.strategy_state)


def test_kill_and_resume_restores_client_state_bitexact(tmp_path):
    """The preemption drill with per-client controls in the carry: stop
    scaffold after ONE segment (state survives only on disk), resume in a
    FRESH call, finish bit-identical to the uninterrupted run — including
    every client's control variate."""
    clients, store = _setup()
    cfg = _cfg(strategy="scaffold")
    p0 = softmax_init(None, 24, 4)
    d = str(tmp_path / "scaffold")
    single = sim.run_experiment(softmax_loss, p0, store, cfg, 6,
                                donate=False)
    part = sim.run_experiment(softmax_loss, p0, store, cfg, 6, donate=False,
                              checkpoint_every=2, checkpoint_dir=d,
                              max_segments=1)
    assert part.rounds == 2
    resumed = sim.run_experiment(softmax_loss, p0, store, cfg, 6,
                                 donate=False, checkpoint_every=2,
                                 checkpoint_dir=d, resume=True)
    assert resumed.rounds == 6
    _assert_results_bitequal(single, resumed)
    _assert_trees_bitequal(single.strategy_state, resumed.strategy_state)
    # the snapshot meta names the strategy
    from repro.checkpoint import checkpoint as ckpt
    with open(os.path.join(ckpt.latest_run_state(d), "meta.json")) as f:
        import json
        md = json.load(f)["meta"]
    assert md["strategy"] == "scaffold" and md["algo"] == "scaffold"


# ---------------------------------------------------------------------------
# surrogate estimator: ≤ half the queries, matched loss


def test_surrogate_halves_queries_at_matched_loss():
    """The FedZOO-style surrogate phase pays ceil(b2/2) fresh queries per
    iterate (vs b2) and still lands within a whisker of the plain wide
    FedZO run on the softmax golden task — matched final-window loss and
    matched final accuracy."""
    from repro.workloads import neural

    task = neural.make_task("softmax", n_train=320, n_test=96, n_clients=6,
                            n_features=24, n_classes=4, alpha=0.5)
    base = neural.default_config(
        task, n_participating=3, local_iters=2, b1=8, b2=4, lr=5e-2,
        mu=1e-3, seed=11, batch_directions=True, direction_conv="block",
        prng_impl="unsafe_rbg")
    surr = dataclasses.replace(base, direction_conv="surrogate")
    assert fedzo.surrogate_queries(surr) * 2 <= base.b2
    res_w = neural.run(task, base, 24, eval_every=4, eval_rows=96,
                       donate=False)
    res_s = neural.run(task, surr, 24, eval_every=4, eval_rows=96,
                       donate=False)
    lw = np.asarray(res_w.metrics["mean_local_loss"])
    ls = np.asarray(res_s.metrics["mean_local_loss"])
    assert ls[-4:].mean() <= lw[-4:].mean() * 1.35
    assert ls[-4:].mean() < 0.5 * ls[0]          # it genuinely trains
    acc_w = float(np.asarray(res_w.evals["test_acc"])[-1])
    acc_s = float(np.asarray(res_s.evals["test_acc"])[-1])
    assert acc_s >= acc_w - 0.05


def test_surrogate_fraction_knob_sets_query_budget():
    cfg = _cfg(b2=20, surrogate_fraction=0.25)
    assert fedzo.surrogate_queries(cfg) == 5
    assert fedzo.surrogate_queries(_cfg(b2=3, surrogate_fraction=0.0)) == 1


# ---------------------------------------------------------------------------
# sweeps + history carry the strategy name


def test_sweep_strategy_axis_and_csv_tags(tmp_path):
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)
    cfg = _cfg(prox_mu=0.5)
    grid = sweep.scenario_grid(strategy=("fedzo", "fedprox"),
                               lr=(1e-2, 2e-2))
    out = str(tmp_path / "sweep.csv")
    recs = sweep.run_sweep(softmax_loss, p0, store, cfg, grid, 3,
                           out_csv=out)
    assert sorted(r["strategy"] for r in recs) == \
        ["fedprox", "fedprox", "fedzo", "fedzo"]
    by = {(r["strategy"], r["scenario"]["lr"]):
          r["metrics"]["mean_local_loss"] for r in recs}
    assert (by[("fedzo", 1e-2)] != by[("fedprox", 1e-2)]).any()
    text = open(out).read().splitlines()
    assert text[0] == "scenario,round,metric,value"
    tags = {line.split(",")[0] for line in text[1:]}
    assert any("strategy=fedprox" in t for t in tags)
    assert any("strategy=fedzo" in t for t in tags)


def test_sweep_without_strategy_axis_still_tags_rows(tmp_path):
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)
    out = str(tmp_path / "plain.csv")
    sweep.run_sweep(softmax_loss, p0, store, _cfg(),
                    sweep.scenario_grid(lr=(1e-2,)), 2, out_csv=out)
    rows = open(out).read().splitlines()[1:]
    assert rows and all(r.startswith("lr=0.01;strategy=fedzo,")
                        for r in rows)


def test_history_rows_carry_strategy_name():
    clients, store = _setup()
    p0 = softmax_init(None, 24, 4)
    res = sim.run_experiment(softmax_loss, p0, store,
                             _cfg(strategy="feddyn", dyn_alpha=0.1), 3,
                             donate=False)
    rows = res.history()
    assert len(rows) == 3
    assert all(r["strategy"] == "feddyn" for r in rows)
    assert rows == sim.history(res)


# ---------------------------------------------------------------------------
# baselines route through the shared direction conventions (satellite fix)


def test_baselines_counter_convention_pinned():
    """dzopa/zone_s/zo_sgd honor the counter Threefry convention (they used
    to silently drop it); trajectories pinned against the jax build CI
    pins, and each counter run must differ from its tree-convention twin."""
    x, y = make_classification(64, 12, 3, seed=4)
    p0 = softmax_init(None, 12, 3)
    batch = {"x": x[:16], "y": y[:16]}
    rng = jax.random.key(9)

    p, base_l = baselines.zo_sgd_step(softmax_loss, p0, batch, rng, lr=1e-2,
                                      mu=1e-3, b2=3, conv="counter")
    np.testing.assert_allclose(
        np.asarray(p["w"])[0, :2], [-0.0090320855, 0.0380805880], rtol=1e-5)
    np.testing.assert_allclose(float(base_l), 1.0986123, rtol=1e-6)
    p_tree, _ = baselines.zo_sgd_step(softmax_loss, p0, batch, rng, lr=1e-2,
                                      mu=1e-3, b2=3)
    assert (np.asarray(p_tree["w"]) != np.asarray(p["w"])).any()

    cfg = FedZOConfig(n_devices=4, lr=1e-2, mu=1e-3, b2=3,
                      direction_conv="counter")
    cp = jax.tree.map(lambda l: jnp.stack([l] * 4), p0)
    cb = {"x": x.reshape(4, 16, 12), "y": y.reshape(4, 16)}
    crngs = jax.random.split(jax.random.key(7), 4)
    mixed, ml = baselines.dzopa_round(softmax_loss, cp, cb, crngs, cfg)
    np.testing.assert_allclose(
        np.asarray(mixed["w"])[0, 0, :2], [-0.0004312342, -0.0007937130],
        rtol=1e-4)
    cfg_tree = dataclasses.replace(cfg, direction_conv="tree")
    mixed_t, _ = baselines.dzopa_round(softmax_loss, cp, cb, crngs, cfg_tree)
    assert (np.asarray(mixed_t["w"]) != np.asarray(mixed["w"])).any()

    pz, _ = baselines.zone_s_round(softmax_loss, p0, batch, rng, rho=500.0,
                                   mu=1e-3, b2=3, conv="counter")
    np.testing.assert_allclose(
        np.asarray(pz["w"])[0, :2], [-0.0018064174, 0.0076161181], rtol=1e-5)
