"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
jsonl records. Usage: python results/make_tables.py > results/tables.md"""
import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
ARCHS = ["rwkv6-7b", "llama-3.2-vision-90b", "deepseek-v3-671b",
         "seamless-m4t-large-v2", "hymba-1.5b", "qwen3-4b", "qwen1.5-32b",
         "gemma-2b", "qwen3-moe-30b-a3b", "qwen2-0.5b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    best = {}
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun_*.jsonl"))):
        for line in open(f):
            r = json.loads(line)
            k = (r["arch"], r["shape"], r["multi_pod"])
            if "error" not in r:
                best[k] = r  # last ok record wins
            elif k not in best:
                best[k] = r
    return best


def gib(b):
    return f"{b/2**30:.1f}"


def s3(x):
    return f"{x:.4f}" if x >= 1e-4 else f"{x:.2e}"


def main():
    best = load()
    print("### Dry-run matrix (compile status, per-device memory)\n")
    print("| arch | shape | 16×16 mem GiB (fits?) | 2×16×16 mem GiB (fits?) |")
    print("|---|---|---|---|")
    for a in ARCHS:
        for sh in SHAPES:
            cells = []
            for mp in (False, True):
                r = best.get((a, sh, mp))
                if r is None:
                    cells.append("—")
                elif "error" in r:
                    cells.append("FAIL")
                else:
                    m = r["memory"]["total_bytes_per_device"]
                    cells.append(f"{gib(m)} ({'✓' if r['hbm_ok'] else '✗'})")
            print(f"| {a} | {sh} | {cells[0]} | {cells[1]} |")

    print("\n### Roofline (single-pod 16×16, per-chip; v5e constants)\n")
    print("`cost_analysis` counts scan/while bodies once, so HLO FLOPs/bytes"
          " under-count by the layer trip count. We correct with"
          " κ = max(1, analytic_ZO_FLOPs / HLO_FLOPs): compute uses the"
          " analytic count directly; memory bytes are scaled by κ (layer"
          " bytes scale with layer flops); collectives are trip-count-"
          "weighted at parse time and need no correction.\n")
    print("| arch | shape | compute s | memory s (κ-adj) | collective s | "
          "dominant | κ |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for sh in SHAPES:
            r = best.get((a, sh, False))
            if r is None or "error" in r:
                print(f"| {a} | {sh} | FAIL | | | | |")
                continue
            ro = r["roofline_s"]
            hlo = r["hlo_flops_per_device"]
            analytic = r["zo_model_flops_total"] / 256
            kappa = max(1.0, analytic / hlo) if hlo else 1.0
            comp = max(analytic, hlo) / 197e12
            mem = ro["memory_s"] * kappa
            coll = ro["collective_s"]
            dom = {"compute": comp, "memory": mem, "collective": coll}
            name = max(dom, key=dom.get)
            print(f"| {a} | {sh} | {s3(comp)} | {s3(mem)} | {s3(coll)} | "
                  f"**{name}** | {kappa:.1f} |")

    print("\n### Collective breakdown (single-pod, trip-count-weighted "
          "GiB/device)\n")
    print("| arch | shape | all-reduce | all-gather | reduce-scatter | "
          "all-to-all | permute |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for sh in SHAPES:
            r = best.get((a, sh, False))
            if r is None or "error" in r:
                continue
            c = r["collective_bytes_per_device"]
            print(f"| {a} | {sh} | {gib(c['all-reduce'])} | "
                  f"{gib(c['all-gather'])} | {gib(c['reduce-scatter'])} | "
                  f"{gib(c['all-to-all'])} | {gib(c['collective-permute'])} |")

    print("\n### Multi-pod (2×16×16): round program + dense-uplink "
          "aggregation program\n")
    print("| arch | shape | round coll GiB/dev | agg-program coll GiB/dev | "
          "mem GiB (fits?) |")
    print("|---|---|---|---|---|")
    for a in ARCHS:
        for sh in SHAPES:
            r = best.get((a, sh, True))
            if r is None or "error" in r:
                continue
            c = sum(r["collective_bytes_per_device"].values())
            agg = r.get("delta_agg_program")
            ac = gib(agg["collective_total_bytes"]) if agg else "—"
            m = r["memory"]["total_bytes_per_device"]
            print(f"| {a} | {sh} | {gib(c)} | {ac} | "
                  f"{gib(m)} ({'✓' if r['hbm_ok'] else '✗'}) |")


if __name__ == "__main__":
    main()
