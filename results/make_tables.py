"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
jsonl records. Usage: python results/make_tables.py > results/tables.md

``--bench`` instead renders the persisted benchmark trajectory from the
``results/BENCH_*.json`` snapshots (written by ``benchmarks/run.py`` via
``obs.save_bench``): one table per suite, the current rows beside the same
rows at each retained history point (newest last), so per-PR perf drift
reads straight off the row."""
import argparse
import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
ARCHS = ["rwkv6-7b", "llama-3.2-vision-90b", "deepseek-v3-671b",
         "seamless-m4t-large-v2", "hymba-1.5b", "qwen3-4b", "qwen1.5-32b",
         "gemma-2b", "qwen3-moe-30b-a3b", "qwen2-0.5b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    best = {}
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun_*.jsonl"))):
        for line in open(f):
            r = json.loads(line)
            k = (r["arch"], r["shape"], r["multi_pod"])
            if "error" not in r:
                best[k] = r  # last ok record wins
            elif k not in best:
                best[k] = r
    return best


def gib(b):
    return f"{b/2**30:.1f}"


def s3(x):
    return f"{x:.4f}" if x >= 1e-4 else f"{x:.2e}"


def _fmt_us(v):
    return f"{v:.1f}" if isinstance(v, (int, float)) else "—"


def _fmt_derived(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return "—" if v is None else str(v)


def bench_tables(out_dir=None):
    """The BENCH_*.json perf trajectory as markdown: per suite, a table of
    ``row | <older snapshots µs...> | current µs | derived`` — the µs
    trajectory of every benchmark row, oldest history column first."""
    try:
        from repro.obs import bench as obs_bench
        snaps = obs_bench.load_benches(out_dir or HERE)
    except ImportError:
        # repro not importable (e.g. bare results/ checkout): read raw
        snaps = {}
        for p in sorted(glob.glob(os.path.join(out_dir or HERE,
                                               "BENCH_*.json"))):
            with open(p) as f:
                snap = json.load(f)
            snaps[snap.get("suite",
                           os.path.basename(p)[6:-5])] = snap
    if not snaps:
        print("no BENCH_*.json snapshots found — run "
              "`python -m benchmarks.run --quick` first")
        return
    for suite, snap in snaps.items():
        hist = snap.get("history", [])
        print(f"### Bench trajectory: {suite} "
              f"(jax {snap.get('jax_version')}, "
              f"{len(hist)} history point(s))\n")
        cols = [f"t-{len(hist) - i}" for i in range(len(hist))] + ["now"]
        print("| row | " + " µs | ".join(cols) + " µs | derived (now) |")
        print("|---" * (len(cols) + 2) + "|")
        rows_now = {r["name"]: r for r in snap.get("rows", [])}
        points = [{r["name"]: r for r in h.get("rows") or []}
                  for h in hist] + [rows_now]
        for name in rows_now:
            cells = [_fmt_us(pt[name]["us_per_call"]) if name in pt
                     else "—" for pt in points]
            print(f"| {name} | " + " | ".join(cells) +
                  f" | {_fmt_derived(rows_now[name].get('derived'))} |")
        print()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", action="store_true",
                    help="render the BENCH_*.json perf trajectory instead")
    ap.add_argument("--out-dir", default=None,
                    help="snapshot directory (default: results/)")
    args = ap.parse_args()
    if args.bench:
        bench_tables(args.out_dir)
        return
    best = load()
    print("### Dry-run matrix (compile status, per-device memory)\n")
    print("| arch | shape | 16×16 mem GiB (fits?) | 2×16×16 mem GiB (fits?) |")
    print("|---|---|---|---|")
    for a in ARCHS:
        for sh in SHAPES:
            cells = []
            for mp in (False, True):
                r = best.get((a, sh, mp))
                if r is None:
                    cells.append("—")
                elif "error" in r:
                    cells.append("FAIL")
                else:
                    m = r["memory"]["total_bytes_per_device"]
                    cells.append(f"{gib(m)} ({'✓' if r['hbm_ok'] else '✗'})")
            print(f"| {a} | {sh} | {cells[0]} | {cells[1]} |")

    print("\n### Roofline (single-pod 16×16, per-chip; v5e constants)\n")
    print("`cost_analysis` counts scan/while bodies once, so HLO FLOPs/bytes"
          " under-count by the layer trip count. We correct with"
          " κ = max(1, analytic_ZO_FLOPs / HLO_FLOPs): compute uses the"
          " analytic count directly; memory bytes are scaled by κ (layer"
          " bytes scale with layer flops); collectives are trip-count-"
          "weighted at parse time and need no correction.\n")
    print("| arch | shape | compute s | memory s (κ-adj) | collective s | "
          "dominant | κ |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for sh in SHAPES:
            r = best.get((a, sh, False))
            if r is None or "error" in r:
                print(f"| {a} | {sh} | FAIL | | | | |")
                continue
            ro = r["roofline_s"]
            hlo = r["hlo_flops_per_device"]
            analytic = r["zo_model_flops_total"] / 256
            kappa = max(1.0, analytic / hlo) if hlo else 1.0
            comp = max(analytic, hlo) / 197e12
            mem = ro["memory_s"] * kappa
            coll = ro["collective_s"]
            dom = {"compute": comp, "memory": mem, "collective": coll}
            name = max(dom, key=dom.get)
            print(f"| {a} | {sh} | {s3(comp)} | {s3(mem)} | {s3(coll)} | "
                  f"**{name}** | {kappa:.1f} |")

    print("\n### Collective breakdown (single-pod, trip-count-weighted "
          "GiB/device)\n")
    print("| arch | shape | all-reduce | all-gather | reduce-scatter | "
          "all-to-all | permute |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for sh in SHAPES:
            r = best.get((a, sh, False))
            if r is None or "error" in r:
                continue
            c = r["collective_bytes_per_device"]
            print(f"| {a} | {sh} | {gib(c['all-reduce'])} | "
                  f"{gib(c['all-gather'])} | {gib(c['reduce-scatter'])} | "
                  f"{gib(c['all-to-all'])} | {gib(c['collective-permute'])} |")

    print("\n### Multi-pod (2×16×16): round program + dense-uplink "
          "aggregation program\n")
    print("| arch | shape | round coll GiB/dev | agg-program coll GiB/dev | "
          "mem GiB (fits?) |")
    print("|---|---|---|---|---|")
    for a in ARCHS:
        for sh in SHAPES:
            r = best.get((a, sh, True))
            if r is None or "error" in r:
                continue
            c = sum(r["collective_bytes_per_device"].values())
            agg = r.get("delta_agg_program")
            ac = gib(agg["collective_total_bytes"]) if agg else "—"
            m = r["memory"]["total_bytes_per_device"]
            print(f"| {a} | {sh} | {gib(c)} | {ac} | "
                  f"{gib(m)} ({'✓' if r['hbm_ok'] else '✗'}) |")


if __name__ == "__main__":
    main()
