"""Fused RMSNorm — Pallas, TPU target.

One pass per row block: mean-square, rsqrt, scale — XLA emits this as
separate reduce + broadcast-multiply passes; the fusion halves HBM reads for
the norm-heavy pre-norm transformer stacks. Rows are tiled (block_rows, D)
with D kept whole in VMEM (d_model ≤ 8192 → ≤ 4 MiB fp32 per 128-row block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps=1e-6, block_rows=128, interpret=False):
    """x [R, D] (rows divisible by block_rows — ops.py pads), scale [D]."""
    R, D = x.shape
    assert R % block_rows == 0
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, scale)
