"""Fused ZO parameter-streaming kernel (Pallas, TPU target).

FedZO's hot loop streams the whole parameter vector through the VPU several
times per estimator sample:

  perturb        x ← x + μ·v                 (before the perturbed forward)
  unperturb +    x ← x + a·v_n + b·v_{n+1}   (MeZO-style fused transition to
   next perturb                               the next direction: ONE pass
                                              over HBM instead of two)
  update         x ← x − η·Σ_n c_n v_n       (replayed from seeds)

These are pure HBM-bandwidth ops; the kernel's job is fusion (XLA will not
fuse across the loss-forward boundary) and explicit VMEM tiling. Block size
is 8·128·64 = 64Ki elements → 256 KiB fp32 per stream, 3 streams ≈ 768 KiB of
the ~16 MiB VMEM budget, leaving room for double buffering.

Inputs are the flattened 1-D parameter leaf (padded to a block multiple by
ops.py). ``zo_axpy2(x, u, v, a, b) = x + a·u + b·v`` is the general form;
``a`` and ``b`` are scalars prefetched to SMEM.

Flat-buffer hot path (DESIGN.md §7): on top of the materialized-direction
axpy kernels, this module carries the *in-kernel direction regeneration*
convention. A direction element is a pure function of
``(round_key, n, flat_index)`` via a counter-based Threefry-2x32
implemented in plain jnp uint32 ops — the same code path runs inside a
Pallas kernel body and outside it, so the perturb end (``zo_walk``), the
replay end (``zo_replay``) and the pure-JAX reference
(``counter_direction`` in core/estimator.py) are bit-identical. That is
what preserves the seed-compression wire format of core/seedcomm.py:
the wire message stays (key, coeffs) and every receiver regenerates the
directions from the counter convention.

- ``zo_walk``     x + a·v(n_prev) + b·v(n_next): the MeZO-style fused
                  transition x+μv_n → x+μv_{n+1} (a=−μ, b=+μ). This is
                  ``zo_axpy2`` with u, v generated in VMEM instead of
                  streamed from HBM: ONE read + ONE write of x per
                  direction, zero direction traffic.
- ``zo_replay``   x + Σ_n c_n·v_n accumulated in VMEM per block, one HBM
                  writeback per block — the whole b2-direction update in a
                  single pass over the parameter buffer.
- ``zo_dirnorms`` per-direction squared norms ‖g_n‖² (for the sphere
                  estimator's normalization) with ~zero HBM traffic: the
                  directions live only in VMEM, the output is [b2] floats.

The 2-D layout [rows, 128] (lane dim last) keeps the kernels inside the
TPU tiling constraints; ops.py does the flat↔2-D reshape + padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 128 * 64  # 64Ki elements per grid step
LANES = 128
BLOCK_ROWS = BLOCK // LANES  # 512 rows of 128 lanes per grid step


def _axpy2_kernel(ab_ref, x_ref, u_ref, v_ref, o_ref):
    a = ab_ref[0]
    b = ab_ref[1]
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    o_ref[...] = (x + a * u + b * v).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def zo_axpy2(x, u, v, ab, *, interpret=False, block=BLOCK):
    """x + ab[0]·u + ab[1]·v on flat arrays (len divisible by ``block``).

    x: [N] any float dtype; u, v: [N] same-or-f32; ab: [2] f32 scalars.
    """
    (n,) = x.shape
    assert n % block == 0, (n, block)
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _axpy2_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((2,), lambda i: (0,)), spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(ab, x, u, v)


def _axpy_kernel(a_ref, x_ref, u_ref, o_ref):
    a = a_ref[0]
    o_ref[...] = (x_ref[...].astype(jnp.float32)
                  + a * u_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def zo_axpy(x, u, a, *, interpret=False, block=BLOCK):
    """x + a[0]·u on flat arrays."""
    (n,) = x.shape
    assert n % block == 0, (n, block)
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _axpy_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,)), spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(a, x, u)


# ---------------------------------------------------------------------------
# counter-based direction convention (round_key, n, flat_index) → fp32
#
# Threefry-2x32 in plain jnp uint32 ops: the identical expression graph runs
# inside Pallas kernel bodies (VPU integer ops) and in ordinary traced JAX,
# so perturb, replay and reference ends agree bit-for-bit.

_THREEFRY_PARITY = 0x1BD11BDA
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))


def _rotl32(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0, k1, c0, c1):
    """Threefry-2x32, 20 rounds. All args uint32 (scalars broadcast)."""
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_THREEFRY_PARITY))
    x0 = c0 + ks[0]
    x1 = c1 + ks[1]
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = _rotl32(x1, r)
            x1 = x0 ^ x1
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def _bits_to_normal(b0, b1):
    """Box-Muller on two uint32 bit planes → one N(0,1) fp32 per element."""
    u1 = (b0 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0 ** -24) \
        + jnp.float32(2.0 ** -25)                         # (0, 1)
    u2 = (b1 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0 ** -24)
    r = jnp.sqrt(jnp.float32(-2.0) * jnp.log(u1))
    return r * jnp.cos(jnp.float32(2.0 * 3.14159265358979323846) * u2)


def counter_gen(kind: str, k0, k1, n, idx):
    """Direction element(s) v_n[idx] for kind ∈ {normal, sign}.

    k0, k1: uint32 round-key words; n: direction index (uint32 scalar);
    idx: uint32 flat element indices, any shape. This IS the shared
    convention — every producer and consumer of a direction calls it.
    """
    b0, b1 = threefry2x32(k0, k1, n, idx)
    if kind == "sign":
        return jnp.where((b0 & jnp.uint32(1)) > 0,
                         jnp.float32(1.0), jnp.float32(-1.0))
    if kind == "normal":
        return _bits_to_normal(b0, b1)
    raise ValueError(f"unknown counter direction kind {kind!r}")


def counter_direction_flat(key2, n, count, *, kind="normal", start=0):
    """Pure-JAX (non-kernel) form: v_n[start:start+count] as fp32 [count].

    ``key2`` is ``jax.random.key_data(key)`` (uint32 [2]). Bit-identical to
    what the kernels below generate in VMEM for the same (key, n, index).
    """
    idx = (jnp.uint32(start)
           + jnp.arange(count, dtype=jnp.uint32))
    return counter_gen(kind, key2[0], key2[1],
                       jnp.asarray(n).astype(jnp.uint32), idx)


def _block_idx(i, rows, lanes):
    """uint32 flat indices of grid block i of a [R, lanes] view."""
    row = jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1)
    return ((i * rows + row) * lanes + col).astype(jnp.uint32)


# -- zo_walk: fused perturbation transition --------------------------------


def _walk_kernel(key_ref, nn_ref, ab_ref, x_ref, o_ref, *, kind):
    i = pl.program_id(0)
    rows, lanes = x_ref.shape
    idx = _block_idx(i, rows, lanes)
    k0, k1 = key_ref[0], key_ref[1]
    g_prev = counter_gen(kind, k0, k1, nn_ref[0].astype(jnp.uint32), idx)
    g_next = counter_gen(kind, k0, k1, nn_ref[1].astype(jnp.uint32), idx)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = (x + ab_ref[0] * g_prev + ab_ref[1] * g_next).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kind", "interpret", "block_rows"))
def zo_walk(x2, key2, nn, ab, *, kind="normal", interpret=False,
            block_rows=BLOCK_ROWS):
    """x + ab[0]·v(nn[0]) + ab[1]·v(nn[1]) with in-kernel direction regen.

    x2: [R, 128] (R divisible by block_rows); key2 uint32 [2]; nn int32 [2]
    direction indices; ab fp32 [2] coefficients (pass ab[0]=0 for the first
    perturbation of a walk). One read + one write of x: 1 HBM pass.
    """
    r, lanes = x2.shape
    assert lanes == LANES and r % block_rows == 0, (x2.shape, block_rows)
    grid = (r // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    small = lambda shape: pl.BlockSpec(shape, lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_walk_kernel, kind=kind),
        grid=grid,
        in_specs=[small((2,)), small((2,)), small((2,)), spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r, LANES), x2.dtype),
        interpret=interpret,
    )(key2, nn, ab, x2)


# -- zo_replay: single-pass seed-replay update ------------------------------


def _replay_kernel(key_ref, c_ref, x_ref, o_ref, *, kind, b2):
    i = pl.program_id(0)
    rows, lanes = x_ref.shape
    idx = _block_idx(i, rows, lanes)
    k0, k1 = key_ref[0], key_ref[1]

    def body(n, acc):
        g = counter_gen(kind, k0, k1, n.astype(jnp.uint32), idx)
        return acc + c_ref[n] * g

    acc = jax.lax.fori_loop(0, b2, body,
                            jnp.zeros((rows, lanes), jnp.float32))
    o_ref[...] = (x_ref[...].astype(jnp.float32) + acc).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kind", "interpret", "block_rows"))
def zo_replay(x2, key2, coeffs, *, kind="normal", interpret=False,
              block_rows=BLOCK_ROWS):
    """x + Σ_n coeffs[n]·v_n — the whole b2-direction update in ONE pass.

    The grid walks blocks; per block all b2 directions are regenerated and
    accumulated in VMEM (fp32), then written back once. coeffs: fp32 [b2]
    *effective* coefficients (caller folds in scale, 1/b2 and any
    per-direction norm factor).
    """
    r, lanes = x2.shape
    (b2,) = coeffs.shape
    assert lanes == LANES and r % block_rows == 0, (x2.shape, block_rows)
    grid = (r // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    small = lambda shape: pl.BlockSpec(shape, lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_replay_kernel, kind=kind, b2=b2),
        grid=grid,
        in_specs=[small((2,)), small((b2,)), spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r, LANES), x2.dtype),
        interpret=interpret,
    )(key2, coeffs, x2)


# -- zo_dirnorms: per-direction ‖g_n‖² with no direction HBM traffic --------


def _dirnorm_kernel(key_ref, d_ref, o_ref, *, kind, block_rows):
    n = pl.program_id(0)
    i = pl.program_id(1)
    idx = _block_idx(i, block_rows, LANES)
    g = counter_gen(kind, key_ref[0], key_ref[1],
                    jnp.asarray(n).astype(jnp.uint32), idx)
    g = jnp.where(idx < d_ref[0].astype(jnp.uint32), g, jnp.float32(0.0))
    part = jnp.sum(g * g)

    @pl.when(i == 0)
    def _init():
        o_ref[0] = jnp.float32(0.0)

    o_ref[0] += part


@functools.partial(jax.jit,
                   static_argnames=("b2", "n_pad", "kind", "interpret",
                                    "block_rows"))
def zo_dirnorms(key2, d, *, b2, n_pad, kind="normal", interpret=False,
                block_rows=BLOCK_ROWS):
    """[b2] squared norms ‖g_n[:d]‖² under the counter convention.

    d: int32 scalar (valid length; padding indices ≥ d are masked out).
    n_pad: padded total element count (block multiple). HBM traffic is just
    the [b2] output — directions never leave VMEM.
    """
    assert n_pad % (block_rows * LANES) == 0, (n_pad, block_rows)
    grid = (b2, n_pad // (block_rows * LANES))
    d_arr = jnp.asarray([d], jnp.int32)
    return pl.pallas_call(
        functools.partial(_dirnorm_kernel, kind=kind, block_rows=block_rows),
        grid=grid,
        in_specs=[pl.BlockSpec((2,), lambda n, i: (0,)),
                  pl.BlockSpec((1,), lambda n, i: (0,))],
        out_specs=pl.BlockSpec((1,), lambda n, i: (n,)),
        out_shape=jax.ShapeDtypeStruct((b2,), jnp.float32),
        interpret=interpret,
    )(key2, d_arr)
