"""Fused ZO parameter-streaming kernel (Pallas, TPU target).

FedZO's hot loop streams the whole parameter vector through the VPU several
times per estimator sample:

  perturb        x ← x + μ·v                 (before the perturbed forward)
  unperturb +    x ← x + a·v_n + b·v_{n+1}   (MeZO-style fused transition to
   next perturb                               the next direction: ONE pass
                                              over HBM instead of two)
  update         x ← x − η·Σ_n c_n v_n       (replayed from seeds)

These are pure HBM-bandwidth ops; the kernel's job is fusion (XLA will not
fuse across the loss-forward boundary) and explicit VMEM tiling. Block size
is 8·128·64 = 64Ki elements → 256 KiB fp32 per stream, 3 streams ≈ 768 KiB of
the ~16 MiB VMEM budget, leaving room for double buffering.

Inputs are the flattened 1-D parameter leaf (padded to a block multiple by
ops.py). ``zo_axpy2(x, u, v, a, b) = x + a·u + b·v`` is the general form;
``a`` and ``b`` are scalars prefetched to SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 128 * 64  # 64Ki elements per grid step


def _axpy2_kernel(ab_ref, x_ref, u_ref, v_ref, o_ref):
    a = ab_ref[0]
    b = ab_ref[1]
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    o_ref[...] = (x + a * u + b * v).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def zo_axpy2(x, u, v, ab, *, interpret=False, block=BLOCK):
    """x + ab[0]·u + ab[1]·v on flat arrays (len divisible by ``block``).

    x: [N] any float dtype; u, v: [N] same-or-f32; ab: [2] f32 scalars.
    """
    (n,) = x.shape
    assert n % block == 0, (n, block)
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _axpy2_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((2,), lambda i: (0,)), spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(ab, x, u, v)


def _axpy_kernel(a_ref, x_ref, u_ref, o_ref):
    a = a_ref[0]
    o_ref[...] = (x_ref[...].astype(jnp.float32)
                  + a * u_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def zo_axpy(x, u, a, *, interpret=False, block=BLOCK):
    """x + a[0]·u on flat arrays."""
    (n,) = x.shape
    assert n % block == 0, (n, block)
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _axpy_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,)), spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(a, x, u)
