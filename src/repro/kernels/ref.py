"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def axpy2_ref(x, u, v, ab):
    out = (x.astype(jnp.float32) + ab[0] * u.astype(jnp.float32)
           + ab[1] * v.astype(jnp.float32))
    return out.astype(x.dtype)


def axpy_ref(x, u, a):
    return (x.astype(jnp.float32) + a[0] * u.astype(jnp.float32)).astype(x.dtype)


def attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """Naive full-materialization attention. q [B, Hq, Sq, D]; k/v [B, Hkv, Sk, D]."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def zo_walk_ref(x2, key2, nn, ab, *, kind="normal"):
    """Oracle for zo_walk: elementwise, so whole-array = per-block bitwise."""
    from repro.kernels.zo_axpy import counter_gen
    r, lanes = x2.shape
    idx = jnp.arange(r * lanes, dtype=jnp.uint32).reshape(r, lanes)
    gp = counter_gen(kind, key2[0], key2[1], nn[0].astype(jnp.uint32), idx)
    gn = counter_gen(kind, key2[0], key2[1], nn[1].astype(jnp.uint32), idx)
    out = x2.astype(jnp.float32) + ab[0] * gp + ab[1] * gn
    return out.astype(x2.dtype)


def zo_replay_ref(x2, key2, coeffs, *, kind="normal"):
    """Oracle for zo_replay: same n-ascending fp32 accumulation order (and
    the same fori_loop structure, so jit compiles the same fp32 adds)."""
    from repro.kernels.zo_axpy import counter_gen
    r, lanes = x2.shape
    idx = jnp.arange(r * lanes, dtype=jnp.uint32).reshape(r, lanes)

    def body(n, acc):
        g = counter_gen(kind, key2[0], key2[1], n.astype(jnp.uint32), idx)
        return acc + coeffs[n] * g

    acc = jax.lax.fori_loop(0, coeffs.shape[0], body,
                            jnp.zeros((r, lanes), jnp.float32))
    return (x2.astype(jnp.float32) + acc).astype(x2.dtype)


def zo_dirnorms_ref(key2, d, b2, n_pad, *, kind="normal", block_rows=None):
    """Oracle for zo_dirnorms: same per-block partial-sum order."""
    from repro.kernels.zo_axpy import BLOCK_ROWS, LANES, counter_gen
    block_rows = block_rows or BLOCK_ROWS
    per = block_rows * LANES
    out = []
    for n in range(b2):
        total = jnp.float32(0.0)
        for i in range(n_pad // per):
            idx = (jnp.uint32(i * per)
                   + jnp.arange(per, dtype=jnp.uint32))
            g = counter_gen(kind, key2[0], key2[1], jnp.uint32(n), idx)
            g = jnp.where(idx < jnp.uint32(d), g, 0.0)
            total = total + jnp.sum(g * g)
        out.append(total)
    return jnp.stack(out)


def aircomp_reduce_ref(x3, scale, d, *, block_rows=None):
    """Oracle for aircomp_reduce: same per-block, row-ascending partial-sum
    order over x3 [M, R, 128]. Returns (mean [R, 128], sq [M])."""
    from repro.kernels.zo_axpy import BLOCK_ROWS, LANES
    block_rows = block_rows or BLOCK_ROWS
    m, r, lanes = x3.shape
    per = block_rows * lanes
    sq = [jnp.float32(0.0)] * m
    mean_blocks = []
    for i in range(r // block_rows):
        idx = jnp.uint32(i * per) + jnp.arange(per, dtype=jnp.uint32)
        valid = (idx < jnp.uint32(d)).reshape(block_rows, lanes)
        acc = jnp.zeros((block_rows, lanes), jnp.float32)
        for mi in range(m):
            x = x3[mi, i * block_rows:(i + 1) * block_rows].astype(jnp.float32)
            sq[mi] = sq[mi] + jnp.sum(jnp.where(valid, x * x, 0.0))
            acc = acc + scale[mi] * x
        mean_blocks.append(acc)
    return jnp.concatenate(mean_blocks, axis=0), jnp.stack(sq)


def rmsnorm_ref(x, scale, *, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
