"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def axpy2_ref(x, u, v, ab):
    out = (x.astype(jnp.float32) + ab[0] * u.astype(jnp.float32)
           + ab[1] * v.astype(jnp.float32))
    return out.astype(x.dtype)


def axpy_ref(x, u, a):
    return (x.astype(jnp.float32) + a[0] * u.astype(jnp.float32)).astype(x.dtype)


def attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """Naive full-materialization attention. q [B, Hq, Sq, D]; k/v [B, Hkv, Sk, D]."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x, scale, *, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
