"""Blocked online-softmax (flash) attention — Pallas, TPU target.

Grid (B, Hq, n_q_blocks, n_kv_blocks); the kv-block axis is innermost and on
TPU executes sequentially per (b, h, iq), so the running max / denominator /
accumulator live in VMEM scratch across kv steps. Block shapes are
(block_q × head_dim) and (block_k × head_dim) with the MXU-aligned default
128×128. GQA is handled in the k/v BlockSpec index maps (kv head = q head //
group) — no materialized head repetition.

Causal and sliding-window masking are positional; fully-masked kv blocks are
not pruned from the grid here (correctness-first; grid pruning via
``pl.Squashed``-style kv bounds is a recorded §Perf follow-up). Validated in
interpret mode against kernels/ref.py on CPU; the TPU path is the target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, block_q, block_k, n_k):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, Dv]
    s = q @ k.T                                          # [bq, bk]

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v
    m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret", "scale"))
def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    block_q=128, block_k=128, interpret=False):
    """q [B, Hq, Sq, D]; k/v [B, Hkv, Sk, D]. Returns [B, Hq, Sq, D].

    Sq/Sk must be multiples of block_q/block_k (ops.py pads).
    """
    from jax.experimental.pallas import tpu as pltpu

    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_k = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
