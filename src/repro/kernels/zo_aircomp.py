"""Fused AirComp aggregation kernel (Pallas, TPU target).

The server side of one AirComp round (core/aircomp.py, paper Eqs. 15-17)
needs three reductions over the stacked client-delta matrix [M, n_pad]:

  per-row squared norms   ‖Δ_i[:d]‖²          (for Δ_max, Eq. 15)
  masked Δ_max            max_{i∈M_t} ‖Δ_i‖²
  masked scaled mean      Σ_{i∈M_t} Δ_i / M_t  (the recovered update)

The pytree path pays one full read of the matrix for the norms
(``_delta_sq_norms``) and a second for the per-leaf ``einsum`` mean. This
kernel fuses both into ONE HBM pass: the grid walks column blocks, each
block loads all M rows once, accumulates the weighted row-combination into
the mean output and the per-row square partial sums into a revisited [M]
output (same cross-grid accumulation pattern as ``zo_dirnorms``).

Δ_max and the Eq.-17 noise scale are then scalar work on the [M] norms,
and the noise itself is injected with a single ``zo_walk`` pass over the
d-sized mean (noise generated in-kernel from the counter convention) — the
M×d matrix is never touched again.

VMEM budget: the block is [M, block_rows, 128] fp32 — at the default 512
block rows that is M·256 KiB, fine for the paper's M ≤ 50 within the
~16 MiB budget (callers can shrink ``block_rows`` for larger cohorts).

The mask/m_eff semantics live in the caller (core/aircomp.py): ``scale``
arrives as maskf/m_eff so masked-out rows contribute 0 to the mean; their
norms are still computed (the [M] output is dense) and masked out of
Δ_max by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.zo_axpy import BLOCK_ROWS, LANES, _block_idx


def _reduce_kernel(scale_ref, d_ref, x_ref, mean_ref, sq_ref, *, m,
                   block_rows):
    i = pl.program_id(0)
    idx = _block_idx(i, block_rows, LANES)
    valid = idx < d_ref[0].astype(jnp.uint32)

    @pl.when(i == 0)
    def _init():
        sq_ref[...] = jnp.zeros((m,), jnp.float32)

    acc = jnp.zeros((block_rows, LANES), jnp.float32)
    for mi in range(m):  # static unroll: all M rows of this column block
        x = x_ref[mi].astype(jnp.float32)
        sq_ref[mi] += jnp.sum(jnp.where(valid, x * x, 0.0))
        acc = acc + scale_ref[mi] * x
    mean_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def aircomp_reduce(x3, scale, d_arr, *, interpret=False,
                   block_rows=BLOCK_ROWS):
    """One-pass (combined mean, per-row sq-norms) over x3 [M, R, 128].

    scale: fp32 [M] per-row weights (the caller folds mask and 1/m_eff in,
    so the first output IS the masked scaled mean). d_arr: int32 [1] valid
    flat length — padding indices ≥ d are excluded from the norms (the pad
    region of walked flat buffers is NOT zero, see DESIGN.md §8).
    Returns (mean [R, 128] fp32, sq [M] fp32).
    """
    m, r, lanes = x3.shape
    assert lanes == LANES and r % block_rows == 0, (x3.shape, block_rows)
    grid = (r // block_rows,)
    small = lambda shape: pl.BlockSpec(shape, lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_reduce_kernel, m=m, block_rows=block_rows),
        grid=grid,
        in_specs=[small((m,)), small((1,)),
                  pl.BlockSpec((m, block_rows, LANES), lambda i: (0, i, 0))],
        out_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
                   pl.BlockSpec((m,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((r, LANES), jnp.float32),
                   jax.ShapeDtypeStruct((m,), jnp.float32)],
        interpret=interpret,
    )(scale, d_arr, x3)
