"""jit'd public wrappers around the Pallas kernels: shape normalization,
padding to block multiples, pytree-level ZO helpers.

``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere (this
container is CPU-only, so tests/benches run the interpreter; the compiled
path is the production target).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import zo_aircomp as _zac
from repro.kernels import zo_axpy as _za


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_to(x, m):
    n = x.shape[0]
    pad = (-n) % m
    if pad:
        x = jnp.pad(x, ((0, pad),))
    return x, n


def axpy2(x, u, v, a, b, *, interpret=None, block=None):
    """x + a·u + b·v for flat arrays of any length."""
    block = block or _za.BLOCK
    xp, n = _pad_to(x, block)
    up, _ = _pad_to(u, block)
    vp, _ = _pad_to(v, block)
    ab = jnp.asarray([a, b], jnp.float32).reshape(2)
    out = _za.zo_axpy2(xp, up, vp, ab, interpret=_auto_interpret(interpret),
                       block=block)
    return out[:n]


def tree_axpy2(x_tree, u_tree, v_tree, a, b, *, interpret=None):
    """Leafwise fused x + a·u + b·v (the MeZO unperturb-and-reperturb pass)."""
    def one(x, u, v):
        out = axpy2(x.reshape(-1), u.reshape(-1), v.reshape(-1), a, b,
                    interpret=interpret)
        return out.reshape(x.shape)
    return jax.tree.map(one, x_tree, u_tree, v_tree)


def _as2d(x, block_rows):
    """[N] → [R, 128] view (padding to a block multiple if needed)."""
    per = block_rows * _za.LANES
    xp, n = _pad_to(x, per)
    return xp.reshape(-1, _za.LANES), n


def zo_walk(x, key2, nn, ab, *, kind="normal", interpret=None,
            block_rows=None):
    """Fused perturbation transition on a flat [N] buffer.

    out = x + ab[0]·v(nn[0]) + ab[1]·v(nn[1]) with directions regenerated
    in-kernel from the counter convention (key2, n, index). One HBM pass.
    """
    block_rows = block_rows or _za.BLOCK_ROWS
    x2, n = _as2d(x, block_rows)
    nn = jnp.asarray(nn, jnp.int32).reshape(2)
    ab = jnp.asarray(ab, jnp.float32).reshape(2)
    out = _za.zo_walk(x2, key2, nn, ab, kind=kind,
                      interpret=_auto_interpret(interpret),
                      block_rows=block_rows)
    return out.reshape(-1)[:n]


def zo_replay(x, key2, coeffs, *, kind="normal", interpret=None,
              block_rows=None):
    """Single-pass Σ_n coeffs[n]·v_n update on a flat [N] buffer."""
    block_rows = block_rows or _za.BLOCK_ROWS
    x2, n = _as2d(x, block_rows)
    out = _za.zo_replay(x2, key2, jnp.asarray(coeffs, jnp.float32),
                        kind=kind, interpret=_auto_interpret(interpret),
                        block_rows=block_rows)
    return out.reshape(-1)[:n]


def zo_dirnorms(key2, d, *, b2, n_pad=None, kind="normal", interpret=None,
                block_rows=None):
    """[b2] squared direction norms ‖g_n[:d]‖² (counter convention)."""
    block_rows = block_rows or _za.BLOCK_ROWS
    per = block_rows * _za.LANES
    if n_pad is None:
        n_pad = d + ((-d) % per)
    assert n_pad % per == 0, (n_pad, per)
    return _za.zo_dirnorms(key2, d, b2=b2, n_pad=n_pad, kind=kind,
                           interpret=_auto_interpret(interpret),
                           block_rows=block_rows)


def aircomp_reduce(deltas, scale, d, *, interpret=None, block_rows=None):
    """Masked scaled row-combination + per-row ‖·[:d]‖² of deltas [M, N]
    in ONE pass over the matrix. Returns (mean [N] fp32, sq [M] fp32).

    ``scale`` [M] is the per-row mean weight (maskf/m_eff); ``d`` is the
    valid flat length (indices ≥ d excluded from the norms).
    """
    block_rows = block_rows or _za.BLOCK_ROWS
    per = block_rows * _za.LANES
    m, n = deltas.shape
    pad = (-n) % per
    if pad:
        deltas = jnp.pad(deltas, ((0, 0), (0, pad)))
    x3 = deltas.reshape(m, -1, _za.LANES)
    mean2, sq = _zac.aircomp_reduce(
        x3, jnp.asarray(scale, jnp.float32), jnp.asarray([d], jnp.int32),
        interpret=_auto_interpret(interpret), block_rows=block_rows)
    return mean2.reshape(-1)[:n], sq


def attention(q, k, v, *, causal=True, window=0, scale=None,
              block_q=128, block_k=128, interpret=None):
    """Flash attention on [B, S, H, D] layout (matches models/layers.py).

    Pads Sq/Sk up to block multiples; padded kv positions are masked out by
    the causal/positional mask (padded q rows are discarded on return).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    if not causal and pk:
        # non-causal: mask padded kv by position via a window over Sk
        raise NotImplementedError("pad non-causal kv not supported; "
                                  "choose block_k dividing Sk")
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              scale=scale, block_q=block_q, block_k=block_k,
                              interpret=_auto_interpret(interpret))
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


def rmsnorm(x, scale, *, eps=1e-6, interpret=None, block_rows=128):
    """RMSNorm over the last dim of x [..., D]."""
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    R = x2.shape[0]
    pad = (-R) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = _rn.rmsnorm(x2, scale, eps=eps, block_rows=block_rows,
                      interpret=_auto_interpret(interpret))
    return out[:R].reshape(shp)
