"""FedZO reproduction package.

Seed replay must be *sharding-invariant*: a direction generated inside a
GSPMD-partitioned program (e.g. the multi-pod round) must be bit-equal to
the one a receiver regenerates elsewhere from the same key. Legacy
non-partitionable threefry does not guarantee that — the partitioner can
produce different bits when RNG is fused into a sharded program (observed
as a wrong-direction update in the pod round on jax 0.4.x, where the flag
still defaults to False). Opt in to partitionable threefry before any key
is used; newer jax defaults to this behavior.
"""
import jax as _jax

_jax.config.update("jax_threefry_partitionable", True)
