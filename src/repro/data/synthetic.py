"""Synthetic federated datasets with the paper's non-iid protocols.

The container is offline, so Fashion-MNIST / CIFAR-10 are replaced by
deterministic synthetic generators that preserve the *shape of the problem*:
class-conditional Gaussian images (classes are linearly separable enough for
softmax regression to train, like F-MNIST) and 32×32×3 "CIFAR-like" images
for the attack task.

Non-iid split (Sec. V-B, following McMahan et al.): sort by label, cut into
2·N shards, deal 2 shards per client → each client sees ≤ 4 distinct labels
(2 per shard boundary effects aside).
"""
from __future__ import annotations

import numpy as np


def make_classification(n, n_features=784, n_classes=10, seed=0, scale=1.0,
                        image_shape=None):
    """Class-conditional Gaussians: x = mu_y + noise, labels balanced."""
    rng = np.random.default_rng(seed)
    mus = rng.normal(0, 1, (n_classes, n_features)).astype(np.float32)
    y = np.arange(n) % n_classes
    rng.shuffle(y)
    x = mus[y] * scale + rng.normal(0, 1, (n, n_features)).astype(np.float32)
    if image_shape is not None:
        # squash to [0,1] pixel range for image-space tasks
        x = 1.0 / (1.0 + np.exp(-x))
        x = x.reshape((n,) + tuple(image_shape))
    return x.astype(np.float32), y.astype(np.int32)


def noniid_shards(x, y, n_clients, shards_per_client=2, seed=0):
    """Label-sorted shard split (the paper's Fashion-MNIST protocol)."""
    rng = np.random.default_rng(seed)
    order = np.argsort(y, kind="stable")
    x, y = x[order], y[order]
    n_shards = n_clients * shards_per_client
    shard_size = len(y) // n_shards
    shard_ids = rng.permutation(n_shards)
    clients = []
    for c in range(n_clients):
        take = shard_ids[c * shards_per_client:(c + 1) * shards_per_client]
        idx = np.concatenate([np.arange(s * shard_size, (s + 1) * shard_size)
                              for s in take])
        clients.append({"x": x[idx], "y": y[idx]})
    return clients


def random_partition(x, y, n_clients, seed=0, uneven=True):
    """IID partition; ``uneven`` draws random (Dirichlet) client sizes like
    the attack experiment ('each device is assigned a random number of
    samples')."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(y))
    if uneven:
        w = rng.dirichlet(np.full(n_clients, 5.0))
        counts = np.maximum((w * len(y)).astype(int), 1)
        counts[-1] = len(y) - counts[:-1].sum()
    else:
        counts = np.full(n_clients, len(y) // n_clients)
    out, off = [], 0
    for c in counts:
        take = idx[off:off + c]
        out.append({"x": x[take], "y": y[take]})
        off += c
    return out


def sample_local_batches(client, rng: np.random.Generator, h, b1):
    """Pre-sample H minibatches of size b1 for one client round -> stacked."""
    n = len(client["y"])
    idx = rng.integers(0, n, size=(h, b1))
    return {"x": client["x"][idx], "y": client["y"][idx]}


def lm_token_stream(n_tokens, vocab, seed=0, order=3):
    """Deterministic synthetic LM corpus: a random Markov chain over the
    vocabulary (gives a learnable non-uniform next-token distribution)."""
    rng = np.random.default_rng(seed)
    state = rng.integers(0, vocab)
    # sparse transition structure: each token has `order` likely successors
    succ = rng.integers(0, vocab, size=(vocab, order))
    toks = np.empty(n_tokens, np.int32)
    jumps = rng.random(n_tokens)
    choices = rng.integers(0, order, n_tokens)
    for i in range(n_tokens):
        state = succ[state, choices[i]] if jumps[i] < 0.9 \
            else rng.integers(0, vocab)
        toks[i] = state
    return toks


def lm_batches(tokens, batch, seq, rng: np.random.Generator):
    starts = rng.integers(0, len(tokens) - seq - 1, size=batch)
    x = np.stack([tokens[s:s + seq] for s in starts])
    y = np.stack([tokens[s + 1:s + seq + 1] for s in starts])
    return {"tokens": x, "labels": y}
