"""Synthetic federated datasets with the paper's non-iid protocols.

The container is offline, so Fashion-MNIST / CIFAR-10 are replaced by
deterministic synthetic generators that preserve the *shape of the problem*:
class-conditional Gaussian images (classes are linearly separable enough for
softmax regression to train, like F-MNIST) and 32×32×3 "CIFAR-like" images
for the attack task.

Non-iid split (Sec. V-B, following McMahan et al.): sort by label, cut into
2·N shards, deal 2 shards per client → each client sees ≤ 4 distinct labels
(2 per shard boundary effects aside).
"""
from __future__ import annotations

import numpy as np


def make_classification(n, n_features=784, n_classes=10, seed=0, scale=1.0,
                        image_shape=None):
    """Class-conditional Gaussians: x = mu_y + noise, labels balanced."""
    rng = np.random.default_rng(seed)
    mus = rng.normal(0, 1, (n_classes, n_features)).astype(np.float32)
    y = np.arange(n) % n_classes
    rng.shuffle(y)
    x = mus[y] * scale + rng.normal(0, 1, (n, n_features)).astype(np.float32)
    if image_shape is not None:
        # squash to [0,1] pixel range for image-space tasks
        x = 1.0 / (1.0 + np.exp(-x))
        x = x.reshape((n,) + tuple(image_shape))
    return x.astype(np.float32), y.astype(np.int32)


def noniid_shards(x, y, n_clients, shards_per_client=2, seed=0):
    """Label-sorted shard split (the paper's Fashion-MNIST protocol).

    When ``len(y)`` doesn't divide into ``n_clients · shards_per_client``
    shards the remainder rows are dealt across the leading shards (one
    extra row each) instead of being dropped — the union of the client
    datasets is always the full dataset.
    """
    rng = np.random.default_rng(seed)
    order = np.argsort(y, kind="stable")
    x, y = x[order], y[order]
    n_shards = n_clients * shards_per_client
    if len(y) < n_shards:
        raise ValueError(f"{len(y)} rows cannot fill {n_shards} shards "
                         f"({n_clients} clients × {shards_per_client})")
    shard_sizes = np.full(n_shards, len(y) // n_shards, np.int64)
    shard_sizes[:len(y) % n_shards] += 1
    bounds = np.concatenate([[0], np.cumsum(shard_sizes)])
    shard_ids = rng.permutation(n_shards)
    clients = []
    for c in range(n_clients):
        take = shard_ids[c * shards_per_client:(c + 1) * shards_per_client]
        idx = np.concatenate([np.arange(bounds[s], bounds[s + 1])
                              for s in take])
        clients.append({"x": x[idx], "y": y[idx]})
    assert sum(len(c["y"]) for c in clients) == len(y)
    return clients


def _renormalize_counts(counts, total):
    """Adjust integer client sizes so each is ≥ 1 and they sum to ``total``
    (deals surpluses/deficits against the largest clients first)."""
    counts = np.maximum(np.asarray(counts, np.int64), 1)
    diff = total - int(counts.sum())
    order = np.argsort(-counts, kind="stable")
    j = 0
    while diff != 0:
        c = order[j % len(counts)]
        if diff > 0:
            counts[c] += 1
            diff -= 1
        elif counts[c] > 1:
            counts[c] -= 1
            diff += 1
        j += 1
    return counts


def random_partition(x, y, n_clients, seed=0, uneven=True):
    """IID partition; ``uneven`` draws random (Dirichlet) client sizes like
    the attack experiment ('each device is assigned a random number of
    samples'). Every client gets ≥ 1 row and the counts sum exactly to
    ``len(y)`` (the naive clamp-then-subtract assignment could hand the
    last client a zero or negative row count)."""
    if len(y) < n_clients:
        raise ValueError(f"{len(y)} rows cannot give each of {n_clients} "
                         f"clients at least one row")
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(y))
    if uneven:
        w = rng.dirichlet(np.full(n_clients, 5.0))
        counts = _renormalize_counts((w * len(y)).astype(int), len(y))
    else:
        counts = np.full(n_clients, len(y) // n_clients)
        counts[:len(y) % n_clients] += 1    # deal the remainder, drop nothing
    out, off = [], 0
    for c in counts:
        take = idx[off:off + c]
        out.append({"x": x[take], "y": y[take]})
        off += c
    return out


def dirichlet_partition(x, y, n_clients, alpha=0.5, seed=0):
    """Dirichlet(α) label-skew partition (Hsu et al. 2019): per class c a
    Dirichlet(α·1) draw over clients proportions the class's rows, so small
    α concentrates each class on few clients and α→∞ recovers IID. All
    rows are assigned; every client ends with ≥ 1 row (deficits are filled
    from the largest clients)."""
    if len(y) < n_clients:
        raise ValueError(f"{len(y)} rows cannot give each of {n_clients} "
                         f"clients at least one row")
    rng = np.random.default_rng(seed)
    assign = [[] for _ in range(n_clients)]
    for cls in np.unique(y):
        rows = np.flatnonzero(y == cls)
        rng.shuffle(rows)
        p = rng.dirichlet(np.full(n_clients, alpha))
        # cumulative-proportion splits keep every row exactly once
        cuts = (np.cumsum(p)[:-1] * len(rows)).astype(int)
        for c, part in enumerate(np.split(rows, cuts)):
            assign[c].extend(part.tolist())
    # re-home rows so no client is empty (build_store needs ≥ 1 row each)
    for c in range(n_clients):
        while not assign[c]:
            donor = max(range(n_clients), key=lambda i: len(assign[i]))
            assign[c].append(assign[donor].pop())
    assert sum(len(a) for a in assign) == len(y)
    return [{"x": x[np.asarray(a, np.int64)], "y": y[np.asarray(a, np.int64)]}
            for a in assign]


def federated_classification(n_train, n_test, n_clients, *, n_features=784,
                             n_classes=10, seed=0, scale=1.0,
                             image_shape=None, partition="dirichlet",
                             alpha=0.5, shards_per_client=2):
    """The Sec. V-B data protocol in one call: a synthetic classification
    problem split into federated client shards plus a pooled held-out test
    batch. ``partition``: "dirichlet" (Hsu-style label skew, concentration
    ``alpha``), "shards" (the paper's label-sorted deal), "iid", or
    "uneven" (IID rows, Dirichlet client sizes). Returns
    (clients, test_batch)."""
    x, y = make_classification(n_train + n_test, n_features, n_classes,
                               seed=seed, scale=scale,
                               image_shape=image_shape)
    xtr, ytr = x[:n_train], y[:n_train]
    if partition == "dirichlet":
        clients = dirichlet_partition(xtr, ytr, n_clients, alpha=alpha,
                                      seed=seed)
    elif partition == "shards":
        clients = noniid_shards(xtr, ytr, n_clients,
                                shards_per_client=shards_per_client,
                                seed=seed)
    elif partition in ("iid", "uneven"):
        clients = random_partition(xtr, ytr, n_clients, seed=seed,
                                   uneven=(partition == "uneven"))
    else:
        raise ValueError(f"unknown partition {partition!r}; use dirichlet | "
                         f"shards | iid | uneven")
    return clients, {"x": x[n_train:], "y": y[n_train:]}


def sample_local_batches(client, rng: np.random.Generator, h, b1):
    """Pre-sample H minibatches of size b1 for one client round -> stacked."""
    n = len(client["y"])
    idx = rng.integers(0, n, size=(h, b1))
    return {"x": client["x"][idx], "y": client["y"][idx]}


def lm_token_stream(n_tokens, vocab, seed=0, order=3):
    """Deterministic synthetic LM corpus: a random Markov chain over the
    vocabulary (gives a learnable non-uniform next-token distribution)."""
    rng = np.random.default_rng(seed)
    state = rng.integers(0, vocab)
    # sparse transition structure: each token has `order` likely successors
    succ = rng.integers(0, vocab, size=(vocab, order))
    toks = np.empty(n_tokens, np.int32)
    jumps = rng.random(n_tokens)
    choices = rng.integers(0, order, n_tokens)
    for i in range(n_tokens):
        state = succ[state, choices[i]] if jumps[i] < 0.9 \
            else rng.integers(0, vocab)
        toks[i] = state
    return toks


def lm_batches(tokens, batch, seq, rng: np.random.Generator):
    starts = rng.integers(0, len(tokens) - seq - 1, size=batch)
    x = np.stack([tokens[s:s + seq] for s in starts])
    y = np.stack([tokens[s + 1:s + seq + 1] for s in starts])
    return {"tokens": x, "labels": y}
