"""Training driver: FedZO (default) or FedAvg on any registered arch.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b-smoke \
        --steps 50 --batch 4 --seq 128 --algo fedzo --b2 8

Cross-silo semantics on a single host: the host mesh's ``data`` axis carries
the batch; FedZO runs one local iterate per step (the launcher is the round
loop). Checkpoints + CSV metrics under --out.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import restore, save
from repro.configs import get_config
from repro.configs.base import FedZOConfig, ShapeConfig
from repro.core import fedavg, fedzo
from repro.data.synthetic import lm_batches, lm_token_stream
from repro.models.api import build


def make_lm_data(cfg, n_tokens=200_000, seed=0):
    vocab = min(cfg.vocab, 4096)  # synthetic stream over a vocab subset
    return lm_token_stream(n_tokens, vocab, seed=seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b-smoke")
    ap.add_argument("--algo", default="fedzo", choices=("fedzo", "fedavg"))
    ap.add_argument("--opt", default="sgd", choices=("sgd", "adam"),
                    help="first-order optimizer (fedavg path only)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--mu", type=float, default=1e-3)
    ap.add_argument("--b2", type=int, default=8)
    ap.add_argument("--estimator", default="sphere",
                    choices=("sphere", "gaussian", "coordinate"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", default="")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--override", default="", help="cfg overrides, e.g. "
                    "d_model=768,n_layers=12,d_ff=3072,vocab=16384")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.override:
        kw = {}
        for part in args.override.split(","):
            k, v = part.split("=")
            cur = getattr(cfg, k)
            kw[k] = type(cur)(v) if cur is not None else int(v)
        cfg = cfg.replace(**kw)
    model = build(cfg)
    lr = args.lr if args.lr is not None else (1e-4 if args.algo == "fedzo"
                                              else 1e-3)
    fcfg = FedZOConfig(lr=lr, mu=args.mu, b2=args.b2,
                       estimator=args.estimator, seed=args.seed)

    params = model.init(jax.random.key(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M algo={args.algo} "
          f"lr={lr} b2={args.b2}", flush=True)

    start = 0
    if args.resume:
        params, start = restore(args.resume, params)
        print(f"resumed from {args.resume} @ step {start}")

    loss_fn = lambda p, b: model.loss(p, b)
    opt_state = None
    if args.algo == "fedzo":
        step_fn = jax.jit(fedzo.make_train_step(loss_fn, fcfg))
    elif args.opt == "adam":
        from repro.optim.sgd import adam_apply, adam_init
        opt_state = adam_init(params)

        def _adam_step(p, batch, rng, st):
            del rng
            loss, g = jax.value_and_grad(loss_fn)(p, batch)
            p, st = adam_apply(p, g, st, lr=lr)
            return p, {"loss": loss}, st

        adam_step = jax.jit(_adam_step)
        step_fn = None
    else:
        step_fn = jax.jit(fedavg.make_train_step(loss_fn, fcfg))

    toks = make_lm_data(cfg, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    key = jax.random.key(args.seed + 1)
    history = []
    t0 = time.time()
    for step in range(start, start + args.steps):
        b = lm_batches(toks, args.batch, args.seq, rng)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.n_frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        if cfg.family == "encdec":
            batch["src_embeds"] = 0.1 * jax.random.normal(
                jax.random.fold_in(key, step),
                (args.batch, cfg.n_frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        key, sub = jax.random.split(key)
        if step_fn is None:
            params, metrics, opt_state = adam_step(params, batch, sub,
                                                   opt_state)
        else:
            params, metrics = step_fn(params, batch, sub)
        history.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            dt = (time.time() - t0) / max(step - start + 1, 1)
            print(f"step {step:5d} loss {history[-1]:.4f} "
                  f"({dt:.2f}s/step)", flush=True)
        if args.ckpt_every and args.out and \
                (step + 1) % args.ckpt_every == 0:
            save(os.path.join(args.out, f"ckpt_{step+1}"), params,
                 step=step + 1, meta=fcfg)

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        with open(os.path.join(args.out, "history.json"), "w") as f:
            json.dump({"loss": history, "arch": cfg.name,
                       "algo": args.algo}, f)
        save(os.path.join(args.out, "final"), params,
             step=start + args.steps, meta=fcfg)
    first = np.mean(history[:5]) if len(history) >= 5 else history[0]
    last = np.mean(history[-5:])
    print(f"done: loss {first:.4f} -> {last:.4f} over {args.steps} steps")


if __name__ == "__main__":
    main()
