"""Divisibility-aware sharding rules: param/batch/cache pytrees → NamedShardings.

Strategy (DESIGN.md §5):
- params: FSDP everywhere + tensor/expert parallel where it fits. For each
  leaf we walk the dims (largest first, skipping stacked-layer leading axes)
  and place the ``model`` axis on the first divisible dim, then ``data`` on
  the next divisible dim. Norm scales/biases and other small leaves stay
  replicated. Expert tensors [L, E, d, f] get E→model, f→data explicitly
  (they must match the moe shard_map specs).
- batch: leading batch dim over ("pod","data") jointly when divisible;
  long_500k (batch=1) falls back to replicated inputs with the KV cache
  sharded over ``data`` on its sequence dim (context parallelism).
- rngs/scalars: replicated.

Awkward dims (qwen1.5-32b's 40 heads on a 16-way model axis) simply fall
through to the next divisible dim — recorded per-arch by ``explain()``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

MIN_SHARD_ELEMS = 2048  # below this a leaf is replicated

# ZO training keeps no grads or optimizer state, so FSDP over `data` is only
# needed when tensor-parallel-only params exceed this per-device budget.
# Below it, model-only sharding removes the per-forward weight all-gathers
# (§Perf iteration 2). Expert tensors always keep their FSDP dim (they must
# match the moe shard_map in_specs).
# §Perf iteration 2 result: model-only sharding was REFUTED for 90B — the
# fp32 perturbation trees (sphere directions) inherit the weight sharding, so
# dropping the data dim replicated them 16x (73 GB temp) while the dominant
# collectives turned out to be activation psums, not weight gathers. FSDP
# therefore stays on unconditionally (threshold 0).
FSDP_BYTES_THRESHOLD = 0


def _is_stacked(path_str):
    return "blocks" in path_str  # stacked-layer leading axis: never shard dim 0


def _is_expert(path_str):
    return any(k in path_str for k in ("w_gate", "w_up", "w_down")) and \
        "moe" in path_str


def leaf_spec(path_str, shape, mesh, allow_data=True) -> P:
    ndim = len(shape)
    if ndim == 0:
        return P()
    n_model = mesh.shape.get("model", 1)
    n_data = mesh.shape.get("data", 1) if allow_data or _is_expert(path_str) \
        else 1
    start = 1 if (_is_stacked(path_str) and ndim > 1) else 0

    if path_str.endswith("['tok']") or path_str.endswith("['unembed']"):
        # vocab-parallel layout: vocab over model, d_model replicated
        # (matches the shard_map embedding lookup and logits matmul).
        v_ax = 0 if path_str.endswith("['tok']") else ndim - 1
        spec = [None] * ndim
        if shape[v_ax] % n_model == 0:
            spec[v_ax] = "model"
        return P(*spec)

    if _is_expert(path_str):
        # [L, E, d, f] (or [E, d, f]): E -> model, FFN dim -> data.
        spec = [None] * ndim
        e_ax = start
        spec[e_ax] = "model" if shape[e_ax] % n_model == 0 else None
        # fsdp dim: w_down has f at e_ax+1, w_gate/up at e_ax+2
        f_ax = e_ax + (1 if "w_down" in path_str else 2)
        if f_ax < ndim and shape[f_ax] % n_data == 0:
            spec[f_ax] = "data"
        return P(*spec)

    size = 1
    for s in shape:
        size *= s
    if size < MIN_SHARD_ELEMS:
        return P()

    dims = sorted(range(start, ndim), key=lambda i: -shape[i])
    spec = [None] * ndim
    for axis_name, n in (("model", n_model), ("data", n_data)):
        if n == 1:
            continue
        for i in dims:
            if spec[i] is None and shape[i] % n == 0 and shape[i] >= n:
                spec[i] = axis_name
                break
    return P(*spec)


def param_shardings(param_specs, mesh):
    """pytree of ShapeDtypeStruct -> pytree of NamedSharding.

    FSDP (the `data` dim on weights) is enabled only when tensor-parallel-
    only sharding would exceed FSDP_BYTES_THRESHOLD per device."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_specs)
    total = sum(l.size * l.dtype.itemsize for _, l in flat)
    allow_data = total / max(mesh.shape.get("model", 1), 1) \
        > FSDP_BYTES_THRESHOLD
    out = []
    for kp, leaf in flat:
        spec = leaf_spec(jax.tree_util.keystr(kp), leaf.shape, mesh,
                         allow_data=allow_data)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch_specs, mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]

    def one(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % n_dp == 0:
            return NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1))))
        # batch not divisible (long_500k B=1): replicate inputs
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_specs)


def cache_shardings(cache_specs, mesh, cfg):
    """Decode caches: [L(, G), B, W, H, hd] / latent [L, B, W, r] / states.

    batch over (pod, data) when divisible; otherwise the *sequence* (W) dim
    of ring caches goes over data (context parallelism for long_500k);
    heads over model when divisible.
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    n_model = mesh.shape.get("model", 1)

    def one(path, leaf):
        path_str = jax.tree_util.keystr(path)
        shape = leaf.shape
        spec = [None] * leaf.ndim
        # find batch dim: first dim after stacked layer axes that matches B
        # heuristics: caches are [L, ...] or [G, n, ...]; batch is the dim
        # right after the stacked prefix. We detect the prefix length by key.
        prefix = 1
        if ".self" in path_str and leaf.ndim >= 5:
            prefix = 2 if "cross" not in path_str else 1
        b_ax = prefix
        if b_ax < leaf.ndim and shape[b_ax] % n_dp == 0 and n_dp > 1:
            spec[b_ax] = dp
        elif leaf.ndim > b_ax + 1 and shape[b_ax + 1] % mesh.shape.get("data", 1) == 0 \
                and ("k" in path_str or "v" in path_str or "latent" in path_str):
            spec[b_ax + 1] = "data"   # context parallelism on W
        # heads axis for kv caches: [..., W, H, hd]
        if leaf.ndim >= b_ax + 3:
            h_ax = leaf.ndim - 2
            w_ax = leaf.ndim - 3
            if spec[h_ax] is None and shape[h_ax] % n_model == 0 and shape[h_ax] >= n_model:
                spec[h_ax] = "model"
            elif spec[w_ax] is None and shape[w_ax] % n_model == 0:
                # heads don't divide the model axis (qwen1.5's 40, GQA 8 on
                # 16): shard the cache *sequence* dim over model instead —
                # decode softmax reduces over it with a psum.
                spec[w_ax] = "model"
        return NamedSharding(mesh, P(*spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_specs)
    return jax.tree_util.tree_unflatten(
        treedef, [one(kp, leaf) for kp, leaf in flat])


def explain(param_specs, mesh, max_rows=0):
    """Human-readable sharding table (DESIGN/EXPERIMENTS docs)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(param_specs)
    rows = []
    for kp, leaf in flat:
        ps = jax.tree_util.keystr(kp)
        rows.append((ps, leaf.shape, leaf_spec(ps, leaf.shape, mesh)))
    if max_rows:
        rows = rows[:max_rows]
    return rows
