"""Serving driver: batched prefill + decode loop on any registered arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b-smoke \
        --batch 4 --prompt-len 32 --gen 16

Demonstrates the production decode path (ring KV caches / SSM states,
one-token steps) that the decode_32k / long_500k dry-run shapes lower.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models.api import build, make_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--width", type=int, default=0, help="cache width")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = build(cfg)
    params = model.init(jax.random.key(args.seed))
    width = args.width or (args.prompt_len + args.gen)

    shape = ShapeConfig("serve", args.prompt_len, args.batch, "prefill")
    batch = make_batch(model, shape, jax.random.key(args.seed + 1))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, width))
    decode = jax.jit(lambda p, b, c, pos: model.decode(p, b, c, pos))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: batch={args.batch} len={args.prompt_len} "
          f"{t_prefill:.2f}s ({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")

    key = jax.random.key(args.seed + 2)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen):
        db = dict(batch)
        db["tokens"] = tok
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, cache = decode(params, db, cache, pos)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    seqs = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"decode: {args.gen} steps in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  request {b}: {seqs[b].tolist()}")
    assert np.isfinite(np.asarray(logits)).all()
    print("serve OK")


if __name__ == "__main__":
    main()
