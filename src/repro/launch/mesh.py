"""Production meshes (TPU v5e).

Single pod: 256 chips as (16, 16) = (data, model).
Multi-pod: 2 pods × 256 chips as (2, 16, 16) = (pod, data, model);
the ``pod`` axis is the *federated* axis — one FedZO client per pod
(DESIGN.md §3.3).

Defined as functions so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before calling.
"""
from __future__ import annotations

import jax

try:  # AxisType landed after 0.4.x; Auto is the default behavior there
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_clients_mesh(n_devices: int = 0, *, axis: str = "clients"):
    """1-D mesh over the local devices with the federated ``clients`` axis —
    the simulation engine's fan-out mesh (repro.sim.shard): the M sampled
    clients of each round split over this axis, one shard of local phases
    and one partial aggregation reduce per device."""
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    if n_devices:
        devs = devs[:n_devices]
    return Mesh(np.array(devs), (axis,))


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return _make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
