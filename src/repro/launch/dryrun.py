import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh) combo
with ShapeDtypeStruct inputs (no allocation) and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k [--multi-pod] [--algo fedzo|fedavg] [--out out.json]

The XLA_FLAGS line above MUST run before any jax import (jax locks the
device count on first init); keep it the first statement of this module.
Results (memory analysis, HLO FLOPs/bytes, per-collective byte counts,
derived roofline seconds) are appended as JSON for EXPERIMENTS.md.
"""
import argparse
import json
import re
import sys
import time

import jax

jax.config.update("jax_threefry_partitionable", True)
if os.environ.get("REPRO_RNG", "") == "rbg":
    # single-op RngBitGenerator: collapses the multi-stage threefry pipeline
    # whose per-stage buffers dominate ZO perturbation memory on big models
    jax.config.update("jax_default_prng_impl", "rbg")

import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape, ARCH_IDS, SHAPE_IDS
from repro.configs.base import FedZOConfig
from repro.core import fedavg, fedzo
from repro.launch.mesh import make_production_mesh
from repro.launch import sharding as shr
from repro.models.api import build, decode_width
from repro.utils import hw

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
                "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2}


def _shape_bytes(type_str):
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text):
    """Trip-count-weighted collective bytes per device, by type.

    Collectives inside scan/while bodies execute once per iteration; XLA
    annotates compiled while ops with ``known_trip_count``, so we build the
    computation call graph (while body= references) and weight each body's
    collective bytes by its trip count, recursively. Unannotated whiles
    count once (conservative lower bound).
    """
    comp_coll = {}     # computation -> {type: bytes}, {type: count}
    comp_calls = {}    # computation -> [(callee, trips)]
    entry = None
    cur = None
    coll_re = re.compile(
        r"%?[\w.\-]+\s*=\s*(.+?)\s+(" + "|".join(COLLECTIVES) +
        r")(-start|-done)?\(")
    head_re = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->")
    body_re = re.compile(r"body=%?([\w.\-]+)")
    trips_re = re.compile(r'known_trip_count\D*?(\d+)')
    call_re = re.compile(r"(?:to_apply|branch_computations)=\{?%?([\w.\-,% ]+)")

    for line in hlo_text.splitlines():
        if not line.startswith(" "):
            m = head_re.match(line.strip())
            if m:
                cur = m.group(2)
                comp_coll[cur] = ({c: 0 for c in COLLECTIVES},
                                  {c: 0 for c in COLLECTIVES})
                comp_calls[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if cur is None:
            continue
        ls = line.strip()
        m = coll_re.match(ls)
        if m and m.group(3) != "-done":
            comp_coll[cur][0][m.group(2)] += _shape_bytes(m.group(1))
            comp_coll[cur][1][m.group(2)] += 1
        if " while(" in ls:
            mb = body_re.search(ls)
            if mb:
                mt = trips_re.search(ls)
                trips = int(mt.group(1)) if mt else 1
                comp_calls[cur].append((mb.group(1), trips))
        elif "to_apply=" in ls and "fusion" not in ls.split("=", 1)[1][:60]:
            mc = call_re.search(ls)
            if mc:
                for callee in mc.group(1).replace("%", "").split(","):
                    comp_calls[cur].append((callee.strip(), 1))

    memo = {}

    def total(comp, depth=0):
        if comp in memo or depth > 50 or comp not in comp_coll:
            return memo.get(comp, ({c: 0 for c in COLLECTIVES},
                                   {c: 0 for c in COLLECTIVES}))
        b = dict(comp_coll[comp][0])
        n = dict(comp_coll[comp][1])
        for callee, trips in comp_calls.get(comp, ()):  # noqa: B020
            cb, cn = total(callee, depth + 1)
            for c in COLLECTIVES:
                b[c] += trips * cb[c]
                n[c] += trips * cn[c]
        memo[comp] = (b, n)
        return memo[comp]

    if entry is None and comp_coll:
        entry = next(iter(comp_coll))
    return total(entry) if entry else ({c: 0 for c in COLLECTIVES},
                                       {c: 0 for c in COLLECTIVES})


def count_params(specs, cfg):
    total = sum(int(l.size) for l in jax.tree.leaves(specs))
    if not cfg.n_experts:
        return total, total
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    expert = sum(int(l.size) for kp, l in flat
                 if shr._is_expert(jax.tree_util.keystr(kp)))
    active = total - expert + expert * cfg.top_k / cfg.n_experts
    return total, int(active)


def build_case(arch, shape_name, *, multi_pod, algo="fedzo", b2=1, h=2,
               estimator="sphere", direction_dtype="float32", donate=False):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    model = build(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    fedcfg = FedZOConfig(b2=b2, local_iters=h, estimator=estimator,
                         direction_dtype=direction_dtype)

    pspecs = model.param_specs()
    psh = shr.param_shardings(pspecs, mesh)
    params_in = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        pspecs, psh)

    bshapes = model.batch_shapes(shape)
    bspecs = {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in bshapes.items()}
    bsh = shr.batch_shardings(bspecs, mesh)
    batch_in = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=bsh[k])
                for k, v in bspecs.items()}
    rng_in = jax.ShapeDtypeStruct(
        (), jax.eval_shape(lambda: jax.random.key(0)).dtype,
        sharding=NamedSharding(mesh, P()))

    if shape.kind == "train":
        loss = lambda p, b: model.loss(p, b, mesh=mesh)
        if algo == "fedavg":
            raw = fedavg.make_train_step(loss, fedcfg)
        elif multi_pod:
            n_pod = mesh.shape["pod"]
            loss_g = lambda p, b: model.loss(p, b, mesh=mesh, n_groups=n_pod)
            raw = fedzo.make_pod_round_step(loss_g, fedcfg, mesh)
        else:
            raw = fedzo.make_train_step(loss, fedcfg)
        fn = jax.jit(raw, out_shardings=(psh, None),
                     donate_argnums=(0,) if donate else ())
        args = (params_in, batch_in, rng_in)
    elif shape.kind == "prefill":
        width = min(shape.seq_len, 32_768)

        def raw(p, b):
            return model.prefill(p, b, width, mesh=mesh)

        cache_specs = jax.eval_shape(raw, pspecs, bspecs)[1]
        csh = shr.cache_shardings(cache_specs, mesh, cfg)
        fn = jax.jit(raw, out_shardings=(None, csh))
        args = (params_in, batch_in)
    else:  # decode
        width = decode_width(cfg, shape)
        window = cfg.long_context_window if shape.seq_len > 65_536 else 0
        cache_specs = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, width))
        csh = shr.cache_shardings(cache_specs, mesh, cfg)
        cache_in = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            cache_specs, csh)
        pos_in = jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=NamedSharding(mesh, P()))

        def raw(p, b, cache, pos):
            return model.decode(p, b, cache, pos, mesh=mesh, window=window)

        fn = jax.jit(raw, out_shardings=(None, csh),
                     donate_argnums=(2,) if donate else ())
        args = (params_in, batch_in, cache_in, pos_in)

    return cfg, shape, mesh, model, pspecs, fn, args


def run_case(arch, shape_name, *, multi_pod, algo="fedzo", b2=1, h=2,
             estimator="sphere", direction_dtype="float32", donate=False):
    t0 = time.time()
    cfg, shape, mesh, model, pspecs, fn, args = build_case(
        arch, shape_name, multi_pod=multi_pod, algo=algo, b2=b2, h=h,
        estimator=estimator, direction_dtype=direction_dtype, donate=donate)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    mem = {k: int(getattr(ma, k)) for k in
           ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes")}
    mem["total_bytes_per_device"] = (mem["argument_size_in_bytes"] +
                                     mem["temp_size_in_bytes"] +
                                     mem["output_size_in_bytes"])
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns [per-device dict]
        ca = ca[0] if ca else {}
    ca = dict(ca)
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    coll, coll_counts = parse_collectives(compiled.as_text())
    coll_total = float(sum(coll.values()))

    agg = None
    if multi_pod and shape.kind == "train":
        # separately lower the dense-uplink aggregation program (per-pod
        # deltas -> mean) so the full-d cross-pod all-reduce is priced even
        # though the round program itself exchanges only coefficients.
        n_pod = mesh.shape["pod"]
        psh_pod = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(
                (n_pod,) + s.shape, s.dtype,
                sharding=NamedSharding(mesh, P(*(("pod",) + tuple(sh.spec))))),
            pspecs, shr.param_shardings(pspecs, mesh))
        rng2 = jax.ShapeDtypeStruct(
            (), jax.eval_shape(lambda: jax.random.key(0)).dtype,
            sharding=NamedSharding(mesh, P()))
        agg_fn = jax.jit(fedzo.make_delta_agg_step(
            FedZOConfig(aircomp=True, snr_db=0.0), n_pod))
        agg_c = agg_fn.lower(psh_pod, rng2).compile()
        a_coll, _ = parse_collectives(agg_c.as_text())
        a_ma = agg_c.memory_analysis()
        agg = {"collective_bytes_per_device": a_coll,
               "temp_bytes": int(a_ma.temp_size_in_bytes),
               "collective_total_bytes": float(sum(a_coll.values()))}

    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v
    roof = hw.roofline_seconds(flops, bytes_accessed, coll_total, chips=1)
    n_params, n_active = count_params(pspecs, cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
    else:
        tokens = shape.global_batch * (shape.seq_len if shape.kind == "prefill" else 1)
    model_flops = 6.0 * n_active * tokens  # fwd+bwd convention
    # FedZO does (1+b2) forwards and no backward:
    zo_model_flops = 2.0 * n_active * tokens * (1 + b2) if shape.kind == "train" \
        else 2.0 * n_active * tokens

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(v) for v in mesh.shape.values()),
        "multi_pod": multi_pod, "algo": algo, "b2": b2,
        "estimator": estimator, "direction_dtype": direction_dtype,
        "donate": donate,
        "n_params": n_params, "n_active_params": n_active,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll, "collective_counts": coll_counts,
        "collective_total_bytes": coll_total,
        "roofline_s": roof,
        "dominant_term": max(roof, key=roof.get),
        "model_flops_total": model_flops,
        "zo_model_flops_total": zo_model_flops,
        "useful_flops_ratio": (zo_model_flops / n_chips) / flops if flops else None,
        "hbm_ok": bool(mem["total_bytes_per_device"] < hw.HBM_PER_CHIP),
    }
    if agg is not None:
        rec["delta_agg_program"] = agg
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCH_IDS + ("all",))
    ap.add_argument("--shape", default="train_4k", choices=SHAPE_IDS + ("all",))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--algo", default="fedzo", choices=("fedzo", "fedavg"))
    ap.add_argument("--b2", type=int, default=1)
    ap.add_argument("--local-iters", type=int, default=2)
    ap.add_argument("--estimator", default="sphere",
                    choices=("sphere", "gaussian", "coordinate"))
    ap.add_argument("--direction-dtype", default="float32",
                    choices=("float32", "bfloat16"))
    ap.add_argument("--donate", action="store_true",
                    help="donate params/cache buffers (in-place update)")
    ap.add_argument("--out", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else (args.arch,)
    shapes = SHAPE_IDS if args.shape == "all" else (args.shape,)
    existing = set()
    if args.out and args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                existing.add((r["arch"], r["shape"], r["multi_pod"], r["algo"]))

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            key = (arch, shape, args.multi_pod, args.algo)
            if key in existing:
                print(f"skip {key}", flush=True)
                continue
            print(f"=== {arch} × {shape} × "
                  f"{'2x16x16' if args.multi_pod else '16x16'} ({args.algo})",
                  flush=True)
            try:
                rec = run_case(arch, shape, multi_pod=args.multi_pod,
                               algo=args.algo, b2=args.b2, h=args.local_iters,
                               estimator=args.estimator,
                               direction_dtype=args.direction_dtype,
                               donate=args.donate)
            except Exception as e:  # noqa: BLE001 — report and continue
                rec = {"arch": arch, "shape": shape, "multi_pod": args.multi_pod,
                       "algo": args.algo, "error": f"{type(e).__name__}: {e}"}
                n_fail += 1
                print(f"FAIL: {rec['error'][:400]}", flush=True)
            else:
                print(json.dumps({k: rec[k] for k in
                                  ("memory", "hlo_flops_per_device",
                                   "roofline_s", "dominant_term", "hbm_ok",
                                   "compile_s")}, indent=1), flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
