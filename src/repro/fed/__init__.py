from repro.fed.server import FedServer, run_seed_compressed_round  # noqa: F401
