"""Federated orchestration: the server loop driving Algorithm 1 end to end.

``FedServer`` owns the global model, samples M of N clients per round
(uniform, per the paper), assembles their pre-sampled local batches, and
calls the jitted round function (FedZO, FedAvg, or a baseline). AirComp and
seed-compression plug in at the aggregation step.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedZOConfig
from repro.core import fedavg, fedzo, seedcomm
from repro.data.synthetic import sample_local_batches
from repro.utils.tree import tree_add, tree_bytes, tree_zeros_like


@dataclass
class FedServer:
    loss_fn: Callable            # loss(params, batch) -> scalar
    params: object               # global model x^t
    clients: list                # list of {"x": ..., "y": ...} datasets
    cfg: FedZOConfig
    algo: str = "fedzo"          # fedzo | fedavg
    eval_fn: Optional[Callable] = None
    history: list = field(default_factory=list)

    def __post_init__(self):
        self._np_rng = np.random.default_rng(self.cfg.seed)
        self._key = jax.random.key(self.cfg.seed)
        self._momentum = None
        if self.algo == "fedzo":
            if self.cfg.server_momentum > 0:
                # momentum state lives on the server and threads through
                # every round (round_simulated returns the updated state)
                self._momentum = tree_zeros_like(self.params)
                self._round = jax.jit(
                    lambda p, b, r, ch, m: fedzo.round_simulated(
                        self.loss_fn, p, b, r, self.cfg, channel_rng=ch,
                        momentum=m))
            else:
                self._round = jax.jit(
                    lambda p, b, r, ch: fedzo.round_simulated(
                        self.loss_fn, p, b, r, self.cfg, channel_rng=ch))
        elif self.algo == "fedavg":
            self._round = jax.jit(
                lambda p, b, ch: fedavg.round_simulated(
                    self.loss_fn, p, b, self.cfg, channel_rng=ch))
        else:
            raise ValueError(self.algo)

    # -- client sampling -----------------------------------------------------
    def sample_clients(self):
        n, m = self.cfg.n_devices, self.cfg.n_participating
        assert len(self.clients) >= n
        return self._np_rng.choice(n, size=min(m, n), replace=False)

    def _stack_batches(self, chosen):
        per = [sample_local_batches(self.clients[i], self._np_rng,
                                    self.cfg.local_iters, self.cfg.b1)
               for i in chosen]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    # -- round ---------------------------------------------------------------
    def run_round(self, t: int):
        chosen = self.sample_clients()
        batches = self._stack_batches(chosen)
        self._key, kr, kc = jax.random.split(self._key, 3)
        if self.algo == "fedzo":
            rngs = jax.random.split(kr, len(chosen))
            if self._momentum is not None:
                self.params, metrics, self._momentum = self._round(
                    self.params, batches, rngs, kc, self._momentum)
            else:
                self.params, metrics = self._round(self.params, batches,
                                                   rngs, kc)
        else:
            self.params, metrics = self._round(self.params, batches, kc)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["round"] = t
        if self.eval_fn is not None:
            metrics.update(self.eval_fn(self.params))
        self.history.append(metrics)
        return metrics

    def run(self, rounds: int, log_every: int = 0, log_fn=print):
        for t in range(rounds):
            m = self.run_round(t)
            if log_every and t % log_every == 0:
                log_fn({k: (round(v, 5) if isinstance(v, float) else v)
                        for k, v in m.items()})
        return self.history


def run_seed_compressed_round(loss_fn, params, clients_batches, rngs, cfg):
    """Reference digital-uplink round: each client ships (key, coeffs); the
    server replays seeds. The M local phases run as ONE vmapped program
    over stacked [M, H, ...] batches and the server replay is one batched
    scan (seedcomm.aggregate). ``clients_batches`` may be a list of
    per-client batch trees or an already-stacked tree; ``rngs`` a list or a
    stacked [M] key array. Returns (params', wire_bytes_total,
    dense_bytes) with both byte counts dtype-exact (actual .nbytes)."""
    if isinstance(clients_batches, (list, tuple)):
        clients_batches = jax.tree.map(lambda *xs: jnp.stack(xs),
                                       *clients_batches)
    if isinstance(rngs, (list, tuple)):
        rngs = jnp.stack(list(rngs))
    res = jax.vmap(
        lambda b, r: fedzo.local_phase(loss_fn, params, b, r, cfg))(
        clients_batches, rngs)
    M = res.coeffs.shape[0]
    msgs = [seedcomm.compress(rngs[i], res.coeffs[i], cfg) for i in range(M)]
    delta = seedcomm.aggregate(msgs, params, cfg)
    dense_bytes = tree_bytes(params) * M
    wire = sum(seedcomm.wire_bytes(m) for m in msgs)
    return tree_add(params, delta), wire, dense_bytes
