"""Federated orchestration: the server loop driving Algorithm 1 end to end.

``FedServer`` owns the global model, samples M of N clients per round
(uniform, per the paper), and runs the jitted round function (FedZO,
FedAvg, or a baseline). AirComp and seed-compression plug in at the
aggregation step.

Two drivers share the class:

- **Host loop** (the reference): per-round Python — numpy client sampling,
  host batch stacking, one jit entry per round. Always available.
- **Sim engine** (repro.sim, DESIGN.md §9): construct with a
  ``store=ClientStore`` and ``run`` executes ALL rounds as one compiled
  ``lax.scan`` — participation draws, minibatch sampling, channel
  realizations, and eval all in-jit, one host sync at the end.
  ``run_round`` on the store path drives the engine's exact round step
  from the host, so the two trajectories are bit-identical.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedZOConfig
from repro.core import aircomp, fedavg, fedzo, seedcomm
from repro.core import strategy as strategy_mod
from repro.data.synthetic import sample_local_batches
from repro.obs.ledger import CommsLedger
from repro.sim.faults import DivergenceError, FaultModel
from repro.utils.tree import tree_add, tree_bytes, tree_zeros_like


@dataclass
class FedServer:
    loss_fn: Callable            # loss(params, batch) -> scalar
    params: object               # global model x^t
    clients: Optional[list]      # list of {"x": ..., "y": ...} datasets
    cfg: FedZOConfig
    # algorithm selection: ``strategy`` (registry name or AlgoStrategy)
    # wins, then the legacy ``algo`` string, then cfg.strategy. After init
    # ``self.algo`` always holds the resolved name.
    algo: Optional[str] = None
    strategy: Optional[object] = None
    eval_fn: Optional[Callable] = None   # host-side, may sync (python loop)
    history: list = field(default_factory=list)
    store: Optional[object] = None       # sim.ClientStore → engine driver
    jit_eval: Optional[Callable] = None  # jit-traceable, runs in-scan
    eval_every: int = 1                  # engine eval cadence (rounds)
    faults: Optional[FaultModel] = None  # in-jit fault injection (§12)
    divergence_guard: bool = False       # roll back non-finite rounds
    max_retries: int = 3                 # lr-backoff retries before failing
    lr_backoff: float = 0.5              # lr multiplier per rollback
    tracer: Optional[object] = None      # obs.Tracer: compile/execute spans

    def __post_init__(self):
        if self.clients is None and self.store is None:
            raise ValueError("FedServer needs client datasets: pass "
                             "clients=[...] and/or store=ClientStore")
        if self.store is not None:
            # either store tier plugs in: the host-driven round needs
            # device residency, so a tiered HostStore materializes here
            # (bit-identical to build_store on the same clients)
            from repro.sim.tiered import resolve_store
            self.store = resolve_store(self.store, tier="resident")
        if self.faults is not None and self.store is None:
            raise ValueError("fault injection runs inside the jitted round "
                             "step — construct the FedServer with a "
                             "store=ClientStore")
        if self.cfg.channel_model is not None and self.store is None:
            raise ValueError("cfg.channel_model (the correlated wireless "
                             "scenario) advances inside the jitted round "
                             "step — construct the FedServer with a "
                             "store=ClientStore")
        n = (len(self.clients) if self.clients is not None
             else self.store.n_clients)
        if n != self.cfg.n_devices:
            raise ValueError(
                f"cfg.n_devices={self.cfg.n_devices} but {n} client "
                f"datasets were provided — the federation size N must "
                f"match the config (did you partition with a different "
                f"n_clients?)")
        if self.cfg.n_participating > n:
            raise ValueError(
                f"cfg.n_participating={self.cfg.n_participating} exceeds "
                f"the federation size N={n}")
        sel = (self.strategy if self.strategy is not None
               else (self.algo or self.cfg.strategy))
        self._strategy = (strategy_mod.get(sel) if isinstance(sel, str)
                          else sel)
        self.algo = self._strategy.name
        self._strategy.validate(self.cfg)
        if self.store is None and self._strategy.name not in ("fedzo",
                                                              "fedavg"):
            raise ValueError(
                f"strategy {self._strategy.name!r} needs the engine round "
                f"step (its state/loss hooks live there) — construct the "
                f"FedServer with a store=ClientStore")
        self._np_rng = np.random.default_rng(self.cfg.seed)
        self._momentum = None
        self._retries = 0
        # successful-round counter: history NUMBERING must not derive from
        # len(self.history) — structured event rows (rollbacks) land in the
        # history too and must not shift round numbers
        self._round_idx = 0
        # jit once for the host-driven rounds; the scan engine traces the
        # raw fn in-scan (wrapping there would be a no-op)
        self._jit_eval = (jax.jit(self.jit_eval)
                          if self.jit_eval is not None else None)
        if self._strategy.has_momentum(self.cfg):
            # momentum state lives on the server and threads through
            # every round (round_simulated returns the updated state)
            self._momentum = tree_zeros_like(self.params)
        self._fstate = (self.faults.init_state(n)
                        if self.faults is not None else None)
        self._zstate = self._strategy.init_state(self.params, self.cfg, n)
        # one byte model per server: host rows and scanned rows get the
        # SAME deterministic ledger columns, so the two drivers stay
        # row-identical (the lr never enters the byte model, so rollback
        # config swaps don't invalidate it)
        self._ledger = CommsLedger.from_run(self.cfg, self.params,
                                            channel=self.cfg.channel_model)
        if self.store is not None:
            from repro.sim import engine as sim_engine
            self._key = sim_engine.experiment_key(self.cfg)
        else:
            self._key = jax.random.key(self.cfg.seed)
        # wireless-scenario carry, initialized off the fold-in key exactly
        # like run_experiment (the round chain is never consumed) so the
        # host-driven and scanned trajectories share one realization
        cm = self.cfg.channel_model
        if cm is not None:
            from repro.sim import channel as channel_lib
            self._cstate = cm.init_state(n, channel_lib.init_key(self._key))
        else:
            self._cstate = None
        self._build_round_fns()

    def _build_round_fns(self):
        """(Re)build the jitted per-round programs for the CURRENT
        ``self.cfg`` — called at init and again after a divergence
        rollback bakes a backed-off lr into the config."""
        self._exp_cache = {}
        if self.store is not None:
            from repro.sim import engine as sim_engine
            self._sim_step = jax.jit(sim_engine.make_round_step(
                self.loss_fn, self.cfg, strategy=self._strategy,
                faults=self.faults))
            return
        # ``w`` is the size-weight vector (None unless cfg.weight_by_size —
        # None is an empty pytree, so the unweighted jit signature is
        # unchanged)
        if self.algo == "fedzo":
            if self._momentum is not None:
                self._round = jax.jit(
                    lambda p, b, r, ch, m, w: fedzo.round_simulated(
                        self.loss_fn, p, b, r, self.cfg, channel_rng=ch,
                        momentum=m, weights=w))
            else:
                self._round = jax.jit(
                    lambda p, b, r, ch, w: fedzo.round_simulated(
                        self.loss_fn, p, b, r, self.cfg, channel_rng=ch,
                        weights=w))
        elif self.algo == "fedavg":
            self._round = jax.jit(
                lambda p, b, ch, w: fedavg.round_simulated(
                    self.loss_fn, p, b, self.cfg, channel_rng=ch, weights=w))
        else:
            raise ValueError(self.algo)

    # -- client sampling (host loop) -----------------------------------------
    def sample_clients(self):
        n = len(self.clients)
        return self._np_rng.choice(n, size=min(self.cfg.n_participating, n),
                                   replace=False)

    def _stack_batches(self, chosen):
        per = [sample_local_batches(self.clients[i], self._np_rng,
                                    self.cfg.local_iters, self.cfg.b1)
               for i in chosen]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    # -- round ---------------------------------------------------------------
    def _step_once(self):
        """Advance one round (store/engine step or host loop) and return
        the fetched metrics dict."""
        if self.store is not None:
            state, metrics = self._sim_step(
                (self.params, self._momentum, self._key, self._fstate,
                 self._cstate, self._zstate), self.store)
            (self.params, self._momentum, self._key, self._fstate,
             self._cstate, self._zstate) = state
        else:
            chosen = self.sample_clients()
            batches = self._stack_batches(chosen)
            weights = None
            if self.cfg.weight_by_size:
                sizes = jnp.asarray(
                    [len(jax.tree.leaves(self.clients[i])[0])
                     for i in chosen], jnp.float32)
                weights = aircomp.size_weights(sizes)
            self._key, kr, kc = jax.random.split(self._key, 3)
            if self.algo == "fedzo":
                rngs = jax.random.split(kr, len(chosen))
                if self._momentum is not None:
                    self.params, metrics, self._momentum = self._round(
                        self.params, batches, rngs, kc, self._momentum,
                        weights)
                else:
                    self.params, metrics = self._round(self.params, batches,
                                                       rngs, kc, weights)
            else:
                self.params, metrics = self._round(self.params, batches, kc,
                                                   weights)
        # ONE host sync for the whole metrics dict, not one per metric
        return {k: float(v) for k, v in jax.device_get(metrics).items()}

    def _diverged(self, metrics: dict) -> bool:
        if any(not np.isfinite(v) for v in metrics.values()
               if isinstance(v, float)):
            return True
        return any(not np.all(np.isfinite(leaf))
                   for leaf in jax.device_get(jax.tree.leaves(self.params)))

    def run_round(self, t: Optional[int] = None):
        """Run one round (numbered ``t``, default the internal successful-
        round counter). With ``divergence_guard`` a round whose metrics,
        eval, or params come back non-finite is ROLLED BACK: the pre-round
        state is restored, the lr is scaled by ``lr_backoff`` (jitted
        programs rebuilt), a structured ``{"round": t, "event":
        "rollback", ...}`` row lands in the history, and the round is
        retried — at most ``max_retries`` consecutive times, then
        ``DivergenceError``."""
        if t is None:
            t = self._round_idx
        while True:
            snap = (self.params, self._momentum, self._key, self._fstate,
                    self._cstate, self._zstate)
            t_start = time.perf_counter()
            metrics = self._step_once()
            metrics["round"] = t
            ev = self.eval_fn or (
                self._jit_eval and (lambda p: {
                    k: float(v)
                    for k, v in jax.device_get(self._jit_eval(p)).items()}))
            if ev:
                if self.tracer is not None:
                    with self.tracer.span("eval", round=t):
                        metrics.update(ev(self.params))
                else:
                    metrics.update(ev(self.params))
            if not self.divergence_guard or not self._diverged(metrics):
                # host wall-clock of the surviving attempt (dispatch +
                # device sync + eval) — the scanned driver has no per-round
                # host time by construction, so this column is host-only
                metrics["round_ms"] = (time.perf_counter() - t_start) * 1e3
                break
            (self.params, self._momentum, self._key, self._fstate,
             self._cstate, self._zstate) = snap
            self._retries += 1
            if self._retries > self.max_retries:
                raise DivergenceError(t, self.max_retries, self.cfg.lr)
            self.cfg = replace(self.cfg, lr=self.cfg.lr * self.lr_backoff)
            self._build_round_fns()
            self.history.append({"round": t, "event": "rollback",
                                 "retry": self._retries, "lr": self.cfg.lr})
        self._retries = 0
        self._round_idx = t + 1
        self._ledger.annotate([metrics])
        self.history.append(metrics)
        return metrics

    def run(self, rounds: int, log_every: int = 0, log_fn=print,
            driver: str = "auto"):
        """Run ``rounds`` rounds. ``driver``: "scan" forces the compiled
        engine (requires a store; host-side ``eval_fn`` is not usable
        there — pass ``jit_eval``), "host" forces the per-round Python
        loop, "auto" picks the engine whenever it can."""
        use_engine = driver == "scan" or (
            driver == "auto" and self.store is not None
            and self.eval_fn is None)
        def log(i, m):
            if log_every and i % log_every == 0:
                log_fn({k: (round(v, 5) if isinstance(v, float) else v)
                        for k, v in m.items()})

        if use_engine:
            if self.store is None:
                raise ValueError("driver='scan' needs store=ClientStore")
            for i, m in enumerate(self._run_scanned(rounds)):
                log(i, m)
        else:
            for i in range(rounds):
                log(i, self.run_round())
        return self.history

    def _run_scanned(self, rounds: int):
        from repro.sim import engine as sim_engine
        fn = self._exp_cache.get(rounds)
        if fn is None:
            # donate=False: the server hands out self.params (history,
            # eval_fn, user code may hold references) — power users get
            # in-place donation through sim.run_experiment directly
            fn = sim_engine.make_experiment_fn(
                self.loss_fn, self.cfg, rounds, strategy=self._strategy,
                eval_fn=self.jit_eval, eval_every=self.eval_every,
                faults=self.faults, donate=False)
            self._exp_cache[rounds] = fn
        args = (self.params, self._momentum, self._key, self._fstate,
                self._cstate, self._zstate, self.store)
        if self.tracer is not None:
            from repro.checkpoint.checkpoint import config_hash
            run = self.tracer.timed_compile(
                ("fedserver", rounds, config_hash(self.cfg),
                 self._strategy.name), fn, *args)
            with self.tracer.span("execute", rounds=rounds):
                out = jax.block_until_ready(run(*args))
        else:
            out = fn(*args)
        (self.params, self._momentum, self._key, self._fstate, self._cstate,
         self._zstate, ring, ebuf) = out
        res = sim_engine.ExperimentResult(
            params=self.params, momentum=self._momentum, key=self._key,
            metrics=ring, evals=ebuf, rounds=rounds, ring_size=rounds,
            eval_rounds=(np.arange(0, rounds, self.eval_every)
                         if self.jit_eval is not None else np.arange(0)),
            fault_state=self._fstate, channel_state=self._cstate,
            strategy=self._strategy.name,
            strategy_state=self._zstate, ledger=self._ledger)
        if self.divergence_guard and self._diverged(
                {k: float(v[-1]) for k, v in
                 jax.device_get(res.metrics).items()}):
            # the one-program scan has no intermediate state to roll back
            # to — fail structurally and point at the recoverable drivers
            raise DivergenceError(
                self._round_idx + rounds, 0, self.cfg.lr,
                detail="the scanned driver has no per-round snapshots; use "
                       "driver='host' or sim.run_experiment(..., "
                       "checkpoint_every=k) for rollback recovery")
        hist = sim_engine.history(res, start_round=self._round_idx)
        self._round_idx += rounds
        self.history.extend(hist)
        return hist


def run_seed_compressed_round(loss_fn, params, clients_batches, rngs, cfg):
    """Reference digital-uplink round: each client ships (key, coeffs); the
    server replays seeds. The M local phases run as ONE vmapped program
    over stacked [M, H, ...] batches, the M wire messages are built as ONE
    stacked bundle (seedcomm.compress_stacked), and the server replay is
    one batched scan (seedcomm.aggregate). ``clients_batches`` may be a
    list of per-client batch trees or an already-stacked tree; ``rngs`` a
    list or a stacked [M] key array. Returns (params', wire_bytes_total,
    dense_bytes) with both byte counts dtype-exact (actual .nbytes)."""
    if isinstance(clients_batches, (list, tuple)):
        clients_batches = jax.tree.map(lambda *xs: jnp.stack(xs),
                                       *clients_batches)
    if isinstance(rngs, (list, tuple)):
        rngs = jnp.stack(list(rngs))
    res = jax.vmap(
        lambda b, r: fedzo.local_phase(loss_fn, params, b, r, cfg))(
        clients_batches, rngs)
    M = res.coeffs.shape[0]
    msgs = seedcomm.compress_stacked(rngs, res.coeffs, cfg)
    delta = seedcomm.aggregate(msgs, params, cfg)
    dense_bytes = tree_bytes(params) * M
    wire = seedcomm.wire_bytes(msgs)
    return tree_add(params, delta), wire, dense_bytes
