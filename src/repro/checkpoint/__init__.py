"""Durable checkpointing: single-snapshot params + full-carry run state."""
from repro.checkpoint.checkpoint import (config_hash, latest_run_state,
                                         restore, restore_run_state, save,
                                         save_run_state)

__all__ = ["config_hash", "latest_run_state", "restore", "restore_run_state",
           "save", "save_run_state"]
