"""Round/step checkpointing: params as .npz (flattened pytree paths) + a JSON
sidecar with step metadata and the FedZO config. Exact-restore is tested."""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(kp): np.asarray(v) for kp, v in flat}, treedef


def save(path, params, *, step=0, meta=None):
    os.makedirs(path, exist_ok=True)
    arrays, _ = _flatten(params)
    np.savez(os.path.join(path, "params.npz"), **arrays)
    md = {"step": int(step)}
    if meta is not None:
        if dataclasses.is_dataclass(meta):
            meta = dataclasses.asdict(meta)
        md["meta"] = meta
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(md, f, indent=1)


def restore(path, params_like):
    """Restore into the structure of ``params_like`` (shape/dtype preserved)."""
    loaded = np.load(os.path.join(path, "params.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_like)
    leaves = []
    for kp, ref in flat:
        arr = loaded[jax.tree_util.keystr(kp)]
        assert arr.shape == ref.shape, (kp, arr.shape, ref.shape)
        leaves.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    with open(os.path.join(path, "meta.json")) as f:
        md = json.load(f)
    return jax.tree_util.tree_unflatten(treedef, leaves), md["step"]
