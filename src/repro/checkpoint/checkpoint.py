"""Durable checkpointing (DESIGN.md §12).

Two layers share one on-disk format (flattened-pytree-path ``.npz`` + JSON
sidecar, exact restore tested):

- ``save``/``restore`` — the original single-snapshot params API.
- ``save_run_state``/``latest_run_state``/``restore_run_state`` — durable
  engine snapshots of the FULL ``run_experiment`` carry (params, momentum,
  key data, fault state, metrics ring, eval buffer) at a round index,
  written ATOMICALLY: the snapshot lands in a temp dir that is renamed
  into place, and only then is the ``LATEST`` pointer file swapped (itself
  via tmp + ``os.replace``). A SIGKILL at any instant leaves either the
  previous consistent snapshot or the new one — never a torn write; stale
  ``*.tmp*`` debris is ignored and swept on the next save.

Every sidecar records the repro config hash, the jax version, and the
wall-clock write time; restore warns when the running jax version differs
(bit-exact trajectories are only pinned per jax version — the golden CI
pin exists for the same reason).
"""
from __future__ import annotations

import dataclasses
import datetime
import hashlib
import json
import os
import shutil
import warnings

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(kp): np.asarray(v) for kp, v in flat}, treedef


def config_hash(cfg) -> str:
    """Stable short hash of a config (dataclass or dict) — recorded in every
    sidecar so a restore into a different experiment is detectable."""
    if dataclasses.is_dataclass(cfg):
        cfg = dataclasses.asdict(cfg)
    blob = json.dumps(cfg, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def _sidecar(meta=None, *, step=None) -> dict:
    md = {"jax_version": jax.__version__,
          "created_at": datetime.datetime.now(
              datetime.timezone.utc).isoformat()}
    if step is not None:
        md["step"] = int(step)
    if meta is not None:
        if dataclasses.is_dataclass(meta):
            md["config_hash"] = config_hash(meta)
            meta = dataclasses.asdict(meta)
        md["meta"] = meta
    return md


def _check_jax_version(md: dict, path: str):
    want = md.get("jax_version")
    if want is not None and want != jax.__version__:
        warnings.warn(
            f"checkpoint {path} was written under jax {want} but this is "
            f"jax {jax.__version__} — bit-exact trajectories are only "
            f"pinned per jax version (see the golden-fixture CI pin)")


def _restore_arrays(npz_path, like):
    """Load flattened arrays into the structure of ``like`` with loud,
    actionable errors: a missing key or a shape mismatch names the exact
    pytree path and both shapes instead of dying on a bare KeyError /
    AssertionError."""
    loaded = np.load(npz_path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, ref in flat:
        name = jax.tree_util.keystr(kp)
        if name not in loaded.files:
            raise ValueError(
                f"checkpoint {npz_path} has no entry for pytree leaf "
                f"{name!r} (file holds {sorted(loaded.files)}); was it "
                f"written from a different model/carry structure?")
        arr = loaded[name]
        if arr.shape != np.shape(ref):
            raise ValueError(
                f"checkpoint {npz_path} leaf {name!r} has shape "
                f"{arr.shape} but the restore target expects "
                f"{np.shape(ref)}; restoring into a different "
                f"model/config?")
        leaves.append(jax.numpy.asarray(arr, dtype=np.asarray(ref).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(path, params, *, step=0, meta=None):
    os.makedirs(path, exist_ok=True)
    arrays, _ = _flatten(params)
    np.savez(os.path.join(path, "params.npz"), **arrays)
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(_sidecar(meta, step=step), f, indent=1)


def restore(path, params_like):
    """Restore into the structure of ``params_like`` (shape/dtype
    preserved). Returns ``(params, step)``."""
    with open(os.path.join(path, "meta.json")) as f:
        md = json.load(f)
    _check_jax_version(md, path)
    params = _restore_arrays(os.path.join(path, "params.npz"), params_like)
    return params, md["step"]


# -- durable run-state snapshots (the engine's full carry) -------------------

_LATEST = "LATEST"


def _snapshot_name(round_idx: int) -> str:
    return f"round_{round_idx:08d}"


def save_run_state(ckpt_dir, state, *, round_idx: int, meta=None,
                   keep: int = 3) -> str:
    """Atomically snapshot a full carry pytree at ``round_idx``.

    Write protocol: stage into ``<name>.tmp.<pid>``, ``os.rename`` the dir
    into place (atomic on POSIX), then swap the ``LATEST`` pointer file via
    tmp + ``os.replace``. Old snapshots beyond the newest ``keep`` (and any
    stale tmp debris from killed writers) are swept AFTER the pointer
    swap, so the pointer never dangles. Returns the snapshot path.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    name = _snapshot_name(round_idx)
    final = os.path.join(ckpt_dir, name)
    tmp = os.path.join(ckpt_dir, f"{name}.tmp.{os.getpid()}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, _ = _flatten(state)
    np.savez(os.path.join(tmp, "state.npz"), **arrays)
    md = _sidecar(dict(meta or {}, round=int(round_idx)))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(md, f, indent=1)
    if os.path.exists(final):  # re-save of the same round (e.g. rollback
        shutil.rmtree(final)   # loops): replace wholesale
    os.rename(tmp, final)
    ptr_tmp = os.path.join(ckpt_dir, f"{_LATEST}.tmp.{os.getpid()}")
    with open(ptr_tmp, "w") as f:
        f.write(name)
    os.replace(ptr_tmp, os.path.join(ckpt_dir, _LATEST))
    _sweep(ckpt_dir, keep=keep)
    return final


def _snapshots(ckpt_dir):
    try:
        entries = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return []
    return sorted(e for e in entries
                  if e.startswith("round_") and ".tmp" not in e
                  and os.path.isdir(os.path.join(ckpt_dir, e)))


def _sweep(ckpt_dir, *, keep: int):
    latest = None
    ptr = os.path.join(ckpt_dir, _LATEST)
    if os.path.exists(ptr):
        with open(ptr) as f:
            latest = f.read().strip()
    snaps = _snapshots(ckpt_dir)
    drop = set(snaps[:-keep]) if keep > 0 else set()
    drop.discard(latest)
    for e in os.listdir(ckpt_dir):
        if e in drop or (".tmp" in e and e != latest):
            target = os.path.join(ckpt_dir, e)
            if os.path.isdir(target):
                shutil.rmtree(target, ignore_errors=True)
            elif e != _LATEST:
                try:
                    os.remove(target)
                except OSError:
                    pass


def latest_run_state(ckpt_dir):
    """Path of the newest consistent snapshot in ``ckpt_dir`` (via the
    ``LATEST`` pointer, falling back to the highest complete round dir),
    or None when the dir holds no snapshot — a fresh start."""
    ptr = os.path.join(ckpt_dir, _LATEST)
    if os.path.exists(ptr):
        with open(ptr) as f:
            name = f.read().strip()
        cand = os.path.join(ckpt_dir, name)
        if os.path.exists(os.path.join(cand, "meta.json")):
            return cand
    for name in reversed(_snapshots(ckpt_dir)):
        cand = os.path.join(ckpt_dir, name)
        if os.path.exists(os.path.join(cand, "meta.json")):
            return cand
    return None


def read_sidecar(snapshot_path) -> dict:
    """The raw JSON sidecar of a snapshot (save/save_run_state layouts
    both) WITHOUT loading any arrays — provenance readers (the obs run
    manifest cross-check, tooling that lists checkpoint dirs) use this to
    get at ``config_hash``/``jax_version``/``round`` cheaply. A
    ``config_hash`` recorded inside a dict-meta (the run-state layout) is
    hoisted to the top level so both layouts read uniformly."""
    with open(os.path.join(snapshot_path, "meta.json")) as f:
        md = json.load(f)
    meta = md.get("meta")
    if isinstance(meta, dict) and "config_hash" not in md \
            and "config_hash" in meta:
        md["config_hash"] = meta["config_hash"]
    return md


def restore_run_state(snapshot_path, state_like):
    """Restore a full-carry snapshot into the structure of ``state_like``.
    Returns ``(state, meta dict)`` where meta is the flattened sidecar
    (round, lr, events, config_hash, ...)."""
    with open(os.path.join(snapshot_path, "meta.json")) as f:
        md = json.load(f)
    _check_jax_version(md, snapshot_path)
    state = _restore_arrays(os.path.join(snapshot_path, "state.npz"),
                            state_like)
    return state, md.get("meta", {})
