"""Federated hyperparameter tuning as an engine-native ZO workload.

The second gradients-unavailable scenario the paper motivates: tuning the
hyperparameters of a learner is a black-box problem — the objective is the
validation loss of an INNER-trained model, and no gradient of that loss
w.r.t. the hyperparameters is available to the clients. FedZO fits
directly: the federated "model" is a small vector of transformed
hyperparameters, every loss query runs the inner training to completion
inside the trace, and clients hold PRIVATE validation shards so the tuned
hyperparameters generalize across the federation rather than overfitting
one client's data.

Concretely (DESIGN.md §10): the server state is ``{"h": [log lr, log λ]}``
for an L2-regularized softmax head; ``loss(params, batch)`` inner-trains
the head on a shared public training set with ``lr = exp(h[0])``,
``λ = exp(h[1])`` (a lax.scan of full-batch GD steps, jit-traceable) and
returns the trained head's cross-entropy on the client's private validation
minibatch. The whole tuning run — inner trainings included — executes as
one compiled ``lax.scan`` over communication rounds.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sim
from repro.configs.base import FedZOConfig
from repro.data.synthetic import dirichlet_partition, make_classification
from repro.models.simple import softmax_accuracy, softmax_loss

# keep exp() of perturbed log-hyperparameters in a numerically sane band
LOG_LR_RANGE = (-7.0, 1.0)
LOG_LAM_RANGE = (-9.0, 2.0)


class HyperTuneTask(NamedTuple):
    """Shared public train set + private per-client validation shards."""
    train: dict
    clients: list
    store: sim.ClientStore
    val_all: dict
    inner_steps: int
    n_features: int
    n_classes: int


@functools.lru_cache(maxsize=2)
def make_task(n_train=256, n_val=768, n_clients=8, n_features=32,
              n_classes=4, seed=0, inner_steps=12, alpha=0.5) -> HyperTuneTask:
    """Synthetic tuning problem: one public train split, the validation
    rows Dirichlet(α)-label-skewed across ``n_clients`` private shards."""
    x, y = make_classification(n_train + n_val, n_features, n_classes,
                               seed=seed)
    train = {"x": jnp.asarray(x[:n_train]), "y": jnp.asarray(y[:n_train])}
    clients = dirichlet_partition(x[n_train:], y[n_train:], n_clients,
                                  alpha=alpha, seed=seed)
    return HyperTuneTask(train=train, clients=clients,
                         store=sim.build_store(clients),
                         val_all={"x": jnp.asarray(x[n_train:]),
                                  "y": jnp.asarray(y[n_train:])},
                         inner_steps=inner_steps, n_features=n_features,
                         n_classes=n_classes)


def hp_init(log_lr=-4.0, log_lam=-4.0):
    """Deliberately mis-tuned start (tiny inner lr → underfit head) so the
    tuner has something to find."""
    return {"h": jnp.asarray([log_lr, log_lam], jnp.float32)}


def transform(h):
    """(lr, λ) from the unconstrained log-space tuning vector."""
    return (jnp.exp(jnp.clip(h[0], *LOG_LR_RANGE)),
            jnp.exp(jnp.clip(h[1], *LOG_LAM_RANGE)))


def inner_train(task: HyperTuneTask, h):
    """Train the regularized softmax head under hyperparameters ``h`` —
    ``inner_steps`` full-batch GD steps on the shared train set, traceable
    so it runs inside every ZO loss query (the inner problem is allowed
    gradients; only the OUTER objective is black-box)."""
    lr, lam = transform(h)

    def reg_loss(p):
        return softmax_loss(p, task.train) + 0.5 * lam * jnp.sum(p["w"] ** 2)

    grad = jax.grad(reg_loss)

    def step(p, _):
        g = grad(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), None

    p0 = {"w": jnp.zeros((task.n_features, task.n_classes), jnp.float32),
          "b": jnp.zeros((task.n_classes,), jnp.float32)}
    head, _ = jax.lax.scan(step, p0, None, length=task.inner_steps)
    return head


def tune_loss(task: HyperTuneTask):
    """The engine's loss contract: params = the hyperparameter vector,
    batch = a private validation minibatch, value = the inner-trained
    head's validation cross-entropy."""
    def loss(params, batch):
        return softmax_loss(inner_train(task, params["h"]), batch)
    return loss


def tune_eval(task: HyperTuneTask):
    """In-scan eval: pooled validation loss/accuracy of the currently
    tuned hyperparameters plus the (log) hyperparameters themselves."""
    def ev(params):
        head = inner_train(task, params["h"])
        return {"val_loss": softmax_loss(head, task.val_all),
                "val_acc": softmax_accuracy(head, task.val_all),
                "log_lr": params["h"][0], "log_lam": params["h"][1]}
    return ev


def default_config(task: HyperTuneTask, **overrides) -> FedZOConfig:
    """The tuning problem is 2-dimensional, so few directions and a larger
    smoothing radius (log-space units) work well; size weighting matches
    the skewed validation shards."""
    kw = dict(n_devices=task.store.n_clients,
              n_participating=min(4, task.store.n_clients),
              local_iters=2, lr=0.2, mu=0.05, b1=16, b2=6,
              weight_by_size=True)
    kw.update(overrides)
    return FedZOConfig(**kw)


def run(task: HyperTuneTask, cfg: FedZOConfig, rounds: int, *, eval_every=2,
        **kw) -> sim.ExperimentResult:
    """One federated tuning run inside ONE compiled program."""
    return sim.run_experiment(tune_loss(task), hp_init(), task.store, cfg,
                              rounds, eval_fn=tune_eval(task),
                              eval_every=eval_every, **kw)
