"""repro.workloads — engine-native gradient-free tasks (DESIGN.md §10).

The paper motivates FedZO by the settings where gradients are unavailable;
this package makes those settings first-class workloads on the simulation
engine (repro.sim): each workload builds a device-resident ``ClientStore``,
exposes its objective through the ``loss(params, batch) -> scalar``
contract, ships a jit-traceable in-scan eval, and runs whole experiments /
scenario sweeps as single compiled programs.

- ``attack``    — the Sec. V-A federated black-box adversarial attack
  (CW loss on a frozen classifier; clients hold private image shards).
- ``hypertune`` — federated hyperparameter tuning: the "model" is a small
  vector of transformed hyperparameters, the ZO loss is the inner-trained
  validation loss on each client's private shard.
- ``neural``    — the Sec. V-B *training* track (DESIGN.md §11): softmax
  regression, a trainable LeNet-style SmallCNN, and a tiny transformer
  head as engine-native FedZO tasks with in-scan top-1 accuracy eval.
"""
from __future__ import annotations

from repro.workloads import attack, hypertune, neural

__all__ = ["attack", "hypertune", "neural"]
