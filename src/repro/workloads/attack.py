"""Engine-native federated black-box adversarial attack (paper Sec. V-A).

FedZO finds ONE shared perturbation that fools a frozen classifier, querying
only its outputs (CW loss, Eq. 21) — the canonical gradients-unavailable
scenario. This module ports the task onto the simulation engine
(DESIGN.md §9/§10): the per-client attack images live in a device-resident
``ClientStore`` (uneven sizes per the paper — 'each device is assigned a
random number of samples' — or Dirichlet label skew), the attack-success
eval runs in-scan, and the paper's SNR sweep is one vmapped compiled
program per static shape, landing as long-format CSV in ``results/``.

The classifier stands in for the pretrained CIFAR-10 network (the container
is offline): a small CNN trained in-repo on synthetic CIFAR-like images;
the attack only ever queries it as a black box.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sim
from repro.configs.base import FedZOConfig
from repro.data.synthetic import (dirichlet_partition, make_classification,
                                  random_partition)
from repro.models import simple

IMAGE_SHAPE = (32, 32, 3)
D = int(np.prod(IMAGE_SHAPE))
# CW margin-vs-distortion trade-off: weights the attack term enough to make
# visible progress at reduced round counts. ONE constant shared by the loss
# and the in-scan eval so the reported curve is the optimized objective.
CW_C = 0.3


class AttackTask(NamedTuple):
    """The federated attack problem: a frozen black-box classifier, the
    per-client image shards (host lists + stacked device store), and the
    pooled correctly-classified images the success rate is measured on."""
    classifier: dict
    clients: list
    store: sim.ClientStore
    eval_batch: dict
    clean_accuracy: float


@functools.lru_cache(maxsize=2)
def make_task(n_train=2000, n_attack=512, n_clients=10, seed=0,
              train_steps=300, partition="uneven", alpha=0.5) -> AttackTask:
    """Train the surrogate classifier on synthetic CIFAR-like data, keep the
    correctly-classified images, and split them across ``n_clients``
    (``partition``: "uneven" random sizes, "dirichlet" label skew with
    concentration ``alpha``, or "even")."""
    x, y = make_classification(n_train + 512, D, 10, seed=seed,
                               scale=0.35, image_shape=IMAGE_SHAPE)
    xtr, ytr = jnp.asarray(x[:n_train]), jnp.asarray(y[:n_train])
    params = simple.cnn_init(jax.random.key(seed))

    @jax.jit
    def sgd_step(p, xb, yb):
        loss, g = jax.value_and_grad(simple.cnn_loss)(p, {"x": xb, "y": yb})
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g), loss

    rng = np.random.default_rng(seed)
    for _ in range(train_steps):
        idx = rng.integers(0, n_train, 64)
        params, _ = sgd_step(params, xtr[idx], ytr[idx])

    pred = jnp.argmax(simple.cnn_logits(params, jnp.asarray(x)), -1)
    correct = np.asarray(pred == jnp.asarray(y))
    acc = correct[:n_train].mean()
    xi, yi = x[correct][:n_attack], y[correct][:n_attack]
    flat = xi.reshape(len(yi), -1)
    if partition == "dirichlet":
        clients = dirichlet_partition(flat, yi, n_clients, alpha=alpha,
                                      seed=seed)
    else:
        clients = random_partition(flat, yi, n_clients, seed=seed,
                                   uneven=(partition == "uneven"))
    for c in clients:
        c["x"] = c["x"].reshape((-1,) + IMAGE_SHAPE)
    return AttackTask(classifier=params, clients=clients,
                      store=sim.build_store(clients),
                      eval_batch={"x": jnp.asarray(xi), "y": jnp.asarray(yi)},
                      clean_accuracy=float(acc))


def attack_loss(task: AttackTask, c=CW_C):
    """The engine's loss contract for the CW objective: ``loss(pert_params,
    batch) -> scalar`` with the classifier closed over as a black box.
    Pass the same ``c`` to ``attack_eval`` when overriding it."""
    def loss(pert_params, batch):
        return simple.cw_attack_loss(pert_params["x"], batch,
                                     task.classifier, c=c)
    return loss


def attack_eval(task: AttackTask, c=CW_C):
    """jit-traceable in-scan eval: attack success rate + CW loss over the
    pooled correctly-classified images (``c`` must match the loss's so the
    reported curve is the optimized objective)."""
    def ev(pert_params):
        return {"attack_success": simple.attack_success(
                    pert_params["x"], task.eval_batch, task.classifier),
                "eval_cw_loss": simple.cw_attack_loss(
                    pert_params["x"], task.eval_batch, task.classifier,
                    c=c)}
    return ev


def pert_init():
    """The shared perturbation the federation optimizes (the ZO variable)."""
    return {"x": jnp.zeros((D,), jnp.float32)}


def default_config(task: AttackTask, **overrides) -> FedZOConfig:
    """The example's attack hyperparameters (Sec. V-A scale-reduced):
    full participation, H=20 local iterates, b2=20 directions."""
    kw = dict(n_devices=task.store.n_clients,
              n_participating=task.store.n_clients,
              local_iters=20, lr=1e-3, mu=1e-3, b1=25, b2=20,
              weight_by_size=True)
    kw.update(overrides)
    return FedZOConfig(**kw)


def run(task: AttackTask, cfg: FedZOConfig, rounds: int, *, eval_every=5,
        **kw) -> sim.ExperimentResult:
    """One attack experiment inside ONE compiled program: store-driven
    rounds with the in-scan attack-success eval every ``eval_every``."""
    return sim.run_experiment(attack_loss(task), pert_init(), task.store,
                              cfg, rounds, eval_fn=attack_eval(task),
                              eval_every=eval_every, **kw)


def run_sweep(task: AttackTask, base_cfg: FedZOConfig, *, snr_dbs, seeds,
              rounds: int, eval_every=5, out_csv=None):
    """The Fig.-4-style AirComp SNR curve family: an SNR × seed grid over
    the attack experiment, one compile for the whole family (the SNR and
    seed axes vmap — sim/sweep.py), curves dumped as long-format CSV."""
    import dataclasses
    cfg = dataclasses.replace(base_cfg, aircomp=True)
    scen = sim.scenario_grid(snr_db=tuple(float(s) for s in snr_dbs),
                             seed=tuple(int(s) for s in seeds))
    return sim.run_sweep(attack_loss(task), pert_init(), task.store, cfg,
                         scen, rounds, eval_fn=attack_eval(task),
                         eval_every=eval_every, out_csv=out_csv)
