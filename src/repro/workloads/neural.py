"""Engine-native neural FedZO: the paper's Sec. V-B training track
(DESIGN.md §11).

The headline experiments (Figs. 2–6) train *neural* models — softmax
regression and a LeNet-style CNN on (Fashion-)MNIST/FEMNIST — under varying
local iterates H, participating devices M, and AirComp SNR. This module is
the ``models ↔ sim`` bridge that makes any init/loss/accuracy triple a
first-class FedZO workload: the model trains MeZO-style (forward passes
only — ``jax.grad`` of the model is never taken), its parameter pytree
flows through ``FlatParams`` on the flat/wide hot paths unchanged, and the
whole multi-round run — participation draws, minibatch sampling, the H·b2
perturbed forwards per client, aggregation (plain / size-weighted / AirComp
/ channel-truncated / clients-mesh sharded), and the in-scan top-1 accuracy
eval — executes as ONE compiled program via ``sim.run_experiment``.

Three registered tracks (``make_task(name)``):

- ``softmax``     — the Sec. V-B multinomial classifier (models/simple).
- ``cnn``         — the trainable LeNet-style SmallCNN (models/simple).
- ``transformer`` — a tiny patch-token transformer head built from the
  LM stack's blocks (models/transformer.init_classifier).

Clients hold Dirichlet(α)-label-skewed shards of a synthetic
class-conditional Gaussian problem (``data.synthetic``; the container is
offline, so F-MNIST is replaced by a generator that preserves the problem's
shape), stacked once into a device-resident ``ClientStore``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import sim
from repro.configs.base import FedZOConfig, ModelConfig
from repro.data.synthetic import federated_classification
from repro.models import simple, transformer


class NeuralTask(NamedTuple):
    """A trainable federated classification problem: the init/loss/accuracy
    triple under the engine's ``loss(params, batch) -> scalar`` contract,
    the client shards (host lists + stacked device store), and the pooled
    held-out test batch the in-scan eval reads."""
    name: str
    init: Callable        # (seed) -> params pytree
    loss: Callable        # (params, batch) -> scalar mean cross-entropy
    accuracy: Callable    # (params, batch) -> top-1 accuracy
    clients: list
    store: sim.ClientStore
    test: dict            # pooled {"x", "y"} held-out batch


def _softmax_triple(n_features, n_classes, kw):
    return (lambda seed: simple.softmax_init(None, n_features, n_classes),
            simple.softmax_loss, simple.softmax_accuracy, None)


def _cnn_triple(n_features, n_classes, kw):
    shape = kw.pop("image_shape")
    width = kw.pop("width", 8)
    return (lambda seed: simple.smallcnn_init(jax.random.key(seed), shape,
                                              n_classes, width),
            simple.smallcnn_loss, simple.smallcnn_accuracy, shape)


def _transformer_triple(n_features, n_classes, kw):
    n_patches = kw.pop("n_patches", 8)
    if n_features % n_patches:
        raise ValueError(f"n_features={n_features} must split into "
                         f"{n_patches} patch tokens")
    d_model = kw.pop("d_model", 32)
    n_heads = kw.pop("n_heads", 2)
    cfg = ModelConfig(
        name="tiny-patch-cls", family="dense",
        source="repro-internal tiny head (DESIGN.md §11)",
        n_layers=kw.pop("n_layers", 1), d_model=d_model,
        d_ff=kw.pop("d_ff", 64), vocab=0, n_heads=n_heads,
        n_kv_heads=n_heads, head_dim=d_model // n_heads,
        act="gelu", dtype="float32")
    patch_dim = n_features // n_patches
    return (lambda seed: transformer.init_classifier(
                jax.random.key(seed), cfg, n_patches=n_patches,
                patch_dim=patch_dim, n_classes=n_classes),
            lambda p, b: transformer.classifier_loss(p, b, cfg),
            lambda p, b: transformer.classifier_accuracy(p, b, cfg),
            None)


_TRIPLES = {"softmax": _softmax_triple, "cnn": _cnn_triple,
            "transformer": _transformer_triple}


def make_task(name="softmax", **kw) -> NeuralTask:
    """Build a registered neural FedZO task.

    ``name``: softmax | cnn | transformer. The data is a synthetic
    class-conditional Gaussian problem (image-shaped and squashed to [0, 1]
    pixels for the cnn track) split ``partition``-wise across ``n_clients``
    (Dirichlet label skew by default; see ``_make_task`` for the data
    defaults). Extra keywords reach the model builder (cnn: image_shape,
    width; transformer: n_patches, n_layers, d_model, d_ff, n_heads).
    Cached: repeated calls with identical arguments (tests, benchmarks,
    figures) reuse the built store.
    """
    if kw.get("image_shape") is not None:
        # normalize before the cache layer — a list would fail lru_cache's
        # key hashing before the body could coerce it
        kw["image_shape"] = tuple(kw["image_shape"])
    return _make_task(name, **kw)


@functools.lru_cache(maxsize=8)
def _make_task(name, *, n_train=2000, n_test=512, n_clients=10,
               n_features=784, n_classes=10, seed=0, scale=1.0,
               partition="dirichlet", alpha=0.5, **model_kw) -> NeuralTask:
    if name not in _TRIPLES:
        raise ValueError(f"unknown neural task {name!r}; registered: "
                         f"{sorted(_TRIPLES)}")
    kw = dict(model_kw)
    if name == "cnn":
        shape = tuple(kw.get("image_shape") or (28, 28, 1))
        kw["image_shape"] = shape
        n_features = 1
        for s in shape:
            n_features *= s
    init, loss, acc, image_shape = _TRIPLES[name](n_features, n_classes, kw)
    if kw:
        # the triples pop what they consume — a misspelled model kwarg must
        # fail here, not silently build-and-cache a default-model task
        raise ValueError(f"unknown model kwargs for task {name!r}: "
                         f"{sorted(kw)}")
    clients, test = federated_classification(
        n_train, n_test, n_clients, n_features=n_features,
        n_classes=n_classes, seed=seed, scale=scale,
        image_shape=image_shape, partition=partition, alpha=alpha)
    return NeuralTask(name=name, init=init, loss=loss, accuracy=acc,
                      clients=clients, store=sim.build_store(clients),
                      test={"x": jnp.asarray(test["x"]),
                            "y": jnp.asarray(test["y"])})


def params_init(task: NeuralTask, seed: int = 0):
    """Fresh model parameters for a task (the FedZO server state x^0)."""
    return task.init(seed)


def task_eval(task: NeuralTask, max_rows: int = 1024):
    """jit-traceable in-scan eval: pooled top-1 test accuracy + test loss.
    ``max_rows`` bounds the per-eval forward (the eval runs INSIDE the
    compiled scan every k rounds, so its cost is paid rounds/k times)."""
    test = jax.tree.map(lambda a: a[:max_rows], task.test)

    def ev(params):
        return {"test_acc": task.accuracy(params, test),
                "test_loss": task.loss(params, test)}

    return ev


def default_config(task: NeuralTask, **overrides) -> FedZOConfig:
    """Sec. V-B-shaped hyperparameters at container scale: partial
    participation, H=5 local iterates, b2=20 directions, size-weighted
    aggregation for the skewed shards."""
    kw = dict(n_devices=task.store.n_clients,
              n_participating=max(2, task.store.n_clients // 2),
              local_iters=5, lr=5e-3, mu=1e-3, b1=25, b2=20,
              weight_by_size=True)
    kw.update(overrides)
    return FedZOConfig(**kw)


def run(task: NeuralTask, cfg: FedZOConfig, rounds: int, *, eval_every=2,
        mesh=None, eval_rows=1024, **kw) -> sim.ExperimentResult:
    """Train the task's model with FedZO inside ONE compiled program.

    ``mesh`` (a ``sim.make_clients_mesh()``) fans the M sampled clients out
    over a device mesh via the sharded round — the experiment is still one
    scan. All aggregation paths (flat / wide / AirComp / channel-schedule /
    weighted) come straight from ``cfg``.
    """
    if mesh is not None:
        kw.setdefault("round_fn", sim.make_sharded_round(task.loss, cfg,
                                                         mesh))
    return sim.run_experiment(task.loss, params_init(task, cfg.seed),
                              task.store, cfg, rounds,
                              eval_fn=task_eval(task, eval_rows),
                              eval_every=eval_every, **kw)


def run_sweep(task: NeuralTask, base_cfg: FedZOConfig, scenarios, rounds, *,
              eval_every=2, eval_rows=1024, out_csv=None) -> list:
    """A scenario grid over the task — {H, M} group per compile, the
    {snr_db, lr, mu, h_min, seed} axes vmapped (sim/sweep.py); per-round
    metrics and the in-scan accuracy curve land as long-format CSV."""
    return sim.run_sweep(task.loss, params_init(task, base_cfg.seed),
                         task.store, base_cfg, scenarios, rounds,
                         eval_fn=task_eval(task, eval_rows),
                         eval_every=eval_every, out_csv=out_csv)
