"""Seed-based delta compression (beyond-paper, DESIGN.md §3.4).

FedZO's local delta is a linear combination of PRNG-generated directions:

    Δ_i = −η · Σ_{k<H} Σ_{n<b2} (c_{i,k,n} / b2) · v(seed_i, k, n)

so a client can upload {seed_i, c_i ∈ R^{H·b2}} — H·b2 scalars instead of d
floats. Every receiver (server or peer pod) replays the seeds to reconstruct
Δ_i exactly (bit-exact: fold_in is deterministic). Uplink bytes per round per
client drop from 4d to 4·H·b2 (+ a 16-byte key): for deepseek-v3-671b at
H=5, b2=4 that is 2.7 TB → 96 B, a ~10^10× reduction — the *digital*
counterpart of the paper's analog AirComp aggregation.

The catch (recorded honestly): the server pays H·b2 axpy passes over the
parameter vector per client to reconstruct, so this trades uplink bandwidth
for server HBM traffic. On a pod, reconstruction is itself sharded (each
device replays only its parameter shard), so the cost is d/n_chips per
device — see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.core import estimator
from repro.utils.tree import tree_add, tree_scale, tree_zeros_like


def compress(rng, coeffs, cfg: FedZOConfig):
    """The wire message for one client round: (key, coeffs [H, b2])."""
    return {"key": jax.random.key_data(rng), "coeffs": coeffs,
            "lr": jnp.float32(cfg.lr)}


def wire_bytes(msg) -> int:
    return int(msg["coeffs"].size * 4 + 16 + 4)


def reconstruct_delta(msg, params_like, cfg: FedZOConfig):
    """Replay Δ = −η Σ_k Σ_n (c[k,n]/b2) v(key, k, n) on this host/shard."""
    rng = jax.random.wrap_key_data(msg["key"])
    H = msg["coeffs"].shape[0]
    keys = jax.random.split(rng, H)

    def body(k, delta):
        return estimator.apply_coefficients(
            delta, keys[k], msg["coeffs"][k], scale=-msg["lr"],
            kind=cfg.estimator), None

    delta, _ = jax.lax.scan(lambda d, k: body(k, d),
                            tree_zeros_like(params_like), jnp.arange(H))
    return delta


def aggregate(msgs, params_like, cfg: FedZOConfig):
    """Mean of M reconstructed deltas. msgs: list of compress() outputs."""
    total = tree_zeros_like(params_like)
    for msg in msgs:
        total = tree_add(total, reconstruct_delta(msg, params_like, cfg))
    return tree_scale(1.0 / len(msgs), total)
