"""Seed-based delta compression (beyond-paper, DESIGN.md §3.4).

FedZO's local delta is a linear combination of PRNG-generated directions:

    Δ_i = −η · Σ_{k<H} Σ_{n<b2} (c_{i,k,n} / b2) · v(seed_i, k, n)

so a client can upload {seed_i, c_i ∈ R^{H·b2}} — H·b2 scalars instead of d
floats. Every receiver (server or peer pod) replays the seeds to reconstruct
Δ_i exactly (bit-exact: fold_in is deterministic). Uplink bytes per round per
client drop from 4d to 4·H·b2 (+ a 16-byte key): for deepseek-v3-671b at
H=5, b2=4 that is 2.7 TB → 96 B, a ~10^10× reduction — the *digital*
counterpart of the paper's analog AirComp aggregation.

The catch (recorded honestly): the server pays H·b2 axpy passes over the
parameter vector per client to reconstruct, so this trades uplink bandwidth
for server HBM traffic. On a pod, reconstruction is itself sharded (each
device replays only its parameter shard), so the cost is d/n_chips per
device — see EXPERIMENTS.md §Perf.

The flat-buffer hot path (cfg.flat_params, DESIGN.md §7) keeps the wire
format IDENTICAL — still (key, coeffs [H, b2]) — but collapses the server's
reconstruction cost from H·b2 axpy passes to H single-pass zo_replay calls:
the b2 directions of each local iterate are regenerated in-kernel from the
counter convention and accumulated in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.core import estimator
from repro.utils.flatparams import flat_geometry, unflatten
from repro.utils.tree import tree_add, tree_scale, tree_zeros_like


def compress(rng, coeffs, cfg: FedZOConfig):
    """The wire message for one client round: (key, coeffs [H, b2])."""
    return {"key": jax.random.key_data(rng), "coeffs": coeffs,
            "lr": jnp.float32(cfg.lr)}


def wire_bytes(msg) -> int:
    return int(msg["coeffs"].size * 4 + 16 + 4)


def reconstruct_delta(msg, params_like, cfg: FedZOConfig):
    """Replay Δ = −η Σ_k Σ_n (c[k,n]/b2) v(key, k, n) on this host/shard.

    Same wire message either way; cfg.flat_params selects how the receiver
    replays it: b2 axpy passes per iterate (pytree) or one zo_replay pass
    per iterate (flat, in-kernel direction regeneration).
    """
    rng = jax.random.wrap_key_data(msg["key"])
    H = msg["coeffs"].shape[0]
    keys = jax.random.split(rng, H)

    if cfg.flat_params:
        # must match the sender's geometry exactly (bit-exact seed replay)
        spec, br = flat_geometry(params_like, cfg.flat_block_rows)

        def fbody(buf, k):
            buf = estimator.flat_apply_coefficients(
                buf, spec, keys[k], msg["coeffs"][k], scale=-msg["lr"],
                kind=cfg.estimator, block_rows=br)
            return buf, None

        buf, _ = jax.lax.scan(fbody, jnp.zeros((spec.n_pad,), jnp.float32),
                              jnp.arange(H))
        return unflatten(buf, spec)

    conv = cfg.direction_conv

    def body(k, delta):
        return estimator.apply_coefficients(
            delta, keys[k], msg["coeffs"][k], scale=-msg["lr"],
            kind=cfg.estimator, conv=conv), None

    delta, _ = jax.lax.scan(lambda d, k: body(k, d),
                            tree_zeros_like(params_like), jnp.arange(H))
    return delta


def aggregate(msgs, params_like, cfg: FedZOConfig):
    """Mean of M reconstructed deltas. msgs: list of compress() outputs."""
    total = tree_zeros_like(params_like)
    for msg in msgs:
        total = tree_add(total, reconstruct_delta(msg, params_like, cfg))
    return tree_scale(1.0 / len(msgs), total)
