"""Seed-based delta compression (beyond-paper, DESIGN.md §3.4).

FedZO's local delta is a linear combination of PRNG-generated directions:

    Δ_i = −η · Σ_{k<H} Σ_{n<b2} (c_{i,k,n} / b2) · v(seed_i, k, n)

so a client can upload {seed_i, c_i ∈ R^{H·b2}} — H·b2 scalars instead of d
floats. Every receiver (server or peer pod) replays the seeds to reconstruct
Δ_i exactly (bit-exact: fold_in is deterministic). Uplink bytes per round per
client drop from 4d to 4·H·b2 (+ the 8-byte threefry key and a 4-byte lr):
for deepseek-v3-671b at H=5, b2=4 that is 2.7 TB → 92 B, a ~10^10×
reduction — the *digital* counterpart of the paper's analog AirComp
aggregation.

The catch (recorded honestly): the server pays H·b2 axpy passes over the
parameter vector per client to reconstruct, so this trades uplink bandwidth
for server HBM traffic. On a pod, reconstruction is itself sharded (each
device replays only its parameter shard), so the cost is d/n_chips per
device — see EXPERIMENTS.md §Perf.

The flat-buffer hot path (cfg.flat_params, DESIGN.md §7) keeps the wire
format IDENTICAL — still (key, coeffs [H, b2]) — but collapses the server's
reconstruction cost from H·b2 axpy passes to H single-pass zo_replay calls:
the b2 directions of each local iterate are regenerated in-kernel from the
counter convention and accumulated in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.core import estimator
from repro.utils.flatparams import flat_geometry, unflatten
from repro.utils.tree import tree_scale, tree_zeros_like


def _wire_key_data(rngs):
    """key_data with the wire contract enforced: the format ships the
    8-byte threefry key, so rbg-family keys (4 words — what
    ``sim.fast_sim_config`` installs for the engine's in-scan streams)
    must be rejected HERE, not as a shape error deep inside the replay."""
    kd = jax.random.key_data(rngs)
    if kd.shape[-1] != 2:
        raise ValueError(
            f"seed-compression wire format carries the 8-byte threefry key; "
            f"got {kd.shape[-1]}-word key data (cfg.prng_impl='rbg'/"
            f"'unsafe_rbg'?) — use threefry2x32 keys for seed-compressed "
            f"uplinks")
    return kd


def _check_replayable(cfg: FedZOConfig):
    """Block-convention coefficients exist only inside the simulation
    engine: a receiver replaying them through the tree/counter conventions
    would rebuild uncorrelated directions with no error (the worst kind of
    wrong), so reject them loudly at the replay boundary."""
    if cfg.batch_directions and cfg.direction_conv != "tree":
        raise ValueError(
            "coefficients from the batched-direction path with "
            "direction_conv='block' are not seed-replayable — use "
            "direction_conv='tree' (bit-identical directions) or the flat "
            "counter path for seed-compressed uplinks")


def compress(rng, coeffs, cfg: FedZOConfig):
    """The wire message for one client round: (key, coeffs [H, b2])."""
    return {"key": _wire_key_data(rng), "coeffs": coeffs,
            "lr": jnp.float32(cfg.lr)}


def compress_stacked(rngs, coeffs, cfg: FedZOConfig):
    """All M wire messages of a round as ONE stacked bundle — no Python
    loop: (keys [M, 2], coeffs [M, H, b2], lrs [M]). Byte-identical on the
    wire to M ``compress`` messages; ``aggregate`` and ``wire_bytes``
    accept the bundle directly. ``rngs`` is a stacked [M] key array."""
    return {"key": _wire_key_data(rngs), "coeffs": coeffs,
            "lr": jnp.full((coeffs.shape[0],), cfg.lr, jnp.float32)}


def wire_bytes(msg) -> int:
    """Exact uplink bytes of one message (or of a whole compress_stacked
    bundle — the stacked arrays' nbytes ARE the per-message total): key
    words + coeffs + the lr scalar, all from actual array nbytes (threefry
    key_data is 2×uint32 = 8 B, not the 16 a typed-key pickle would
    cost)."""
    return int(jnp.asarray(msg["key"]).nbytes + msg["coeffs"].nbytes
               + jnp.asarray(msg["lr"]).nbytes)


def wire_bytes_model(cfg: FedZOConfig) -> int:
    """The STATIC per-client byte model of one wire message — the number
    ``wire_bytes`` measures, derived from the config alone: the 8-byte
    threefry key + H·b2 float32 coefficients + the 4-byte lr. The comms
    ledger (obs/ledger.py) builds its seed-path uplink column from this;
    tests pin it against an actual ``compress`` message so the two byte
    accountings can never drift apart."""
    return 8 + cfg.local_iters * cfg.b2 * 4 + 4


def reconstruct_delta(msg, params_like, cfg: FedZOConfig):
    """Replay Δ = −η Σ_k Σ_n (c[k,n]/b2) v(key, k, n) on this host/shard.

    Same wire message either way; cfg.flat_params selects how the receiver
    replays it: b2 axpy passes per iterate (pytree) or one zo_replay pass
    per iterate (flat, in-kernel direction regeneration).
    """
    _check_replayable(cfg)
    rng = jax.random.wrap_key_data(msg["key"])
    H = msg["coeffs"].shape[0]
    keys = jax.random.split(rng, H)

    if cfg.flat_params:
        # must match the sender's geometry exactly (bit-exact seed replay)
        spec, br = flat_geometry(params_like, cfg.flat_block_rows)

        def fbody(buf, k):
            buf = estimator.flat_apply_coefficients(
                buf, spec, keys[k], msg["coeffs"][k], scale=-msg["lr"],
                kind=cfg.estimator, block_rows=br)
            return buf, None

        buf, _ = jax.lax.scan(fbody, jnp.zeros((spec.n_pad,), jnp.float32),
                              jnp.arange(H))
        return unflatten(buf, spec)

    conv = cfg.direction_conv

    def body(k, delta):
        return estimator.apply_coefficients(
            delta, keys[k], msg["coeffs"][k], scale=-msg["lr"],
            kind=cfg.estimator, conv=conv), None

    delta, _ = jax.lax.scan(lambda d, k: body(k, d),
                            tree_zeros_like(params_like), jnp.arange(H))
    return delta


def stack_messages(msgs):
    """Stack M wire messages into dense arrays: (keys [M, 2] uint32,
    coeffs [M, H, b2], lrs [M]). All messages must share (H, b2)."""
    keys = jnp.stack([jnp.asarray(m["key"], jnp.uint32) for m in msgs])
    coeffs = jnp.stack([m["coeffs"] for m in msgs])
    lrs = jnp.stack([jnp.asarray(m["lr"], jnp.float32) for m in msgs])
    return keys, coeffs, lrs


def _iterate_keys(keys, H):
    """[M, 2] round-key data → [M·H, 2] per-iterate key data — the same
    ``split(key, H)`` replay every receiver of a single message performs."""
    def one(k2):
        return jax.random.key_data(
            jax.random.split(jax.random.wrap_key_data(k2), H))

    return jax.vmap(one)(keys).reshape(-1, 2)


def aggregate(msgs, params_like, cfg: FedZOConfig):
    """Mean of M reconstructed deltas as ONE batched seed replay.

    msgs: a list of compress() outputs or one compress_stacked() bundle.
    Instead of M Python-level reconstructions (each tracing its own
    H-scan), the stacked [M, H, b2] coefficients replay as a single scan
    over the M·H (key, coeffs [b2]) iterate records: the accumulator is
    one flat buffer (cfg.flat_params) or one delta pytree, and each step
    is one zo_replay pass / one b2-axpy replay. Trace size is O(1) in M,
    and the fp32 accumulation order (m-ascending, h-ascending) matches
    the old loop.
    """
    _check_replayable(cfg)
    if isinstance(msgs, dict):
        keys = jnp.asarray(msgs["key"], jnp.uint32)
        coeffs, lrs = msgs["coeffs"], msgs["lr"]
        M = coeffs.shape[0]
    else:
        M = len(msgs)
        keys, coeffs, lrs = stack_messages(msgs)
    H, b2 = coeffs.shape[1], coeffs.shape[2]
    k_mh = _iterate_keys(keys, H)
    c_mh = coeffs.reshape(M * H, b2)
    lr_mh = jnp.repeat(lrs, H)

    if cfg.flat_params:
        spec, br = flat_geometry(params_like, cfg.flat_block_rows)

        def fbody(buf, inp):
            k2, c, lr = inp
            return estimator.flat_apply_coefficients(
                buf, spec, k2, c, scale=-lr, kind=cfg.estimator,
                block_rows=br), None

        buf, _ = jax.lax.scan(fbody, jnp.zeros((spec.n_pad,), jnp.float32),
                              (k_mh, c_mh, lr_mh))
        return unflatten(buf / M, spec)

    def body(delta, inp):
        k2, c, lr = inp
        return estimator.apply_coefficients(
            delta, jax.random.wrap_key_data(k2), c, scale=-lr,
            kind=cfg.estimator, conv=cfg.direction_conv), None

    delta, _ = jax.lax.scan(body, tree_zeros_like(params_like),
                            (k_mh, c_mh, lr_mh))
    return tree_scale(1.0 / M, delta)
