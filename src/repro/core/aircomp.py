"""AirComp-assisted aggregation (paper Section IV).

Uplink model: the M_t scheduled devices transmit α_i^t·Δ_i^t concurrently
over a flat-fading MAC; the server receives

    s^t = Σ_i h_i^t α_i^t Δ_i^t + n_t,      n_t ~ CN(0, σ_w² I_d)

with the COTAF-style transmit scalar (Eq. 15)

    α_i^t = (h_min / h_i^t) · sqrt(dP / Δ_max^t),  Δ_max^t = max_i ‖Δ_i^t‖²

which inverts the channel and normalizes by the *largest current update*, so
the effective noise shrinks as the algorithm converges (paper Remark 4).
After receive scaling the server holds  y^t = Δ̄^t + ñ_t  with

    ñ_t ~ CN(0, σ_w²·Δ_max / (M²·d·P·h_min²) I).                    (Eq. 17)

Three implementations:
- ``aircomp_aggregate``      — the equivalent real-noise form on a delta
  pytree (model deltas are real so the real projection of ñ applies,
  variance σ_eff²/2 per real dimension — we keep the paper's full variance
  as the conservative choice and verify equivalence in tests).
- ``aircomp_aggregate_flat`` — the same statistics on a flat [M, n_pad]
  delta matrix via the fused one-pass kernel (kernels/zo_aircomp.py): row
  norms + masked mean in one sweep of the matrix, Eq.-17 noise injected
  in-kernel (counter convention) with one pass over the d-sized mean. The
  flat round engine (core/fedzo.py, DESIGN.md §8) aggregates through this.
- ``aircomp_simulate_channel`` — the explicit complex simulation (per-device
  h_i, transmit scalars, superposition, AWGN, receive scaling) used by the
  tests to verify the closed form and the per-device energy constraint
  ‖α_i Δ_i‖² ≤ dP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.utils.tree import tree_size

# per-round per-device energy budget is d·P with P normalized to 1;
# SNR γ = P·h_min²/σ_w² is controlled through snr_db = 10·log10(P/σ_w²).
P_TX = 1.0


def schedule_by_channel(rng, n_devices, h_min):
    """Rayleigh channel draw + threshold scheduling M_t = {i : |h_i| ≥ h_min}.

    Returns (h [N] complex64, mask [N] bool). The paper treats this as
    uniform sampling (Sec. IV-A); tests check |h| ~ Rayleigh and the mask
    rate matches exp(-h_min²).
    """
    kr, ki = jax.random.split(rng)
    h = (jax.random.normal(kr, (n_devices,)) +
         1j * jax.random.normal(ki, (n_devices,))) / jnp.sqrt(2.0)
    return h.astype(jnp.complex64), jnp.abs(h) >= h_min


def mask_stats(mask, M, weights=None):
    """(maskf, m_div, m_sched) for a scheduling mask over M rows.

    ``m_div`` is the clamped mean/noise divisor (never 0, so an all-masked
    round degenerates to a zero update instead of NaN); ``m_sched`` is the
    TRUE scheduled-client count — this is what ``m_effective`` reports, so
    a 0-client round is distinguishable from a 1-client one. The one
    definition is shared by every aggregation path (pytree, fused-flat,
    the masked plain means in core/fedzo.py, and the sharded round).

    ``weights`` optionally carries FedAvg-style size weights (positive [M],
    normalized by the caller so uniform sizes give all-ones — see
    ``size_weights``): the returned per-row coefficients become
    ``mask·w`` and the divisor ``Σ mask·w``, so the aggregate is the
    weighted mean over the scheduled rows. ``m_sched`` stays the
    UNWEIGHTED scheduled count. With all-ones weights this is bit-for-bit
    the unweighted path.
    """
    maskf = (jnp.ones((M,), jnp.float32) if mask is None
             else mask.astype(jnp.float32))
    m_sched = jnp.sum(maskf)
    if weights is None:
        return maskf, jnp.maximum(m_sched, 1.0), m_sched
    wf = maskf * weights.astype(jnp.float32)
    # clamp tiny (not 1.0): a lone scheduled client with weight 0.5 must be
    # divided by 0.5; an all-masked round still degenerates to zero update
    # (zero numerator, zero Δ_max → zero noise)
    return wf, jnp.maximum(jnp.sum(wf), 1e-8), m_sched


def size_weights(sizes):
    """FedAvg-style n_i/n client weights from row counts [M], normalized to
    mean 1 (so uniform sizes → all-ones and the weighted divisor matches
    the unweighted M in the Eq.-17 noise scale). Divide by the mean rather
    than multiply by its reciprocal: s / (M·s / M) is EXACTLY 1.0 for
    uniform sizes, keeping the documented bit-for-bit fallback."""
    w = sizes.astype(jnp.float32)
    return w / (jnp.sum(w) / w.shape[0])


def _delta_sq_norms(deltas):
    """‖Δ_i‖² for stacked deltas (leading M axis). -> [M]"""
    leaves = jax.tree.leaves(deltas)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32)),
                       axis=tuple(range(1, l.ndim))) for l in leaves)


def aircomp_aggregate(deltas, rng, *, snr_db, h_min, mask=None, weights=None):
    """Noisy mean of stacked deltas [M, ...] per Eq. 17.

    ``mask`` optionally marks which of the M rows actually transmit
    (channel-truncation scheduling); unmasked rows are excluded from both
    the mean and Δ_max. ``weights`` turns the mean into the FedAvg-style
    size-weighted mean (see ``mask_stats``); Δ_max and the noise scale
    keep their unweighted per-row norms — the channel doesn't know about
    statistical weighting, only the post-scaling divisor changes.
    """
    m_leaves = jax.tree.leaves(deltas)
    M = m_leaves[0].shape[0]
    d = tree_size(deltas) // M
    sigma_w2 = P_TX / (10.0 ** (snr_db / 10.0))

    sq = _delta_sq_norms(deltas)                       # [M]
    maskf, m_div, m_sched = mask_stats(mask, M, weights)
    delta_max = jnp.max(jnp.where(maskf > 0, sq, 0.0))

    noise_var = sigma_w2 * delta_max / (m_div ** 2 * float(d) * P_TX * h_min ** 2)
    noise_std = jnp.sqrt(noise_var)

    leaves, treedef = jax.tree.flatten(deltas)
    out = []
    for i, leaf in enumerate(leaves):
        mean = jnp.einsum("m...,m->...", leaf.astype(jnp.float32), maskf) / m_div
        k = jax.random.fold_in(rng, i)
        noisy = mean + noise_std * jax.random.normal(k, mean.shape, jnp.float32)
        out.append(noisy.astype(leaf.dtype))
    agg = jax.tree.unflatten(treedef, out)
    stats = {"aircomp_noise_std": noise_std, "delta_max": delta_max,
             "m_effective": m_sched}
    return agg, stats


def aircomp_aggregate_flat(deltas, rng, *, snr_db, h_min, d=None, mask=None,
                           weights=None, block_rows=None, interpret=None):
    """Eq.-17 aggregation of a flat delta matrix [M, n_pad] (fused kernel).

    One HBM pass over the matrix yields the per-row squared norms and the
    masked scaled mean together (``kernels/zo_aircomp.py``); Δ_max and the
    noise scale are scalar work on the [M] norms, and the noise is one
    ``zo_walk`` pass over the mean with the N(0,1) field regenerated
    in-kernel. Same Δ_max / m_eff / noise_std as ``aircomp_aggregate``
    (the noise *realization* differs: counter convention vs per-leaf
    fold_in). ``d`` is the valid flat length (pad indices carry walk
    residue and are excluded from the norms); defaults to the full width.
    """
    M, n = deltas.shape
    d = n if d is None else d
    sigma_w2 = P_TX / (10.0 ** (snr_db / 10.0))
    maskf, m_div, m_sched = mask_stats(mask, M, weights)
    mean, sq = kops.aircomp_reduce(deltas, maskf / m_div, d,
                                   block_rows=block_rows, interpret=interpret)
    delta_max = jnp.max(jnp.where(maskf > 0, sq, 0.0))
    noise_var = sigma_w2 * delta_max / (m_div ** 2 * float(d) * P_TX * h_min ** 2)
    noise_std = jnp.sqrt(noise_var)
    out = kops.zo_walk(mean, jax.random.key_data(rng),
                       jnp.zeros((2,), jnp.int32),
                       jnp.stack([noise_std, jnp.float32(0.0)]),
                       kind="normal", block_rows=block_rows,
                       interpret=interpret)
    stats = {"aircomp_noise_std": noise_std, "delta_max": delta_max,
             "m_effective": m_sched}
    return out, stats


def aircomp_simulate_channel(deltas_flat, rng, *, snr_db, h_min, h=None):
    """Explicit complex-channel simulation on flat [M, d] deltas.

    Only the SCHEDULED devices (|h_i| ≥ h_min — Sec. IV-A channel
    truncation) transmit: a deep-fade device would need α_i = h_min/h_i > 1
    to invert its channel and blow through the d·P energy budget, so it
    stays silent and contributes to neither the superposition nor Δ_max.
    The receiver divides by the scheduled count (clamped, so an all-masked
    round degenerates to the zero update). ``h`` optionally supplies an
    externally-realized channel (e.g. a ``sim.ChannelModel`` chain state)
    instead of the fresh i.i.d. draw.

    Returns (y [d] real recovered update, diag dict with per-device transmit
    energies, the channel draw, and the scheduling mask). Used by tests to
    validate ``aircomp_aggregate`` and the per-device energy constraint.
    """
    M, d = deltas_flat.shape
    sigma_w2 = P_TX / (10.0 ** (snr_db / 10.0))
    k_h, k_n = jax.random.split(rng)
    if h is None:
        h, mask = schedule_by_channel(k_h, M, h_min)
    else:
        mask = jnp.abs(h) >= h_min
    maskf, m_div, m_sched = mask_stats(mask, M)
    sq = jnp.sum(jnp.square(deltas_flat), axis=1)
    delta_max = jnp.max(jnp.where(maskf > 0, sq, 0.0))  # scheduled rows only

    alpha = maskf * (h_min / h) \
        * jnp.sqrt(d * P_TX / jnp.maximum(delta_max, 1e-30))      # Eq. 15
    tx = alpha[:, None] * deltas_flat.astype(jnp.complex64)
    energies = jnp.sum(jnp.abs(tx) ** 2, axis=1)                  # ≤ d·P
    kr, ki = jax.random.split(k_n)
    noise = (jax.random.normal(kr, (d,)) + 1j * jax.random.normal(ki, (d,))) \
        * jnp.sqrt(sigma_w2 / 2.0)
    s = jnp.sum(h[:, None] * tx, axis=0) + noise                  # Eq. 14/16
    rx_scale = jnp.sqrt(delta_max / (d * P_TX * h_min ** 2)) / m_div
    y = jnp.real(rx_scale * s)                                    # Eq. 17
    return y, {"h": h, "mask": mask, "m_effective": m_sched,
               "tx_energy": energies, "delta_max": delta_max,
               "energy_budget": d * P_TX}
