"""FedZO (paper Algorithm 1) — derivative-free federated optimization.

Two deployment modes share this module:

1. **Simulation mode** (paper scale, Sec. V): N clients held in memory,
   ``round_simulated`` vmaps the H-step local phase over the M sampled
   clients and aggregates deltas (exact Algorithm 1, with optional AirComp
   channel distortion from ``core.aircomp``).

2. **Cross-silo mode** (framework scale): each TPU pod is one client.
   ``local_iterate`` is the jitted unit the dry-run lowers; the launcher
   loops H of them per round and aggregates across the ``pod`` mesh axis
   (dense psum, AirComp-noisy psum, or seed-compressed — core/seedcomm.py).

The local phase never materializes a gradient pytree: per direction it pays
one loss forward + one axpy, and the update is replayed from seeds
(DESIGN.md §3). ``jax.grad`` is never called.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.core import estimator
from repro.core.aircomp import aircomp_aggregate
from repro.utils.tree import tree_add, tree_scale, tree_sub


class LocalResult(NamedTuple):
    params: object        # x_i^{(t,H)}
    coeffs: jnp.ndarray   # [H, b2] estimator coefficients (seed-replayable)
    losses: jnp.ndarray   # [H] base losses along the trajectory


def local_iterate(loss_fn, params, batch, rng, cfg: FedZOConfig):
    """One stochastic zeroth-order update (Eq. 5-6): x ← x − η ∇̃F(x).

    Returns (new_params, coeffs [b2], base_loss). This is the unit the
    multi-pod dry-run lowers as ``train_step``.
    """
    import jax.numpy as _jnp
    ddt = _jnp.dtype(cfg.direction_dtype)
    coeffs, base = estimator.coefficients(
        loss_fn, params, batch, rng, mu=cfg.mu, b2=cfg.b2, kind=cfg.estimator,
        direction_dtype=ddt, central=cfg.central)
    new_params = estimator.apply_coefficients(
        params, rng, coeffs, scale=-cfg.lr, kind=cfg.estimator,
        direction_dtype=ddt)
    return new_params, coeffs, base


def local_phase(loss_fn, params, batches, rng, cfg: FedZOConfig) -> LocalResult:
    """H local iterates (Algorithm 1 inner loop).

    ``batches`` is a pytree whose leaves have a leading [H] axis (the client
    pre-samples H minibatches of size b1).
    """
    def body(carry, inp):
        p = carry
        k, batch = inp
        p, coeffs, base = local_iterate(loss_fn, p, batch, k, cfg)
        return p, (coeffs, base)

    keys = jax.random.split(rng, cfg.local_iters)
    p_fin, (coeffs, losses) = jax.lax.scan(body, params, (keys, batches))
    return LocalResult(p_fin, coeffs, losses)


def client_delta(loss_fn, params, batches, rng, cfg) -> tuple:
    """Δ_i = x_i^{(t,H)} − x^t plus the seed-replayable summary."""
    res = local_phase(loss_fn, params, batches, rng, cfg)
    return tree_sub(res.params, params), res


def round_simulated(loss_fn, server_params, client_batches, client_rngs,
                    cfg: FedZOConfig, *, channel_rng=None, momentum=None):
    """One full communication round over the M sampled clients (vmapped).

    client_batches: pytree with leading [M, H, ...] axes.
    client_rngs:    [M] PRNG keys.
    ``momentum``: optional server-momentum state (FedOpt-style — beyond
    paper); pass a zeros-like tree and cfg.server_momentum > 0 to enable.
    Returns (new_server_params, metrics dict[, new_momentum]).
    """
    def one_client(batches, rng):
        delta, res = client_delta(loss_fn, server_params, batches, rng, cfg)
        return delta, res.losses

    deltas, losses = jax.vmap(one_client)(client_batches, client_rngs)

    if cfg.aircomp and channel_rng is not None:
        agg, air_stats = aircomp_aggregate(
            deltas, channel_rng, snr_db=cfg.snr_db, h_min=cfg.h_min)
    else:
        agg = tree_scale(1.0 / losses.shape[0],
                         jax.tree.map(lambda x: jnp.sum(x, 0), deltas))
        air_stats = {}

    if momentum is not None and cfg.server_momentum > 0:
        momentum = jax.tree.map(
            lambda m, g: (cfg.server_momentum * m + g).astype(m.dtype),
            momentum, agg)
        agg = momentum
    new_params = tree_add(server_params, agg)
    metrics = {"mean_local_loss": jnp.mean(losses),
               "first_loss": jnp.mean(losses[:, 0]), **air_stats}
    if momentum is not None:
        return new_params, metrics, momentum
    return new_params, metrics


def make_pod_round_step(loss_fn_grouped, cfg: FedZOConfig, mesh) -> Callable:
    """Cross-silo FedZO round for the multi-pod mesh: each pod is one client.

    Pure-GSPMD formulation (the nested manual-axis formulation with
    independent per-pod directions crashes XLA's SPMD partitioner — see
    DESIGN.md §5): all pods share the round's perturbation directions
    (common random seeds — exactly the wire format of core/seedcomm.py), the
    batch is sharded over ('pod','data') so each pod's loss group is
    computed from its own silo data, and the only cross-pod exchange is the
    per-pod coefficient vector [n_pod, b2] (scalar psums). The dense-delta /
    AirComp uplink variant is costed separately by ``make_delta_agg_step``.

    With shared directions, per-pod local trajectories cannot diverge inside
    one jit program, so this round runs H=1 (FedSGD-ZO). The paper-faithful
    independent-direction, H>1 algorithm is exercised by the simulation mode
    (``round_simulated``) and by the per-pod single-silo ``make_train_step``
    programs that a real deployment would run on each pod slice.

    ``loss_fn_grouped(params, batch) -> [n_pod] per-pod losses``.
    signature: (params, batch, rng) -> (params, metrics)
    """
    from repro.core.estimator import (_scale_factor, sample_direction,
                                      stream_perturb)
    from repro.utils.tree import tree_axpy, tree_size

    n_pod = mesh.shape["pod"]

    def step(params, batch, rng):
        d = tree_size(params)
        scale = _scale_factor(d, cfg.estimator)
        base = loss_fn_grouped(params, batch)              # [n_pod]

        def body(n, acc):
            v = sample_direction(jax.random.fold_in(rng, n), params,
                                 cfg.estimator, jnp.dtype(cfg.direction_dtype))
            lp = loss_fn_grouped(tree_axpy(cfg.mu, v, params), batch)
            c = scale * (lp - base).astype(jnp.float32) / cfg.mu  # [n_pod]
            return acc.at[n].set(c)

        coeffs = jax.lax.fori_loop(
            0, cfg.b2, body, jnp.zeros((cfg.b2, n_pod), jnp.float32))
        # federated aggregation: mean of per-pod coefficients (the entire
        # cross-pod uplink in seed-compression mode)
        c_mean = jnp.mean(coeffs, axis=1)                  # [b2]
        new_params = estimator.apply_coefficients(
            params, rng, c_mean, scale=-cfg.lr, kind=cfg.estimator,
            direction_dtype=jnp.dtype(cfg.direction_dtype))
        return new_params, {"loss": jnp.mean(base),
                            "per_pod_loss": base,
                            "coeff_pod_spread": jnp.std(coeffs, axis=1).mean()}

    return step


def make_delta_agg_step(cfg: FedZOConfig, n_pod: int) -> Callable:
    """The dense-uplink aggregation program: per-pod model deltas (leading
    [n_pod] axis, sharded over ``pod``) -> mean delta (+ optional AirComp
    noise, Sec. IV). Lowered separately on the multi-pod mesh so the dry-run
    prices the full-d cross-pod all-reduce that AirComp / seed-compression
    eliminate. signature: (deltas, rng) -> tree
    """
    from repro.core.aircomp import aircomp_aggregate

    def step(deltas, rng):
        if cfg.aircomp:
            agg, _ = aircomp_aggregate(deltas, rng, snr_db=cfg.snr_db,
                                       h_min=cfg.h_min)
            return agg
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), deltas)

    return step


def make_train_step(loss_fn, cfg: FedZOConfig) -> Callable:
    """jit-ready cross-silo train step: one local ZO iterate.

    signature: (params, batch, rng) -> (params, metrics)
    """
    def step(params, batch, rng):
        new_params, coeffs, base = local_iterate(loss_fn, params, batch, rng, cfg)
        return new_params, {"loss": base, "coeff_norm": jnp.linalg.norm(coeffs)}

    return step
