"""FedZO (paper Algorithm 1) — derivative-free federated optimization.

Two deployment modes share this module:

1. **Simulation mode** (paper scale, Sec. V): N clients held in memory,
   ``round_simulated`` vmaps the H-step local phase over the M sampled
   clients and aggregates deltas (exact Algorithm 1, with optional AirComp
   channel distortion from ``core.aircomp``).

2. **Cross-silo mode** (framework scale): each TPU pod is one client.
   ``local_iterate`` is the jitted unit the dry-run lowers; the launcher
   loops H of them per round and aggregates across the ``pod`` mesh axis
   (dense psum, AirComp-noisy psum, or seed-compressed — core/seedcomm.py).

The local phase never materializes a gradient pytree: per direction it pays
one loss forward + one axpy, and the update is replayed from seeds
(DESIGN.md §3). ``jax.grad`` is never called.

With ``cfg.flat_params=True`` the local phase runs on the flat-buffer hot
path (DESIGN.md §7): the pytree is flattened ONCE per phase into a padded
1-D buffer, every perturb is a fused zo_walk transition (one HBM pass per
direction, directions regenerated in-kernel), and the b2-direction update
is a single zo_replay pass. The pytree path stays as the reference.

With ``cfg.batch_directions=True`` the local phase runs the batched-
direction ("wide") plan of the simulation engine (DESIGN.md §9): per
iterate ONE [b2, n_pad] direction block, the b2 perturbed forwards as one
vmap, the update as one matvec. Same estimator statistics; bit-identical
directions to the loop path under direction_conv="tree".
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.core import estimator
from repro.core.aircomp import (aircomp_aggregate, aircomp_aggregate_flat,
                                mask_stats, schedule_by_channel)
from repro.utils.flatparams import (flat_geometry, flat_spec, flatten,
                                    unflatten)
from repro.utils.tree import tree_add, tree_scale, tree_sub


class LocalResult(NamedTuple):
    params: object        # x_i^{(t,H)}
    coeffs: jnp.ndarray   # [H, b2] estimator coefficients (seed-replayable)
    losses: jnp.ndarray   # [H] base losses along the trajectory


def _flat_setup(params, cfg: FedZOConfig):
    """(spec, block_rows kwarg) for the cfg's flat-buffer geometry."""
    return flat_geometry(params, cfg.flat_block_rows)


def _wide_setup(params, cfg: FedZOConfig):
    """Flat geometry for the batched-direction (wide) path.

    The wide phase never enters a Pallas kernel, so it pads only to the
    vector-lane width — NOT to the kernel block (BLOCK_ROWS·LANES can be
    8× the model size at softmax-regression scale, and every [b2, n_pad]
    direction block would pay for the dead columns). The kernel geometry
    is kept only when the fused AirComp kernel consumes the delta matrix.
    """
    from repro.kernels.zo_axpy import LANES

    if cfg.aircomp:
        return _flat_setup(params, cfg)
    return flat_spec(params, block=LANES), (cfg.flat_block_rows or None)


def flat_local_iterate(loss_fn, buf, spec, batch, rng, cfg: FedZOConfig,
                       block_rows=None):
    """One ZO update on the flat buffer: fused walk + single-pass replay.

    The sphere inv-norms are computed once and shared by both ends — the
    zo_dirnorms kernel regenerates all b2 directions, so running it twice
    would double the direction-generation compute of the iterate.
    """
    key2 = estimator._key_data(rng)
    inv = estimator.flat_inv_norms(key2, spec, cfg.b2, cfg.estimator,
                                   block_rows=block_rows)
    coeffs, base = estimator.flat_coefficients(
        loss_fn, buf, spec, batch, rng, mu=cfg.mu, b2=cfg.b2,
        kind=cfg.estimator, central=cfg.central, block_rows=block_rows,
        inv=inv)
    buf = estimator.flat_apply_coefficients(
        buf, spec, rng, coeffs, scale=-cfg.lr, kind=cfg.estimator,
        block_rows=block_rows, inv=inv)
    return buf, coeffs, base


def local_iterate(loss_fn, params, batch, rng, cfg: FedZOConfig):
    """One stochastic zeroth-order update (Eq. 5-6): x ← x − η ∇̃F(x).

    Returns (new_params, coeffs [b2], base_loss). This is the unit the
    multi-pod dry-run lowers as ``train_step``. Dispatches to the flat
    hot path when cfg.flat_params is set.
    """
    if cfg.flat_params:
        spec, br = _flat_setup(params, cfg)
        buf = flatten(params, spec)
        buf, coeffs, base = flat_local_iterate(loss_fn, buf, spec, batch,
                                               rng, cfg, block_rows=br)
        return unflatten(buf, spec), coeffs, base
    ddt = jnp.dtype(cfg.direction_dtype)
    coeffs, base = estimator.coefficients(
        loss_fn, params, batch, rng, mu=cfg.mu, b2=cfg.b2, kind=cfg.estimator,
        direction_dtype=ddt, central=cfg.central, conv=cfg.direction_conv)
    new_params = estimator.apply_coefficients(
        params, rng, coeffs, scale=-cfg.lr, kind=cfg.estimator,
        direction_dtype=ddt, conv=cfg.direction_conv)
    return new_params, coeffs, base


def _flat_phase_scan(loss_fn, buf0, spec, br, keys, batches, cfg):
    """Scan H flat local iterates over a flat buffer — THE flat local
    phase, shared by ``local_phase`` and the flat round engine so the two
    can never walk different iterate protocols. Returns
    (final buf, coeffs [H, b2], losses [H])."""
    def fbody(carry, inp):
        k, batch = inp
        b, coeffs, base = flat_local_iterate(loss_fn, carry, spec, batch,
                                             k, cfg, block_rows=br)
        return b, (coeffs, base)

    buf, (coeffs, losses) = jax.lax.scan(fbody, buf0, (keys, batches))
    return buf, coeffs, losses


def _check_surrogate(cfg: FedZOConfig):
    if cfg.direction_conv in ("surrogate", "channel") \
            and not cfg.batch_directions:
        raise ValueError(
            f"direction_conv={cfg.direction_conv!r} runs on the batched-"
            f"direction (wide) local phase — set cfg.batch_directions=True")


def surrogate_queries(cfg: FedZOConfig) -> int:
    """Fresh perturbed-loss queries per local iterate under the surrogate
    estimator (direction_conv="surrogate"): ceil(b2·surrogate_fraction),
    at least 1. The single source of truth shared by the phase scan and the
    query-budget acceptance test."""
    return max(1, int(round(cfg.b2 * cfg.surrogate_fraction)))


def _surrogate_phase_scan(loss_fn, buf0, spec, keys, batches, cfg):
    """Trajectory-informed surrogate local phase (FedZOO-style,
    arXiv 2308.04077): instead of b2 fresh directions per iterate, pay only
    ``surrogate_queries(cfg)`` fresh ZO queries and blend the fresh estimate
    into a running surrogate gradient carried along the local trajectory:

        g ← β·g + (1−β)·ĝ_fresh,   x ← x − η·g

    The replay history already flowing through the phase (the per-iterate
    (direction, finite-difference) pairs) is what the surrogate memorizes —
    an exponentially-weighted rank-|history| fit, the cheap end of FedZOO's
    quadratic surrogate family. Returns (final buf, coeffs [H, b2q],
    losses [H]); the coeffs are NOT seed-replayable (seedcomm rejects
    non-tree wide convs already)."""
    mu = jnp.float32(cfg.mu)
    scale = estimator._scale_factor(spec.d, cfg.estimator)
    b2q = surrogate_queries(cfg)
    beta = jnp.float32(cfg.surrogate_beta)

    def step(carry, inp):
        buf, g_hat, t = carry
        k, batch = inp
        V, inv = estimator.direction_block(k, spec, b2q, kind=cfg.estimator,
                                           conv="block")
        base = loss_fn(unflatten(buf, spec), batch)
        lp = jax.vmap(lambda v, s: loss_fn(
            unflatten(buf + (mu * s) * v, spec), batch))(V, inv)
        if cfg.central:
            lm = jax.vmap(lambda v, s: loss_fn(
                unflatten(buf - (mu * s) * v, spec), batch))(V, inv)
            coeffs = scale * (lp - lm).astype(jnp.float32) / (2 * mu)
        else:
            coeffs = scale * (lp - base).astype(jnp.float32) / mu
        g_fresh = ((coeffs * inv) @ V) / b2q
        # first iterate: no history yet, the surrogate IS the fresh estimate
        w = jnp.where(t == 0, 0.0, beta)
        g_hat = w * g_hat + (1.0 - w) * g_fresh
        buf = buf - cfg.lr * g_hat
        return (buf, g_hat, t + 1), (coeffs, base)

    (buf, _, _), (coeffs, losses) = jax.lax.scan(
        step, (buf0, jnp.zeros_like(buf0), jnp.int32(0)), (keys, batches))
    return buf, coeffs, losses


def _wide_phase_scan(loss_fn, buf0, spec, keys, batches, cfg, like=None):
    """Scan H batched-direction ("wide") iterates over a flat buffer — the
    simulation engine's local phase (DESIGN.md §9). Per step: ONE direction
    block [b2, n_pad], the b2 perturbed forwards as one vmap (XLA batches
    them), and the update as one matvec. Statistically identical to the
    loop estimator; walks its exact directions when direction_conv="tree".
    direction_conv="surrogate" swaps in the trajectory-informed surrogate
    phase (fewer fresh queries, EW-blended update direction);
    direction_conv="channel" perturbs along channel-driven gaussian
    directions (the one-point wireless estimator, arXiv 2401.17460).
    Returns (final buf, coeffs [H, b2], losses [H])."""
    if cfg.direction_conv == "surrogate":
        return _surrogate_phase_scan(loss_fn, buf0, spec, keys, batches, cfg)
    mu = jnp.float32(cfg.mu)
    conv = (cfg.direction_conv if cfg.direction_conv in ("tree", "channel")
            else "block")
    # the channel-driven one-point estimator (arXiv 2401.17460) perturbs
    # along raw fading-projection gaussians — gaussian statistics
    # (E[vvᵀ] = I) whatever cfg.estimator says, so the unbiasedness factor
    # is 1, not d (estimator.direction_block documents the convention)
    scale = (1.0 if conv == "channel"
             else estimator._scale_factor(spec.d, cfg.estimator))

    def step(buf, inp):
        k, batch = inp
        V, inv = estimator.direction_block(k, spec, cfg.b2,
                                           kind=cfg.estimator, conv=conv,
                                           like=like)
        base = loss_fn(unflatten(buf, spec), batch)
        lp = jax.vmap(lambda v, s: loss_fn(
            unflatten(buf + (mu * s) * v, spec), batch))(V, inv)
        if cfg.central:
            lm = jax.vmap(lambda v, s: loss_fn(
                unflatten(buf - (mu * s) * v, spec), batch))(V, inv)
            coeffs = scale * (lp - lm).astype(jnp.float32) / (2 * mu)
        else:
            coeffs = scale * (lp - base).astype(jnp.float32) / mu
        buf = buf + (-cfg.lr / cfg.b2) * ((coeffs * inv) @ V)
        return buf, (coeffs, base)

    buf, (coeffs, losses) = jax.lax.scan(step, buf0, (keys, batches))
    return buf, coeffs, losses


def local_phase(loss_fn, params, batches, rng, cfg: FedZOConfig) -> LocalResult:
    """H local iterates (Algorithm 1 inner loop).

    ``batches`` is a pytree whose leaves have a leading [H] axis (the client
    pre-samples H minibatches of size b1). On the flat path the pytree is
    flattened once for the whole phase — the H·b2 perturb/update passes all
    run on the single flat buffer.
    """
    keys = jax.random.split(rng, cfg.local_iters)
    _check_surrogate(cfg)

    if cfg.batch_directions:
        spec, _ = _wide_setup(params, cfg)
        buf, coeffs, losses = _wide_phase_scan(
            loss_fn, flatten(params, spec), spec, keys, batches, cfg,
            like=params)
        return LocalResult(unflatten(buf, spec), coeffs, losses)

    if cfg.flat_params:
        spec, br = _flat_setup(params, cfg)
        buf, coeffs, losses = _flat_phase_scan(
            loss_fn, flatten(params, spec), spec, br, keys, batches, cfg)
        return LocalResult(unflatten(buf, spec), coeffs, losses)

    def body(carry, inp):
        p = carry
        k, batch = inp
        p, coeffs, base = local_iterate(loss_fn, p, batch, k, cfg)
        return p, (coeffs, base)

    p_fin, (coeffs, losses) = jax.lax.scan(body, params, (keys, batches))
    return LocalResult(p_fin, coeffs, losses)


def client_delta(loss_fn, params, batches, rng, cfg) -> tuple:
    """Δ_i = x_i^{(t,H)} − x^t plus the seed-replayable summary."""
    res = local_phase(loss_fn, params, batches, rng, cfg)
    return tree_sub(res.params, params), res


def round_simulated(loss_fn, server_params, client_batches, client_rngs,
                    cfg: FedZOConfig, *, channel_rng=None, momentum=None,
                    weights=None, faults=None, channel=None, cstate=None,
                    loss_wrap=None, state_fn=None):
    """One full communication round over the M sampled clients (vmapped).

    client_batches: pytree with leading [M, H, ...] axes.
    client_rngs:    [M] PRNG keys.
    ``momentum``: optional server-momentum state (FedOpt-style — beyond
    paper); pass a zeros-like tree and cfg.server_momentum > 0 to enable.
    Returns (new_server_params, metrics dict[, new_momentum]).

    With cfg.flat_params the whole round runs on the flat buffer
    (DESIGN.md §8): the server params are flattened ONCE, the flat local
    phase is vmapped over the M clients so the client deltas materialize
    as one [M, n_pad] matrix, and aggregation (masked mean or the fused
    one-pass AirComp kernel) happens on that matrix before a single
    unflatten.

    cfg.channel_schedule enables the paper's channel-truncation scheduling
    (Sec. IV-A): a Rayleigh draw from ``channel_rng`` masks out clients
    with |h| < h_min; masked rows are excluded from both the mean and
    Δ_max and ``m_effective`` is reported in the metrics.

    ``weights`` ([M] positive, mean-1 normalized — ``aircomp.size_weights``)
    switches every aggregation path to the FedAvg-style size-weighted mean
    n_i/n over the (scheduled) clients; the engine threads it from
    ``ClientStore.sizes`` under ``cfg.weight_by_size``.

    ``faults`` (a ``sim.faults.RoundFaults``) injects this round's realized
    client faults: the deltas are corrupted-then-scrubbed before
    aggregation and the surviving-client mask composes with the channel
    mask, so dropped/straggling/poisoned clients are excluded from the
    mean and Δ_max exactly like channel-masked ones (DESIGN.md §12).

    ``channel`` (a ``sim.channel.RoundChannel``) supplies this round's
    realized wireless scenario (DESIGN.md §16): its transmit mask —
    time-correlated-fading scheduling ∧ battery gating, advanced by the
    engine's ``ChannelModel`` carry step — REPLACES the i.i.d.
    ``schedule_by_channel`` draw, composing with faults and weights
    through the same ``mask_stats`` convention. ``channel=None`` keeps
    the per-round i.i.d. draw bit-exactly.

    Strategy hooks (core/strategy.py, DESIGN.md §13) — all default None,
    in which case every code path above is byte-for-byte the plain FedZO
    round:

    - ``cstate``: the [M, ...] per-client strategy state of the sampled
      cohort (SCAFFOLD control variates, FedDyn duals), vmapped alongside
      the batches; the (possibly updated) cohort state is appended to the
      return tuple whenever ``cstate`` is passed.
    - ``loss_wrap(loss_fn, cst) -> loss_fn'`` wraps the ZO loss query per
      client (proximal term, dynamic regularizer) — the estimator itself
      is untouched.
    - ``state_fn(deltas, cstate, spec) -> (deltas', cstate')`` is the
      client-side post-phase delta correction, applied in flat [M, n_pad]
      space on the flat/wide paths (``spec`` set) and on the stacked delta
      pytree otherwise (``spec=None``) — BEFORE fault corruption and the
      aggregation, so it composes with AirComp, scheduling, weighting,
      and the sharded reduce unchanged.
    """
    M = client_rngs.shape[0]
    _check_surrogate(cfg)
    new_cstate = cstate
    mask = None
    noise_rng = channel_rng
    air_stats = {}
    if cfg.channel_schedule and channel_rng is not None:
        k_sched, noise_rng = jax.random.split(channel_rng)
        if channel is None:
            _, mask = schedule_by_channel(k_sched, M, cfg.h_min)
    if channel is not None:
        # the scenario engine realized this round's channel already:
        # correlated-fading scheduling ∧ battery gating (sim/channel.py)
        mask = channel.mask

    if cfg.flat_params or cfg.batch_directions:
        spec, br = (_wide_setup(server_params, cfg) if cfg.batch_directions
                    else _flat_setup(server_params, cfg))
        buf0 = flatten(server_params, spec)
        keys = jax.vmap(lambda r: jax.random.split(r, cfg.local_iters))(
            client_rngs)

        def one_client(batches, ks, cst=None):
            lf = loss_wrap(loss_fn, cst) if loss_wrap is not None else loss_fn
            if cfg.batch_directions:
                buf, _, base = _wide_phase_scan(lf, buf0, spec, ks, batches,
                                                cfg, like=server_params)
            else:
                buf, _, base = _flat_phase_scan(lf, buf0, spec, br, ks,
                                                batches, cfg)
            return buf - buf0, base

        if cstate is not None:
            deltas, losses = jax.vmap(one_client)(client_batches, keys,
                                                  cstate)
        else:
            deltas, losses = jax.vmap(one_client)(client_batches, keys)

        if state_fn is not None:
            deltas, new_cstate = state_fn(deltas, cstate, spec)

        if faults is not None:
            deltas, fmask = faults.apply_flat(deltas)
            mask = fmask if mask is None else mask & fmask

        if cfg.aircomp and channel_rng is not None:
            agg_flat, air_stats = aircomp_aggregate_flat(
                deltas, noise_rng, snr_db=cfg.snr_db, h_min=cfg.h_min,
                d=spec.d, mask=mask, weights=weights, block_rows=br)
        elif mask is not None or weights is not None:
            maskf, m_div, m_sched = mask_stats(mask, M, weights)
            agg_flat = jnp.einsum("mn,m->n", deltas, maskf) / m_div
            # m_effective reports unconditionally: a weighted-but-
            # unscheduled round must carry the same cohort-size column as
            # every other aggregation path (history/CSV consistency)
            air_stats = {"m_effective": m_sched}
        else:
            agg_flat = jnp.mean(deltas, axis=0)
        agg = unflatten(agg_flat, spec)
    else:
        def one_client(batches, rng, cst=None):
            lf = loss_wrap(loss_fn, cst) if loss_wrap is not None else loss_fn
            delta, res = client_delta(lf, server_params, batches, rng, cfg)
            return delta, res.losses

        if cstate is not None:
            deltas, losses = jax.vmap(one_client)(client_batches,
                                                  client_rngs, cstate)
        else:
            deltas, losses = jax.vmap(one_client)(client_batches, client_rngs)

        if state_fn is not None:
            deltas, new_cstate = state_fn(deltas, cstate, None)

        if faults is not None:
            deltas, fmask = faults.apply_tree(deltas)
            mask = fmask if mask is None else mask & fmask

        if cfg.aircomp and channel_rng is not None:
            agg, air_stats = aircomp_aggregate(
                deltas, noise_rng, snr_db=cfg.snr_db, h_min=cfg.h_min,
                mask=mask, weights=weights)
        elif mask is not None or weights is not None:
            maskf, m_div, m_sched = mask_stats(mask, M, weights)
            agg = jax.tree.map(
                lambda x: (jnp.einsum("m...,m->...", x.astype(jnp.float32),
                                      maskf) / m_div).astype(x.dtype),
                deltas)
            air_stats = {"m_effective": m_sched}  # see flat-path comment
        else:
            agg = tree_scale(1.0 / M,
                             jax.tree.map(lambda x: jnp.sum(x, 0), deltas))

    if momentum is not None and cfg.server_momentum > 0:
        momentum = jax.tree.map(
            lambda m, g: (cfg.server_momentum * m + g).astype(m.dtype),
            momentum, agg)
        agg = momentum
    new_params = tree_add(server_params, agg)
    if faults is not None:
        # mask is never None under faults, so every branch above reported
        # m_effective (the surviving cohort); add the poison count
        air_stats["m_corrupt"] = faults.n_corrupt
    metrics = {"mean_local_loss": jnp.mean(losses),
               "first_loss": jnp.mean(losses[:, 0]), **air_stats}
    out = (new_params, metrics)
    if momentum is not None:
        out = out + (momentum,)
    if cstate is not None:
        out = out + (new_cstate,)
    return out


def make_pod_round_step(loss_fn_grouped, cfg: FedZOConfig, mesh) -> Callable:
    """Cross-silo FedZO round for the multi-pod mesh: each pod is one client.

    Pure-GSPMD formulation (the nested manual-axis formulation with
    independent per-pod directions crashes XLA's SPMD partitioner — see
    DESIGN.md §5): all pods share the round's perturbation directions
    (common random seeds — exactly the wire format of core/seedcomm.py), the
    batch is sharded over ('pod','data') so each pod's loss group is
    computed from its own silo data, and the only cross-pod exchange is the
    per-pod coefficient vector [n_pod, b2] (scalar psums). The dense-delta /
    AirComp uplink variant is costed separately by ``make_delta_agg_step``.

    With shared directions, per-pod local trajectories cannot diverge inside
    one jit program, so this round runs H=1 (FedSGD-ZO). The paper-faithful
    independent-direction, H>1 algorithm is exercised by the simulation mode
    (``round_simulated``) and by the per-pod single-silo ``make_train_step``
    programs that a real deployment would run on each pod slice.

    ``loss_fn_grouped(params, batch) -> [n_pod] per-pod losses``.
    signature: (params, batch, rng) -> (params, metrics)
    """
    from repro.core.estimator import _scale_factor
    from repro.utils.tree import tree_axpy, tree_size

    n_pod = mesh.shape["pod"]

    if cfg.flat_params:
        def flat_step(params, batch, rng):
            spec, br = _flat_setup(params, cfg)
            buf = flatten(params, spec)
            # sphere inv-norms computed ONCE and shared by both ends — the
            # same invariant flat_local_iterate documents (zo_dirnorms
            # regenerates all b2 directions, so running it twice doubles
            # the direction-generation compute of the step)
            inv = estimator.flat_inv_norms(
                estimator._key_data(rng), spec, cfg.b2, cfg.estimator,
                block_rows=br)
            # flat_coefficients handles vector-valued (grouped) losses:
            # coeffs come back [b2, n_pod]
            coeffs, base = estimator.flat_coefficients(
                loss_fn_grouped, buf, spec, batch, rng,
                mu=cfg.mu, b2=cfg.b2, kind=cfg.estimator,
                central=cfg.central, block_rows=br, inv=inv)
            # the only cross-pod uplink: mean of per-pod coefficients
            c_mean = jnp.mean(coeffs, axis=1)               # [b2]
            buf = estimator.flat_apply_coefficients(
                buf, spec, rng, c_mean, scale=-cfg.lr, kind=cfg.estimator,
                block_rows=br, inv=inv)
            return unflatten(buf, spec), {
                "loss": jnp.mean(base), "per_pod_loss": base,
                "coeff_pod_spread": jnp.std(coeffs, axis=1).mean()}

        return flat_step

    def step(params, batch, rng):
        d = tree_size(params)
        scale = _scale_factor(d, cfg.estimator)
        base = loss_fn_grouped(params, batch)              # [n_pod]

        def body(n, acc):
            v = estimator._direction(rng, n, params, cfg.estimator,
                                     jnp.dtype(cfg.direction_dtype),
                                     cfg.direction_conv)
            lp = loss_fn_grouped(tree_axpy(cfg.mu, v, params), batch)
            c = scale * (lp - base).astype(jnp.float32) / cfg.mu  # [n_pod]
            return acc.at[n].set(c)

        coeffs = jax.lax.fori_loop(
            0, cfg.b2, body, jnp.zeros((cfg.b2, n_pod), jnp.float32))
        # federated aggregation: mean of per-pod coefficients (the entire
        # cross-pod uplink in seed-compression mode)
        c_mean = jnp.mean(coeffs, axis=1)                  # [b2]
        new_params = estimator.apply_coefficients(
            params, rng, c_mean, scale=-cfg.lr, kind=cfg.estimator,
            direction_dtype=jnp.dtype(cfg.direction_dtype),
            conv=cfg.direction_conv)
        return new_params, {"loss": jnp.mean(base),
                            "per_pod_loss": base,
                            "coeff_pod_spread": jnp.std(coeffs, axis=1).mean()}

    return step


def make_delta_agg_step(cfg: FedZOConfig, n_pod: int) -> Callable:
    """The dense-uplink aggregation program: per-pod model deltas (leading
    [n_pod] axis, sharded over ``pod``) -> mean delta (+ optional AirComp
    noise, Sec. IV). Lowered separately on the multi-pod mesh so the dry-run
    prices the full-d cross-pod all-reduce that AirComp / seed-compression
    eliminate. signature: (deltas, rng) -> tree
    """
    from repro.core.aircomp import aircomp_aggregate

    def step(deltas, rng):
        if cfg.aircomp:
            agg, _ = aircomp_aggregate(deltas, rng, snr_db=cfg.snr_db,
                                       h_min=cfg.h_min)
            return agg
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), deltas)

    return step


def make_train_step(loss_fn, cfg: FedZOConfig) -> Callable:
    """jit-ready cross-silo train step: one local ZO iterate.

    signature: (params, batch, rng) -> (params, metrics)
    """
    def step(params, batch, rng):
        new_params, coeffs, base = local_iterate(loss_fn, params, batch, rng, cfg)
        return new_params, {"loss": base, "coeff_norm": jnp.linalg.norm(coeffs)}

    return step
