"""FedZO (paper Algorithm 1) — derivative-free federated optimization.

Two deployment modes share this module:

1. **Simulation mode** (paper scale, Sec. V): N clients held in memory,
   ``round_simulated`` vmaps the H-step local phase over the M sampled
   clients and aggregates deltas (exact Algorithm 1, with optional AirComp
   channel distortion from ``core.aircomp``).

2. **Cross-silo mode** (framework scale): each TPU pod is one client.
   ``local_iterate`` is the jitted unit the dry-run lowers; the launcher
   loops H of them per round and aggregates across the ``pod`` mesh axis
   (dense psum, AirComp-noisy psum, or seed-compressed — core/seedcomm.py).

The local phase never materializes a gradient pytree: per direction it pays
one loss forward + one axpy, and the update is replayed from seeds
(DESIGN.md §3). ``jax.grad`` is never called.

With ``cfg.flat_params=True`` the local phase runs on the flat-buffer hot
path (DESIGN.md §7): the pytree is flattened ONCE per phase into a padded
1-D buffer, every perturb is a fused zo_walk transition (one HBM pass per
direction, directions regenerated in-kernel), and the b2-direction update
is a single zo_replay pass. The pytree path stays as the reference.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.core import estimator
from repro.core.aircomp import aircomp_aggregate
from repro.utils.flatparams import flat_geometry, flatten, unflatten
from repro.utils.tree import tree_add, tree_scale, tree_sub


class LocalResult(NamedTuple):
    params: object        # x_i^{(t,H)}
    coeffs: jnp.ndarray   # [H, b2] estimator coefficients (seed-replayable)
    losses: jnp.ndarray   # [H] base losses along the trajectory


def _flat_setup(params, cfg: FedZOConfig):
    """(spec, block_rows kwarg) for the cfg's flat-buffer geometry."""
    return flat_geometry(params, cfg.flat_block_rows)


def flat_local_iterate(loss_fn, buf, spec, batch, rng, cfg: FedZOConfig,
                       block_rows=None):
    """One ZO update on the flat buffer: fused walk + single-pass replay.

    The sphere inv-norms are computed once and shared by both ends — the
    zo_dirnorms kernel regenerates all b2 directions, so running it twice
    would double the direction-generation compute of the iterate.
    """
    key2 = estimator._key_data(rng)
    inv = estimator.flat_inv_norms(key2, spec, cfg.b2, cfg.estimator,
                                   block_rows=block_rows)
    coeffs, base = estimator.flat_coefficients(
        loss_fn, buf, spec, batch, rng, mu=cfg.mu, b2=cfg.b2,
        kind=cfg.estimator, central=cfg.central, block_rows=block_rows,
        inv=inv)
    buf = estimator.flat_apply_coefficients(
        buf, spec, rng, coeffs, scale=-cfg.lr, kind=cfg.estimator,
        block_rows=block_rows, inv=inv)
    return buf, coeffs, base


def local_iterate(loss_fn, params, batch, rng, cfg: FedZOConfig):
    """One stochastic zeroth-order update (Eq. 5-6): x ← x − η ∇̃F(x).

    Returns (new_params, coeffs [b2], base_loss). This is the unit the
    multi-pod dry-run lowers as ``train_step``. Dispatches to the flat
    hot path when cfg.flat_params is set.
    """
    if cfg.flat_params:
        spec, br = _flat_setup(params, cfg)
        buf = flatten(params, spec)
        buf, coeffs, base = flat_local_iterate(loss_fn, buf, spec, batch,
                                               rng, cfg, block_rows=br)
        return unflatten(buf, spec), coeffs, base
    ddt = jnp.dtype(cfg.direction_dtype)
    coeffs, base = estimator.coefficients(
        loss_fn, params, batch, rng, mu=cfg.mu, b2=cfg.b2, kind=cfg.estimator,
        direction_dtype=ddt, central=cfg.central, conv=cfg.direction_conv)
    new_params = estimator.apply_coefficients(
        params, rng, coeffs, scale=-cfg.lr, kind=cfg.estimator,
        direction_dtype=ddt, conv=cfg.direction_conv)
    return new_params, coeffs, base


def local_phase(loss_fn, params, batches, rng, cfg: FedZOConfig) -> LocalResult:
    """H local iterates (Algorithm 1 inner loop).

    ``batches`` is a pytree whose leaves have a leading [H] axis (the client
    pre-samples H minibatches of size b1). On the flat path the pytree is
    flattened once for the whole phase — the H·b2 perturb/update passes all
    run on the single flat buffer.
    """
    keys = jax.random.split(rng, cfg.local_iters)

    if cfg.flat_params:
        spec, br = _flat_setup(params, cfg)

        def fbody(carry, inp):
            k, batch = inp
            b, coeffs, base = flat_local_iterate(loss_fn, carry, spec, batch,
                                                 k, cfg, block_rows=br)
            return b, (coeffs, base)

        buf, (coeffs, losses) = jax.lax.scan(
            fbody, flatten(params, spec), (keys, batches))
        return LocalResult(unflatten(buf, spec), coeffs, losses)

    def body(carry, inp):
        p = carry
        k, batch = inp
        p, coeffs, base = local_iterate(loss_fn, p, batch, k, cfg)
        return p, (coeffs, base)

    p_fin, (coeffs, losses) = jax.lax.scan(body, params, (keys, batches))
    return LocalResult(p_fin, coeffs, losses)


def client_delta(loss_fn, params, batches, rng, cfg) -> tuple:
    """Δ_i = x_i^{(t,H)} − x^t plus the seed-replayable summary."""
    res = local_phase(loss_fn, params, batches, rng, cfg)
    return tree_sub(res.params, params), res


def round_simulated(loss_fn, server_params, client_batches, client_rngs,
                    cfg: FedZOConfig, *, channel_rng=None, momentum=None):
    """One full communication round over the M sampled clients (vmapped).

    client_batches: pytree with leading [M, H, ...] axes.
    client_rngs:    [M] PRNG keys.
    ``momentum``: optional server-momentum state (FedOpt-style — beyond
    paper); pass a zeros-like tree and cfg.server_momentum > 0 to enable.
    Returns (new_server_params, metrics dict[, new_momentum]).
    """
    def one_client(batches, rng):
        delta, res = client_delta(loss_fn, server_params, batches, rng, cfg)
        return delta, res.losses

    deltas, losses = jax.vmap(one_client)(client_batches, client_rngs)

    if cfg.aircomp and channel_rng is not None:
        agg, air_stats = aircomp_aggregate(
            deltas, channel_rng, snr_db=cfg.snr_db, h_min=cfg.h_min)
    else:
        agg = tree_scale(1.0 / losses.shape[0],
                         jax.tree.map(lambda x: jnp.sum(x, 0), deltas))
        air_stats = {}

    if momentum is not None and cfg.server_momentum > 0:
        momentum = jax.tree.map(
            lambda m, g: (cfg.server_momentum * m + g).astype(m.dtype),
            momentum, agg)
        agg = momentum
    new_params = tree_add(server_params, agg)
    metrics = {"mean_local_loss": jnp.mean(losses),
               "first_loss": jnp.mean(losses[:, 0]), **air_stats}
    if momentum is not None:
        return new_params, metrics, momentum
    return new_params, metrics


def make_pod_round_step(loss_fn_grouped, cfg: FedZOConfig, mesh) -> Callable:
    """Cross-silo FedZO round for the multi-pod mesh: each pod is one client.

    Pure-GSPMD formulation (the nested manual-axis formulation with
    independent per-pod directions crashes XLA's SPMD partitioner — see
    DESIGN.md §5): all pods share the round's perturbation directions
    (common random seeds — exactly the wire format of core/seedcomm.py), the
    batch is sharded over ('pod','data') so each pod's loss group is
    computed from its own silo data, and the only cross-pod exchange is the
    per-pod coefficient vector [n_pod, b2] (scalar psums). The dense-delta /
    AirComp uplink variant is costed separately by ``make_delta_agg_step``.

    With shared directions, per-pod local trajectories cannot diverge inside
    one jit program, so this round runs H=1 (FedSGD-ZO). The paper-faithful
    independent-direction, H>1 algorithm is exercised by the simulation mode
    (``round_simulated``) and by the per-pod single-silo ``make_train_step``
    programs that a real deployment would run on each pod slice.

    ``loss_fn_grouped(params, batch) -> [n_pod] per-pod losses``.
    signature: (params, batch, rng) -> (params, metrics)
    """
    from repro.core.estimator import _scale_factor
    from repro.utils.tree import tree_axpy, tree_size

    n_pod = mesh.shape["pod"]

    if cfg.flat_params:
        def flat_step(params, batch, rng):
            spec, br = _flat_setup(params, cfg)
            buf = flatten(params, spec)
            # flat_coefficients handles vector-valued (grouped) losses:
            # coeffs come back [b2, n_pod]
            coeffs, base = estimator.flat_coefficients(
                loss_fn_grouped, buf, spec, batch, rng,
                mu=cfg.mu, b2=cfg.b2, kind=cfg.estimator,
                central=cfg.central, block_rows=br)
            # the only cross-pod uplink: mean of per-pod coefficients
            c_mean = jnp.mean(coeffs, axis=1)               # [b2]
            buf = estimator.flat_apply_coefficients(
                buf, spec, rng, c_mean, scale=-cfg.lr, kind=cfg.estimator,
                block_rows=br)
            return unflatten(buf, spec), {
                "loss": jnp.mean(base), "per_pod_loss": base,
                "coeff_pod_spread": jnp.std(coeffs, axis=1).mean()}

        return flat_step

    def step(params, batch, rng):
        d = tree_size(params)
        scale = _scale_factor(d, cfg.estimator)
        base = loss_fn_grouped(params, batch)              # [n_pod]

        def body(n, acc):
            v = estimator._direction(rng, n, params, cfg.estimator,
                                     jnp.dtype(cfg.direction_dtype),
                                     cfg.direction_conv)
            lp = loss_fn_grouped(tree_axpy(cfg.mu, v, params), batch)
            c = scale * (lp - base).astype(jnp.float32) / cfg.mu  # [n_pod]
            return acc.at[n].set(c)

        coeffs = jax.lax.fori_loop(
            0, cfg.b2, body, jnp.zeros((cfg.b2, n_pod), jnp.float32))
        # federated aggregation: mean of per-pod coefficients (the entire
        # cross-pod uplink in seed-compression mode)
        c_mean = jnp.mean(coeffs, axis=1)                  # [b2]
        new_params = estimator.apply_coefficients(
            params, rng, c_mean, scale=-cfg.lr, kind=cfg.estimator,
            direction_dtype=jnp.dtype(cfg.direction_dtype),
            conv=cfg.direction_conv)
        return new_params, {"loss": jnp.mean(base),
                            "per_pod_loss": base,
                            "coeff_pod_spread": jnp.std(coeffs, axis=1).mean()}

    return step


def make_delta_agg_step(cfg: FedZOConfig, n_pod: int) -> Callable:
    """The dense-uplink aggregation program: per-pod model deltas (leading
    [n_pod] axis, sharded over ``pod``) -> mean delta (+ optional AirComp
    noise, Sec. IV). Lowered separately on the multi-pod mesh so the dry-run
    prices the full-d cross-pod all-reduce that AirComp / seed-compression
    eliminate. signature: (deltas, rng) -> tree
    """
    from repro.core.aircomp import aircomp_aggregate

    def step(deltas, rng):
        if cfg.aircomp:
            agg, _ = aircomp_aggregate(deltas, rng, snr_db=cfg.snr_db,
                                       h_min=cfg.h_min)
            return agg
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), deltas)

    return step


def make_train_step(loss_fn, cfg: FedZOConfig) -> Callable:
    """jit-ready cross-silo train step: one local ZO iterate.

    signature: (params, batch, rng) -> (params, metrics)
    """
    def step(params, batch, rng):
        new_params, coeffs, base = local_iterate(loss_fn, params, batch, rng, cfg)
        return new_params, {"loss": base, "coeff_norm": jnp.linalg.norm(coeffs)}

    return step
