"""Stochastic zeroth-order gradient estimators (paper Sec. II-B, Eq. 2).

The mini-batch estimator with b1 data samples and b2 directions:

    ∇̃F(x) = 1/(b1·b2) Σ_m Σ_n (d·v_n/μ) (F(x + μ v_n, ξ_m) − F(x, ξ_m)),
    v_n ~ U(S^{d-1})

Because the same minibatch {ξ_m} is used at both points, the m-average is
just the minibatch-mean loss, so the implementation evaluates the minibatch
loss once at x and once at each x + μ v_n.

Directions are *never stored*: each v_n is regenerated from
``fold_in(rng, n)`` (seed replay, see utils/tree.py). That gives two forms:

- ``estimate(...)``        → materialized gradient-estimate pytree
                             (paper-scale models; FedAvg-compatible API)
- ``coefficients(...)``    → only the b2 scalar coefficients
                             c_n = d·(L(x+μv_n) − L(x))/μ; the update
                             Σ c_n v_n / b2 is replayed later (big models,
                             seed-based delta compression, AirComp-free mode)

Variants beyond the paper's sphere estimator:
- ``gaussian``  (Nesterov-Spokoiny smoothing; MeZO-style)  — no d factor.
- ``coordinate`` (Kiefer-Wolfowitz-type, random coordinates) — d factor,
  v = e_i basis vectors; paper Table I compares against this family.
- ``rademacher`` (SPSA-style ±1 directions) — no d factor (E[vvᵀ] = I).
- ``central=True`` uses the two-sided difference
  (F(x+μv) − F(x−μv)) / 2μ — one extra query per direction buys an
  O(μ²) bias instead of O(μ) (standard ZO variance/bias trade).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.tree import (normal_like_tree, sphere_like_tree,
                              tree_add_normal, tree_axpy, tree_norm,
                              tree_random_sq_norm, tree_scale, tree_size,
                              tree_zeros_like)


def sample_direction(rng, params, kind: str, dtype=jnp.float32):
    """One direction pytree v with E-factor folded into the caller's d-scale."""
    if kind == "sphere":
        return sphere_like_tree(rng, params, dtype=dtype)
    if kind == "gaussian":
        return normal_like_tree(rng, params, dtype=dtype)
    if kind == "rademacher":
        leaves, treedef = jax.tree.flatten(params)
        out = [jax.random.rademacher(jax.random.fold_in(rng, i), l.shape,
                                     dtype)
               for i, l in enumerate(leaves)]
        return jax.tree.unflatten(treedef, out)
    if kind == "coordinate":
        # one-hot at a uniformly random flat index, built leafwise
        d = tree_size(params)
        idx = jax.random.randint(rng, (), 0, d)
        leaves, treedef = jax.tree.flatten(params)
        out, off = [], 0
        for leaf in leaves:
            n = leaf.size
            flat = jnp.where(jnp.arange(n) == idx - off, 1.0, 0.0)
            out.append(flat.reshape(leaf.shape).astype(jnp.float32))
            off += n
        return jax.tree.unflatten(treedef, out)
    raise ValueError(f"unknown estimator kind {kind!r}")


def _scale_factor(d, kind):
    # unbiasedness factor: d for sphere/coordinate, 1 for gaussian/rademacher
    # (for which E[vv^T] = I without rescaling)
    return 1.0 if kind in ("gaussian", "rademacher") else float(d)


def stream_perturb(params, key, mag, kind="sphere", dtype=jnp.float32):
    """params + mag·v(key) WITHOUT materializing v (chunked RNG streaming —
    the big-model memory path, §Perf iteration 3). Bit-consistent with
    ``sample_direction`` up to float reassociation of the sphere scaling."""
    if kind == "coordinate":
        return tree_axpy(mag, sample_direction(key, params, kind), params)
    if kind == "sphere":
        inv = 1.0 / (jnp.sqrt(tree_random_sq_norm(key, params, dtype)) + 1e-30)
        return tree_add_normal(params, key, mag * inv, dtype)
    return tree_add_normal(params, key, mag, dtype)  # gaussian


def coefficients(loss_fn, params, batch, rng, *, mu, b2, kind="sphere",
                 base_loss=None, direction_dtype=jnp.float32, central=False):
    """The b2 coefficients c_n = scale·(L(x+μ v_n) − L(x))/μ  (fp32 [b2]).

    ``loss_fn(params, batch) -> scalar``. Directions are regenerated from
    ``fold_in(rng, n)``; callers replay the same seeds to apply updates.
    ``central=True`` uses (L(x+μv) − L(x−μv)) / 2μ (O(μ²) smoothing bias,
    one extra forward per direction).
    """
    d = tree_size(params)
    scale = _scale_factor(d, kind)
    base = loss_fn(params, batch) if base_loss is None else base_loss

    def body(n, acc):
        # materialized direction + axpy measured Pareto-best on the XLA:CPU
        # buffer-assignment instrument (§Perf iteration 3: two-pass
        # streaming, chunked and rbg variants all refuted).
        v = sample_direction(jax.random.fold_in(rng, n), params, kind,
                             direction_dtype)
        lp = loss_fn(tree_axpy(mu, v, params), batch)
        if central:
            lm = loss_fn(tree_axpy(-mu, v, params), batch)
            c = scale * (lp - lm).astype(jnp.float32) / (2 * mu)
        else:
            c = scale * (lp - base).astype(jnp.float32) / mu
        return acc.at[n].set(c)

    coeffs = jax.lax.fori_loop(0, b2, body, jnp.zeros((b2,), jnp.float32))
    return coeffs, base


def apply_coefficients(params, rng, coeffs, *, scale=1.0, kind="sphere",
                       direction_dtype=jnp.float32):
    """params + scale · Σ_n coeffs[n] · v_n / b2  (seed replay of v_n)."""
    b2 = coeffs.shape[0]

    def body(n, p):
        v = sample_direction(jax.random.fold_in(rng, n), params, kind,
                             direction_dtype)
        return tree_axpy(scale * coeffs[n] / b2, v, p)

    return jax.lax.fori_loop(0, b2, body, params)


def estimate(loss_fn, params, batch, rng, *, mu, b2, kind="sphere"):
    """Materialized gradient-estimate pytree (Eq. 2). Two tree passes per
    direction; used at paper scale and by tests/property checks."""
    coeffs, _ = coefficients(loss_fn, params, batch, rng, mu=mu, b2=b2,
                             kind=kind)
    grad = apply_coefficients(tree_zeros_like(params), rng, coeffs, kind=kind)
    return grad


def two_point_estimate(loss_fn, params, batch, rng, *, mu, kind="sphere"):
    """The classic two-point estimator (b1=b2=1 special case) used by the
    DZOPA / ZONE-S baselines before their mini-batch upgrade."""
    return estimate(loss_fn, params, batch, rng, mu=mu, b2=1, kind=kind)
