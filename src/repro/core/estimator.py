"""Stochastic zeroth-order gradient estimators (paper Sec. II-B, Eq. 2).

The mini-batch estimator with b1 data samples and b2 directions:

    ∇̃F(x) = 1/(b1·b2) Σ_m Σ_n (d·v_n/μ) (F(x + μ v_n, ξ_m) − F(x, ξ_m)),
    v_n ~ U(S^{d-1})

Because the same minibatch {ξ_m} is used at both points, the m-average is
just the minibatch-mean loss, so the implementation evaluates the minibatch
loss once at x and once at each x + μ v_n.

Directions are *never stored*: each v_n is regenerated from
``fold_in(rng, n)`` (seed replay, see utils/tree.py). That gives two forms:

- ``estimate(...)``        → materialized gradient-estimate pytree
                             (paper-scale models; FedAvg-compatible API)
- ``coefficients(...)``    → only the b2 scalar coefficients
                             c_n = d·(L(x+μv_n) − L(x))/μ; the update
                             Σ c_n v_n / b2 is replayed later (big models,
                             seed-based delta compression, AirComp-free mode)

Variants beyond the paper's sphere estimator:
- ``gaussian``  (Nesterov-Spokoiny smoothing; MeZO-style)  — no d factor.
- ``coordinate`` (Kiefer-Wolfowitz-type, random coordinates) — d factor,
  v = e_i basis vectors; paper Table I compares against this family.
- ``rademacher`` (SPSA-style ±1 directions) — no d factor (E[vvᵀ] = I).
- ``central=True`` uses the two-sided difference
  (F(x+μv) − F(x−μv)) / 2μ — one extra query per direction buys an
  O(μ²) bias instead of O(μ) (standard ZO variance/bias trade).

Two direction *conventions* coexist (DESIGN.md §7):

- ``conv="tree"``    (default) per-leaf threefry keys via fold_in — the
                     original pytree path.
- ``conv="counter"`` the flat counter convention (round_key, n, flat
                     index) of kernels/zo_axpy.py, shared bit-for-bit with
                     the in-kernel generators of the flat-buffer hot path
                     (``flat_coefficients`` / ``flat_apply_coefficients``
                     below). With this conv the pytree path and the fused
                     flat path walk the *same* directions, so their loss
                     trajectories agree to fp32 round-off — the
                     equivalence tests pin exactly that.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels.zo_axpy import counter_direction_flat
from repro.utils.flatparams import FlatSpec, flat_spec, unflatten
from repro.utils.tree import (normal_like_tree, sphere_like_tree,
                              tree_add_normal, tree_axpy, tree_norm,
                              tree_random_sq_norm, tree_scale, tree_size,
                              tree_zeros_like)

# estimator kind → counter-convention generator kind (coordinate directions
# have no streaming generator; the flat path rejects them)
COUNTER_KINDS = {"sphere": "normal", "gaussian": "normal",
                 "rademacher": "sign"}


def sample_direction(rng, params, kind: str, dtype=jnp.float32):
    """One direction pytree v with E-factor folded into the caller's d-scale."""
    if kind == "sphere":
        return sphere_like_tree(rng, params, dtype=dtype)
    if kind == "gaussian":
        return normal_like_tree(rng, params, dtype=dtype)
    if kind == "rademacher":
        leaves, treedef = jax.tree.flatten(params)
        out = [jax.random.rademacher(jax.random.fold_in(rng, i), l.shape,
                                     dtype)
               for i, l in enumerate(leaves)]
        return jax.tree.unflatten(treedef, out)
    if kind == "coordinate":
        # one-hot at a uniformly random flat index, built leafwise
        d = tree_size(params)
        idx = jax.random.randint(rng, (), 0, d)
        leaves, treedef = jax.tree.flatten(params)
        out, off = [], 0
        for leaf in leaves:
            n = leaf.size
            flat = jnp.where(jnp.arange(n) == idx - off, 1.0, 0.0)
            out.append(flat.reshape(leaf.shape).astype(dtype))
            off += n
        return jax.tree.unflatten(treedef, out)
    raise ValueError(f"unknown estimator kind {kind!r}")


def _key_data(rng):
    """uint32 [2] key words from either a typed PRNG key or raw key data."""
    if jnp.issubdtype(jnp.asarray(rng).dtype, jnp.unsignedinteger):
        return jnp.asarray(rng, jnp.uint32)
    return jax.random.key_data(rng)


def counter_direction(rng, n, params, kind, dtype=jnp.float32):
    """Direction pytree v_n under the flat counter convention.

    The pure-JAX twin of the in-kernel generators: same
    (round_key, n, flat_index) → element map as zo_walk / zo_replay, so a
    pytree-path run with conv="counter" walks the flat path's directions.
    """
    ck = COUNTER_KINDS.get(kind)
    if ck is None:
        raise ValueError(f"counter convention does not support {kind!r}")
    spec = flat_spec(params)
    key2 = _key_data(rng)
    g = counter_direction_flat(key2, n, spec.d, kind=ck)
    if kind == "sphere":
        g = g * (1.0 / (jnp.linalg.norm(g) + 1e-30))
    out = [g[off:off + sz].reshape(shp).astype(dtype)
           for shp, off, sz in zip(spec.shapes, spec.offsets, spec.sizes)]
    return jax.tree.unflatten(spec.treedef, out)


def _direction(rng, n, params, kind, dtype, conv):
    if conv == "counter":
        return counter_direction(rng, n, params, kind, dtype)
    return sample_direction(jax.random.fold_in(rng, n), params, kind, dtype)


def _scale_factor(d, kind):
    # unbiasedness factor: d for sphere/coordinate, 1 for gaussian/rademacher
    # (for which E[vv^T] = I without rescaling)
    return 1.0 if kind in ("gaussian", "rademacher") else float(d)


def stream_perturb(params, key, mag, kind="sphere", dtype=jnp.float32):
    """params + mag·v(key) WITHOUT materializing v (chunked RNG streaming —
    the big-model memory path, §Perf iteration 3). Bit-consistent with
    ``sample_direction`` up to float reassociation of the sphere scaling."""
    if kind == "coordinate":
        return tree_axpy(mag, sample_direction(key, params, kind), params)
    if kind == "sphere":
        inv = 1.0 / (jnp.sqrt(tree_random_sq_norm(key, params, dtype)) + 1e-30)
        return tree_add_normal(params, key, mag * inv, dtype)
    return tree_add_normal(params, key, mag, dtype)  # gaussian


def coefficients(loss_fn, params, batch, rng, *, mu, b2, kind="sphere",
                 base_loss=None, direction_dtype=jnp.float32, central=False,
                 conv="tree"):
    """The b2 coefficients c_n = scale·(L(x+μ v_n) − L(x))/μ  (fp32 [b2]).

    ``loss_fn(params, batch) -> scalar``. Directions are regenerated from
    ``fold_in(rng, n)`` (conv="tree") or the counter convention
    (conv="counter"); callers replay the same seeds to apply updates.
    ``central=True`` uses (L(x+μv) − L(x−μv)) / 2μ (O(μ²) smoothing bias,
    one extra forward per direction).
    """
    d = tree_size(params)
    scale = _scale_factor(d, kind)
    base = loss_fn(params, batch) if base_loss is None else base_loss

    def body(n, acc):
        # materialized direction + axpy measured Pareto-best on the XLA:CPU
        # buffer-assignment instrument (§Perf iteration 3: two-pass
        # streaming, chunked and rbg variants all refuted).
        v = _direction(rng, n, params, kind, direction_dtype, conv)
        lp = loss_fn(tree_axpy(mu, v, params), batch)
        if central:
            lm = loss_fn(tree_axpy(-mu, v, params), batch)
            c = scale * (lp - lm).astype(jnp.float32) / (2 * mu)
        else:
            c = scale * (lp - base).astype(jnp.float32) / mu
        return acc.at[n].set(c)

    coeffs = jax.lax.fori_loop(0, b2, body, jnp.zeros((b2,), jnp.float32))
    return coeffs, base


def apply_coefficients(params, rng, coeffs, *, scale=1.0, kind="sphere",
                       direction_dtype=jnp.float32, conv="tree"):
    """params + scale · Σ_n coeffs[n] · v_n / b2  (seed replay of v_n)."""
    b2 = coeffs.shape[0]

    def body(n, p):
        v = _direction(rng, n, params, kind, direction_dtype, conv)
        return tree_axpy(scale * coeffs[n] / b2, v, p)

    return jax.lax.fori_loop(0, b2, body, params)


# ---------------------------------------------------------------------------
# flat-buffer hot path (DESIGN.md §7): fused perturbation walk + single-pass
# seed-replay update over a FlatParams buffer, all directions regenerated
# in-kernel from the counter convention.


def flat_inv_norms(key2, spec: FlatSpec, b2, kind, *, interpret=None,
                   block_rows=None):
    """[b2] per-direction scale factors: 1/‖g_n‖ for sphere, else ones.

    Computed by the zo_dirnorms kernel — directions never touch HBM.
    """
    if kind != "sphere":
        return jnp.ones((b2,), jnp.float32)
    sq = kops.zo_dirnorms(key2, spec.d, b2=b2, n_pad=spec.n_pad,
                          kind="normal", interpret=interpret,
                          block_rows=block_rows)
    return 1.0 / (jnp.sqrt(sq) + 1e-30)


def flat_coefficients(loss_fn, buf, spec: FlatSpec, batch, rng, *, mu, b2,
                      kind="sphere", base_loss=None, central=False,
                      interpret=None, block_rows=None, inv=None):
    """Fused MeZO-style perturbation walk over the flat buffer (fp32 [b2]).

    Instead of perturb-then-restore (two passes between forwards), each
    step transitions x+μv_{n-1} → x+μv_n directly with one zo_walk call
    (a=−μ, b=+μ): ONE read + ONE write of the parameter buffer per
    direction, zero direction HBM traffic. Numerically this is the pytree
    path with conv="counter" up to fp32 reassociation.
    """
    ck = COUNTER_KINDS.get(kind)
    if ck is None:
        raise ValueError(f"flat path does not support kind={kind!r}")
    key2 = _key_data(rng)
    scale = _scale_factor(spec.d, kind)
    base = (loss_fn(unflatten(buf, spec), batch)
            if base_loss is None else base_loss)
    if inv is None:
        inv = flat_inv_norms(key2, spec, b2, kind, interpret=interpret,
                             block_rows=block_rows)
    mu = jnp.float32(mu)

    def body(n, carry):
        xp, coeffs = carry
        prev = jnp.maximum(n - 1, 0)
        # state entering step n: x (n=0); x+μv_{n-1} (one-sided, n>0);
        # x−μv_{n-1} (central, n>0) — remove it and add +μv_n in one pass
        a = jnp.where(n == 0, 0.0, (mu if central else -mu) * inv[prev])
        b = mu * inv[n]
        xp = kops.zo_walk(xp, key2, jnp.stack([prev, n]), jnp.stack([a, b]),
                          kind=ck, interpret=interpret,
                          block_rows=block_rows)
        lp = loss_fn(unflatten(xp, spec), batch)
        if central:
            xp = kops.zo_walk(xp, key2, jnp.stack([n, n]),
                              jnp.stack([-2 * mu * inv[n], jnp.float32(0.0)]),
                              kind=ck, interpret=interpret,
                              block_rows=block_rows)
            lm = loss_fn(unflatten(xp, spec), batch)
            c = scale * (lp - lm).astype(jnp.float32) / (2 * mu)
        else:
            c = scale * (lp - base).astype(jnp.float32) / mu
        return xp, coeffs.at[n].set(c)

    # loss_fn may return a scalar or a vector (e.g. per-pod grouped losses);
    # coefficients get a matching trailing shape
    _, coeffs = jax.lax.fori_loop(
        0, b2, body, (buf, jnp.zeros((b2,) + jnp.shape(base), jnp.float32)))
    return coeffs, base


def flat_apply_coefficients(buf, spec: FlatSpec, rng, coeffs, *, scale=1.0,
                            kind="sphere", interpret=None, block_rows=None,
                            inv=None):
    """buf + scale · Σ_n coeffs[n]·v_n / b2 in a SINGLE pass (zo_replay).

    The b2 directions are regenerated and accumulated in VMEM per block —
    one HBM read + write of the parameter buffer total, versus b2
    sequential axpy passes on the pytree path. Pass ``inv`` when the
    per-direction norms were already computed (one zo_dirnorms run covers
    both the perturb and the replay end of an iterate).
    """
    ck = COUNTER_KINDS.get(kind)
    if ck is None:
        raise ValueError(f"flat path does not support kind={kind!r}")
    b2 = coeffs.shape[0]
    key2 = _key_data(rng)
    if inv is None:
        inv = flat_inv_norms(key2, spec, b2, kind, interpret=interpret,
                             block_rows=block_rows)
    eff = (jnp.float32(scale) / b2) * coeffs.astype(jnp.float32) * inv
    return kops.zo_replay(buf, key2, eff, kind=ck, interpret=interpret,
                          block_rows=block_rows)


def direction_block(rng, spec: FlatSpec, b2, *, kind="sphere", conv="block",
                    like=None, dtype=jnp.float32):
    """All b2 directions of one iterate as ONE [b2, n_pad] block, plus the
    [b2] per-direction scale factors (1/‖g_n‖ for sphere, ones otherwise).

    The batched-direction ("wide") estimator of the simulation engine
    (DESIGN.md §9). Three conventions:

    - conv="block": one PRNG call for the whole block — the fast path. The
      pad columns may carry generator residue; norms are taken over the
      valid [:, :spec.d] region only and pad residue in downstream updates
      is invisible to ``unflatten``.
    - conv="tree": per-direction per-leaf fold_in keys, bit-identical to
      ``sample_direction(fold_in(rng, n), ...)`` — the loop estimator's
      directions, used to prove wide-vs-loop trajectory equivalence.
      Requires ``like`` (a params pytree matching ``spec``).
    - conv="channel": the channel-driven one-point wireless estimator
      (arXiv 2401.17460) — the direction block is the real baseband
      projection of CN(0,1) fading randomness, i.e. a unit-variance
      gaussian block drawn with the channel innovation's key fan-out
      (``kr`` of ``split(rng)`` drives the in-phase component, exactly
      like ``sim.channel.ChannelModel._innovation``), so in a deployment
      the perturbation reuses the randomness the receiver already
      estimates and costs no direction downlink. Statistically a gaussian
      estimator: E[vvᵀ] = I, so ``inv`` is ones and the update scale must
      be the gaussian one (no d-factor, no sphere normalization) whatever
      ``kind`` says — the wide phase overrides it.
    """
    if kind == "coordinate":
        raise ValueError("batched-direction path does not support "
                         "kind='coordinate'")
    if conv == "channel":
        kr, _ki = jax.random.split(rng)
        V = jax.random.normal(kr, (b2, spec.n_pad), dtype)
        return V, jnp.ones((b2,), jnp.float32)
    if conv == "tree":
        if like is None:
            raise ValueError("conv='tree' direction blocks need the params "
                             "pytree (like=...) for per-leaf key derivation")
        from repro.utils.flatparams import flatten

        def one(k):
            if kind == "rademacher":
                g = sample_direction(k, like, kind, dtype)
            else:
                g = normal_like_tree(k, like, dtype=dtype)
            return flatten(g, spec)

        keys = jax.vmap(lambda n: jax.random.fold_in(rng, n))(jnp.arange(b2))
        V = jax.vmap(one)(keys)                              # [b2, n_pad]
    elif conv == "block":
        if kind == "rademacher":
            V = jax.random.rademacher(rng, (b2, spec.n_pad), dtype)
        else:
            V = jax.random.normal(rng, (b2, spec.n_pad), dtype)
    else:
        raise ValueError(f"unknown direction block conv {conv!r}")
    if kind == "sphere":
        inv = 1.0 / (jnp.linalg.norm(
            V[:, :spec.d].astype(jnp.float32), axis=1) + 1e-30)
    else:
        inv = jnp.ones((b2,), jnp.float32)
    return V, inv


def estimate(loss_fn, params, batch, rng, *, mu, b2, kind="sphere"):
    """Materialized gradient-estimate pytree (Eq. 2). Two tree passes per
    direction; used at paper scale and by tests/property checks."""
    coeffs, _ = coefficients(loss_fn, params, batch, rng, mu=mu, b2=b2,
                             kind=kind)
    grad = apply_coefficients(tree_zeros_like(params), rng, coeffs, kind=kind)
    return grad


def two_point_estimate(loss_fn, params, batch, rng, *, mu, kind="sphere"):
    """The classic two-point estimator (b1=b2=1 special case) used by the
    DZOPA / ZONE-S baselines before their mini-batch upgrade."""
    return estimate(loss_fn, params, batch, rng, mu=mu, b2=1, kind=kind)
