"""Composable algorithm strategies (DESIGN.md §13).

One communication round decomposes into four pluggable pieces, all wired
through the SAME simulated round (``fedzo.round_simulated``) so every
aggregation path — pytree / flat Pallas / wide batched-direction / AirComp
/ channel-truncation scheduling / size weighting / the sharded psum — is
shared by every algorithm:

- **loss transform** — wraps the ZO loss query per client (FedProx's
  proximal term, FedDyn's dynamic regularizer) so the estimator itself is
  untouched; the finite-difference machinery never sees the algorithm.
- **client state** — a fixed-shape per-client pytree stacked ``[N, ...]``
  (SCAFFOLD control variates, FedDyn duals), threaded through the
  experiment-scan carry exactly like ``FaultModel`` state: the round
  gathers the sampled cohort's rows, updates them, scatters them back.
- **delta transform** — a post-local-phase correction applied in flat
  ``[M, n_pad]`` space on the flat/wide paths (stacked pytree otherwise),
  BEFORE fault corruption and aggregation, so it composes with AirComp,
  scheduling, weighting, and the sharded reduce unchanged.
- **server update** — the post-aggregation step (momentum/lr, SCAFFOLD's
  global control, FedDyn's ``x ← x̄ − h/α``), applied at the round-step
  level from the recovered aggregate ``Δ̄ = x' − x_t``.

``AlgoStrategy`` (the base class) IS FedZO: every hook defaults to None
and ``run_round`` reproduces the engine's historical round branch
byte-for-byte — the golden fixtures and the host≡engine matrix pin that.
The registry (``get``/``register``) is what ``sim.engine.make_round_step``
dispatches on; ``resolve`` additionally honors the deprecated ``algo=``
string kwarg and falls back to ``cfg.strategy``.

Reductions (pinned bit-exactly by tests/test_strategy.py): ZO-FedProx with
``prox_mu=0`` and ZO-FedDyn with ``dyn_alpha=0`` statically elide their
hooks and run the base FedZO round unchanged.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.core import fedavg, fedzo
from repro.utils.flatparams import flatten, unflatten
from repro.utils.tree import tree_dot, tree_sub, tree_zeros_like


def _static_positive(x, name: str = "server_momentum") -> bool:
    """cfg fields compared against 0 at trace time must be static — a
    sweep-vmapped (traced) value here would silently change the program
    structure, so reject it loudly."""
    if isinstance(x, jax.core.Tracer):
        raise ValueError(f"{name} selects the round program structure and "
                         f"cannot be swept/vmapped — keep it static")
    return x > 0


def _sq_diff(a, b):
    """Σ‖a − b‖² over a pytree pair, fp32."""
    return sum(jnp.sum(jnp.square(la.astype(jnp.float32) -
                                  lb.astype(jnp.float32)))
               for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _stack_zeros(template, n: int):
    """[n, ...]-stacked zeros_like of a pytree template."""
    return jax.tree.map(lambda l: jnp.zeros((n,) + l.shape, l.dtype),
                        template)


class AlgoStrategy:
    """Base strategy == plain FedZO (paper Algorithm 1).

    Subclasses override the hooks (or ``run_round`` wholesale). The engine
    calls, per round::

        params', metrics, momentum', zstate' = strat.run_round(
            loss_fn, params, batches, k_zo, cfg, channel_rng=..,
            momentum=.., zstate=.., idx=.., round_fn=.., **wkw)

    ``zstate`` is the strategy's carry slot — ``None`` for stateless
    strategies, else ``{"client": [N, ...] stacked pytree, "server":
    pytree}``; ``idx`` the round's sampled client indices ([M] int32).
    """
    name = "fedzo"
    stateful = False
    # custom round_fns (the clients-mesh sharded round) replace
    # fedzo.round_simulated wholesale and know nothing of strategy hooks
    supports_round_fn = True

    def validate(self, cfg: FedZOConfig):
        """Static config validation at round-step build time."""

    def has_momentum(self, cfg: FedZOConfig) -> bool:
        return _static_positive(cfg.server_momentum)

    def init_state(self, params, cfg: FedZOConfig, n_clients: int):
        """Round-0 strategy carry (None when the strategy is stateless)."""
        return None

    def run_round(self, loss_fn, params, batches, k_zo, cfg: FedZOConfig, *,
                  channel_rng=None, momentum=None, zstate=None, idx=None,
                  round_fn=None, **wkw):
        fz = round_fn if round_fn is not None else fedzo.round_simulated
        rngs = jax.random.split(k_zo, cfg.n_participating)
        if self.has_momentum(cfg):
            params, metrics, momentum = fz(
                loss_fn, params, batches, rngs, cfg, channel_rng=channel_rng,
                momentum=momentum, **wkw)
        else:
            params, metrics = fz(loss_fn, params, batches, rngs, cfg,
                                 channel_rng=channel_rng, **wkw)
        return params, metrics, momentum, zstate


class FedAvgStrategy(AlgoStrategy):
    """First-order FedAvg baseline as a strategy (no ZO keys, no momentum
    carry) — byte-identical to the engine's historical fedavg branch."""
    name = "fedavg"

    def has_momentum(self, cfg):
        return False

    def run_round(self, loss_fn, params, batches, k_zo, cfg, *,
                  channel_rng=None, momentum=None, zstate=None, idx=None,
                  round_fn=None, **wkw):
        params, metrics = fedavg.round_simulated(
            loss_fn, params, batches, cfg, channel_rng=channel_rng, **wkw)
        return params, metrics, momentum, zstate


class ZOFedProx(AlgoStrategy):
    """ZO-FedProx: the FedZO round with the proximal term
    (prox_mu/2)·‖x − x_t‖² folded into every local ZO loss query.
    Stateless; composes with server momentum like FedZO. ``prox_mu=0``
    statically elides the wrap — bit-exact FedZO."""
    name = "fedprox"
    supports_round_fn = False

    def run_round(self, loss_fn, params, batches, k_zo, cfg, *,
                  channel_rng=None, momentum=None, zstate=None, idx=None,
                  round_fn=None, **wkw):
        if not _static_positive(cfg.prox_mu, "prox_mu"):
            return super().run_round(
                loss_fn, params, batches, k_zo, cfg, channel_rng=channel_rng,
                momentum=momentum, zstate=zstate, idx=idx, round_fn=round_fn,
                **wkw)
        half_mu = 0.5 * cfg.prox_mu

        def loss_wrap(lf, cst):
            del cst
            return lambda p, b: lf(p, b) + half_mu * _sq_diff(p, params)

        rngs = jax.random.split(k_zo, cfg.n_participating)
        if self.has_momentum(cfg):
            params_new, metrics, momentum = fedzo.round_simulated(
                loss_fn, params, batches, rngs, cfg, channel_rng=channel_rng,
                momentum=momentum, loss_wrap=loss_wrap, **wkw)
        else:
            params_new, metrics = fedzo.round_simulated(
                loss_fn, params, batches, rngs, cfg, channel_rng=channel_rng,
                loss_wrap=loss_wrap, **wkw)
        return params_new, metrics, momentum, zstate


class _StatefulZO(AlgoStrategy):
    """Shared plumbing for strategies with a per-client + server state."""
    stateful = True
    supports_round_fn = False

    def validate(self, cfg):
        self.has_momentum(cfg)  # rejects cfg.server_momentum > 0

    def has_momentum(self, cfg):
        if _static_positive(cfg.server_momentum):
            raise ValueError(
                f"strategy {self.name!r} carries its own server-side "
                f"control state and does not compose with "
                f"cfg.server_momentum — run momentum through fedzo/fedprox")
        return False

    def _gather(self, zstate, idx):
        return jax.tree.map(lambda a: a[idx], zstate["client"])

    def _scatter(self, zstate, idx, cohort):
        client = jax.tree.map(
            lambda a, u: a.at[idx].set(u.astype(a.dtype)),
            zstate["client"], cohort)
        return client


class ZOFedDyn(_StatefulZO):
    """ZO-FedDyn (Acar et al. 2021, zeroth-order form). Per client i the
    local ZO loss query becomes  L(x) − ⟨h_i, x⟩ + (α/2)‖x − x_t‖²  and the
    dual is refreshed client-side from its own delta, h_i ← h_i − α·Δ_i.
    The server keeps the running correction h ← h − α·(M/N)·Δ̄ and steps
    x ← (x_t + Δ̄) − h/α. ``dyn_alpha=0`` statically elides everything —
    bit-exact FedZO."""
    name = "feddyn"

    def init_state(self, params, cfg, n_clients):
        if not _static_positive(cfg.dyn_alpha, "dyn_alpha"):
            return None
        return {"client": _stack_zeros(params, n_clients),
                "server": tree_zeros_like(params)}

    def run_round(self, loss_fn, params, batches, k_zo, cfg, *,
                  channel_rng=None, momentum=None, zstate=None, idx=None,
                  round_fn=None, **wkw):
        a = cfg.dyn_alpha
        if not _static_positive(a, "dyn_alpha"):
            return super().run_round(
                loss_fn, params, batches, k_zo, cfg, channel_rng=channel_rng,
                momentum=momentum, zstate=zstate, idx=idx, round_fn=round_fn,
                **wkw)
        rngs = jax.random.split(k_zo, cfg.n_participating)
        cohort = self._gather(zstate, idx)

        def loss_wrap(lf, h_i):
            return lambda p, b: (lf(p, b) - tree_dot(h_i, p)
                                 + (0.5 * a) * _sq_diff(p, params))

        def state_fn(deltas, h, spec):
            d_tree = (jax.vmap(lambda row: unflatten(row, spec))(deltas)
                      if spec is not None else deltas)
            new_h = jax.tree.map(lambda hi, d: (hi - a * d).astype(hi.dtype),
                                 h, d_tree)
            return deltas, new_h

        params_new, metrics, new_cohort = fedzo.round_simulated(
            loss_fn, params, batches, rngs, cfg, channel_rng=channel_rng,
            cstate=cohort, loss_wrap=loss_wrap, state_fn=state_fn, **wkw)
        # server update from the recovered aggregate Δ̄ = x' − x_t — works
        # under every aggregation path because Δ̄ is whatever aggregation
        # produced (AirComp noise, masking, weighting included)
        agg = tree_sub(params_new, params)
        frac = cfg.n_participating / cfg.n_devices
        hs = jax.tree.map(lambda h, d: (h - (a * frac) * d).astype(h.dtype),
                          zstate["server"], agg)
        params_new = jax.tree.map(lambda p, h: (p - h / a).astype(p.dtype),
                                  params_new, hs)
        return params_new, metrics, momentum, {
            "client": self._scatter(zstate, idx, new_cohort), "server": hs}


class ZOScaffold(_StatefulZO):
    """ZO-SCAFFOLD (Karimireddy et al. 2020, option II, zeroth-order
    post-phase form). The variance-reduction correction −lr·(c − c_i) per
    local step is constant across the H iterates, so it is applied ONCE in
    delta space: Δ_i ← Δ_zo,i − lr·H·(c − c_i) — exactly equivalent to the
    per-iterate form, and it composes with the wide phase, AirComp, and the
    sharded reduce untouched. The refreshed client control is then
    c_i⁺ = −Δ_zo,i/(lr·H) and the server control moves by
    c ← c + (M/N)·mean_i(c_i⁺ − c_i)."""
    name = "scaffold"

    def init_state(self, params, cfg, n_clients):
        return {"client": _stack_zeros(params, n_clients),
                "server": tree_zeros_like(params)}

    def run_round(self, loss_fn, params, batches, k_zo, cfg, *,
                  channel_rng=None, momentum=None, zstate=None, idx=None,
                  round_fn=None, **wkw):
        rngs = jax.random.split(k_zo, cfg.n_participating)
        cohort = self._gather(zstate, idx)
        c = zstate["server"]
        eta = cfg.lr * cfg.local_iters  # total local step length lr·H

        def state_fn(deltas, c_i, spec):
            if spec is not None:
                c_flat = flatten(c, spec)
                ci_flat = jax.vmap(lambda t: flatten(t, spec))(c_i)
                new_deltas = deltas - eta * (c_flat[None, :] - ci_flat)
                new_ci = jax.tree.map(
                    lambda ref, u: u.astype(ref.dtype), c_i,
                    jax.vmap(lambda row: unflatten(row, spec))(
                        (-1.0 / eta) * deltas))
            else:
                new_deltas = jax.tree.map(
                    lambda d, cc, cic: (d - eta * (cc[None] - cic)
                                        ).astype(d.dtype),
                    deltas, c, c_i)
                new_ci = jax.tree.map(
                    lambda cic, d: ((-1.0 / eta) * d).astype(cic.dtype),
                    c_i, deltas)
            return new_deltas, new_ci

        params_new, metrics, new_cohort = fedzo.round_simulated(
            loss_fn, params, batches, rngs, cfg, channel_rng=channel_rng,
            cstate=cohort, state_fn=state_fn, **wkw)
        frac = cfg.n_participating / cfg.n_devices
        dmean = jax.tree.map(
            lambda n_, o: jnp.mean(n_.astype(jnp.float32) -
                                   o.astype(jnp.float32), axis=0),
            new_cohort, cohort)
        c_new = jax.tree.map(lambda cc, d: (cc + frac * d).astype(cc.dtype),
                             c, dmean)
        return params_new, metrics, momentum, {
            "client": self._scatter(zstate, idx, new_cohort),
            "server": c_new}


# ---------------------------------------------------------------------------
# registry

STRATEGIES: dict = {}


def register(strat: AlgoStrategy) -> AlgoStrategy:
    """Register a strategy instance under its ``name`` (last write wins —
    deliberate, so downstream code can swap in a tuned variant)."""
    STRATEGIES[strat.name] = strat
    return strat


register(AlgoStrategy())
register(FedAvgStrategy())
register(ZOFedProx())
register(ZOFedDyn())
register(ZOScaffold())


def get(name: str) -> AlgoStrategy:
    """Look up a registered strategy by name, loudly."""
    try:
        return STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered strategies: "
            f"{sorted(STRATEGIES)}") from None


def resolve(strategy=None, algo: Optional[str] = None,
            cfg: Optional[FedZOConfig] = None) -> AlgoStrategy:
    """Resolution order for the engine entry points: an explicit
    ``strategy`` (name or instance) wins; the legacy ``algo=`` string is
    honored with a DeprecationWarning; otherwise ``cfg.strategy``."""
    if strategy is not None:
        return get(strategy) if isinstance(strategy, str) else strategy
    if algo is not None:
        warnings.warn(
            "the algo= string kwarg is deprecated — pass strategy="
            "(a name or AlgoStrategy) or set cfg.strategy",
            DeprecationWarning, stacklevel=3)
        return get(algo)
    return get(cfg.strategy if cfg is not None else "fedzo")
