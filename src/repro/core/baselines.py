"""Distributed zeroth-order baselines the paper compares against (Fig. 1-2).

- ZO-SGD  (Ghadimi & Lan 2013): centralized stochastic ZO — the speedup
  reference point of Table I.
- DZOPA   (Yi et al. 2021 [10]): peer-to-peer distributed ZO, one ZO update +
  one consensus-mixing step per iteration. The paper evaluates it on a
  fully-connected graph and upgrades its two-point estimator to the
  mini-batch type of Eq. (2); we do the same (mixing over a fully-connected
  graph = uniform averaging).
- ZONE-S  (Hajinezhad et al. 2019 [28]): primal-dual, one sampled agent per
  iteration with penalty ρ; per its update rule the primal step reduces to
  x^{r+1} = x^r − (1/ρ)·e_{i_r}. We implement that practical form with the
  paper's ρ = 500 default (noted simplification: the exact ZONE-S dual
  recursion collapses to this under a fully-available primal oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.core import estimator
from repro.utils.tree import tree_add, tree_axpy, tree_scale


def zo_sgd_step(loss_fn, params, batch, rng, *, lr, mu, b2=1, kind="sphere",
                conv="tree", direction_dtype=jnp.float32):
    """Centralized ZO-SGD step.

    ``conv``/``direction_dtype`` route through the shared estimator
    direction conventions (tree | counter), so a baseline trajectory under
    the counter convention replays the directions the flat/engine paths
    draw — previously the kwargs were silently dropped and every call ran
    the per-leaf tree convention regardless of the experiment config.
    """
    ddt = jnp.dtype(direction_dtype)
    coeffs, base = estimator.coefficients(loss_fn, params, batch, rng,
                                          mu=mu, b2=b2, kind=kind,
                                          direction_dtype=ddt, conv=conv)
    params = estimator.apply_coefficients(params, rng, coeffs, scale=-lr,
                                          kind=kind, direction_dtype=ddt,
                                          conv=conv)
    return params, base


def dzopa_round(loss_fn, client_params, client_batches, client_rngs,
                cfg: FedZOConfig):
    """One DZOPA iteration over all N agents (fully-connected mixing).

    client_params: pytree with leading [N] axis (per-agent iterates).
    Returns (new_client_params, mean_loss). One ZO update per agent per
    round (H=1 by construction — DZOPA has no local-update loop).
    Directions follow ``cfg.direction_conv``/``cfg.direction_dtype`` like
    every FedZO path, so baseline-vs-FedZO comparisons run one convention.
    """
    ddt = jnp.dtype(cfg.direction_dtype)

    def one(params, batch, rng):
        coeffs, base = estimator.coefficients(
            loss_fn, params, batch, rng, mu=cfg.mu, b2=cfg.b2,
            kind=cfg.estimator, direction_dtype=ddt, conv=cfg.direction_conv)
        upd = estimator.apply_coefficients(params, rng, coeffs, scale=-cfg.lr,
                                           kind=cfg.estimator,
                                           direction_dtype=ddt,
                                           conv=cfg.direction_conv)
        return upd, base

    updated, losses = jax.vmap(one)(client_params, client_batches, client_rngs)
    # W = (1/N) 11^T mixing: every agent moves to the average
    mixed = jax.tree.map(
        lambda x: jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape),
        updated)
    return mixed, jnp.mean(losses)


def zone_s_round(loss_fn, params, batch, rng, *, rho, mu, b2=1, kind="sphere",
                 conv="tree", direction_dtype=jnp.float32):
    """One ZONE-S iteration: one sampled agent, penalty-ρ primal step.

    The caller samples the agent (and its batch); the step is
    x ← x − (1/ρ)·e_i with e_i the agent's mini-batch ZO estimator.
    ``conv``/``direction_dtype`` route through the shared direction
    conventions (see ``zo_sgd_step``).
    """
    ddt = jnp.dtype(direction_dtype)
    coeffs, base = estimator.coefficients(loss_fn, params, batch, rng,
                                          mu=mu, b2=b2, kind=kind,
                                          direction_dtype=ddt, conv=conv)
    params = estimator.apply_coefficients(params, rng, coeffs,
                                          scale=-1.0 / rho, kind=kind,
                                          direction_dtype=ddt, conv=conv)
    return params, base
