"""FedAvg baseline (McMahan et al. 2017) — the paper's first-order comparison
(Sec. V-B, Figs. 3-5). Same round structure as FedZO with the stochastic
zeroth-order update replaced by an SGD step on jax.grad."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.core.aircomp import aircomp_aggregate
from repro.utils.tree import tree_add, tree_axpy, tree_scale, tree_sub


def local_phase(loss_fn, params, batches, cfg: FedZOConfig):
    grad_fn = jax.value_and_grad(loss_fn)

    def body(p, batch):
        loss, g = grad_fn(p, batch)
        return tree_axpy(-cfg.lr, g, p), loss

    p_fin, losses = jax.lax.scan(body, params, batches)
    return p_fin, losses


def round_simulated(loss_fn, server_params, client_batches, cfg: FedZOConfig,
                    *, channel_rng=None):
    """One FedAvg round over M clients (batches leading axes [M, H, ...])."""
    def one_client(batches):
        p_fin, losses = local_phase(loss_fn, server_params, batches, cfg)
        return tree_sub(p_fin, server_params), losses

    deltas, losses = jax.vmap(one_client)(client_batches)
    if cfg.aircomp and channel_rng is not None:
        agg, _ = aircomp_aggregate(deltas, channel_rng, snr_db=cfg.snr_db,
                                   h_min=cfg.h_min)
    else:
        agg = tree_scale(1.0 / losses.shape[0],
                         jax.tree.map(lambda x: jnp.sum(x, 0), deltas))
    return tree_add(server_params, agg), {"mean_local_loss": jnp.mean(losses)}


def make_train_step(loss_fn, cfg: FedZOConfig):
    """Cross-silo first-order step (dry-run/roofline comparison baseline)."""
    grad_fn = jax.value_and_grad(loss_fn)

    def step(params, batch, rng):
        del rng
        loss, g = grad_fn(params, batch)
        return tree_axpy(-cfg.lr, g, params), {"loss": loss}

    return step
