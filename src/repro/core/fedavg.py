"""FedAvg baseline (McMahan et al. 2017) — the paper's first-order comparison
(Sec. V-B, Figs. 3-5). Same round structure as FedZO with the stochastic
zeroth-order update replaced by an SGD step on jax.grad."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.core.aircomp import (aircomp_aggregate, mask_stats,
                                schedule_by_channel)
from repro.utils.tree import tree_add, tree_axpy, tree_scale, tree_sub


def local_phase(loss_fn, params, batches, cfg: FedZOConfig):
    grad_fn = jax.value_and_grad(loss_fn)

    def body(p, batch):
        loss, g = grad_fn(p, batch)
        return tree_axpy(-cfg.lr, g, p), loss

    p_fin, losses = jax.lax.scan(body, params, batches)
    return p_fin, losses


def round_simulated(loss_fn, server_params, client_batches, cfg: FedZOConfig,
                    *, channel_rng=None, weights=None, faults=None,
                    channel=None):
    """One FedAvg round over M clients (batches leading axes [M, H, ...]).

    Honors the same channel-truncation scheduling as the FedZO round
    (cfg.channel_schedule): masked clients are excluded from the mean and
    Δ_max, m_effective lands in the metrics. ``weights`` ([M] mean-1
    normalized) selects the size-weighted n_i/n mean — the original
    FedAvg aggregation — on every path. ``faults`` (a
    ``sim.faults.RoundFaults``) corrupts-then-scrubs the deltas and folds
    the surviving-client mask into the aggregation, same semantics as the
    FedZO round (DESIGN.md §12).
    """
    def one_client(batches):
        p_fin, losses = local_phase(loss_fn, server_params, batches, cfg)
        return tree_sub(p_fin, server_params), losses

    deltas, losses = jax.vmap(one_client)(client_batches)
    M = losses.shape[0]
    mask = None
    noise_rng = channel_rng
    stats = {}
    if cfg.channel_schedule and channel_rng is not None:
        k_sched, noise_rng = jax.random.split(channel_rng)
        if channel is None:
            _, mask = schedule_by_channel(k_sched, M, cfg.h_min)
    if channel is not None:
        # realized wireless scenario (sim/channel.py): correlated-fading
        # scheduling ∧ battery gating replaces the i.i.d. draw
        mask = channel.mask
    if faults is not None:
        deltas, fmask = faults.apply_tree(deltas)
        mask = fmask if mask is None else mask & fmask
    if cfg.aircomp and channel_rng is not None:
        agg, stats = aircomp_aggregate(deltas, noise_rng, snr_db=cfg.snr_db,
                                       h_min=cfg.h_min, mask=mask,
                                       weights=weights)
    elif mask is not None or weights is not None:
        maskf, m_div, m_sched = mask_stats(mask, M, weights)
        agg = jax.tree.map(
            lambda x: (jnp.einsum("m...,m->...", x.astype(jnp.float32),
                                  maskf) / m_div).astype(x.dtype), deltas)
        # unconditional: weighted-but-unscheduled rounds must report the
        # same cohort-size column as every other aggregation path
        stats = {"m_effective": m_sched}
    else:
        agg = tree_scale(1.0 / M,
                         jax.tree.map(lambda x: jnp.sum(x, 0), deltas))
    if faults is not None:
        stats["m_corrupt"] = faults.n_corrupt
    return tree_add(server_params, agg), {"mean_local_loss": jnp.mean(losses),
                                          **stats}


def make_train_step(loss_fn, cfg: FedZOConfig):
    """Cross-silo first-order step (dry-run/roofline comparison baseline)."""
    grad_fn = jax.value_and_grad(loss_fn)

    def step(params, batch, rng):
        del rng
        loss, g = grad_fn(params, batch)
        return tree_axpy(-cfg.lr, g, params), {"loss": loss}

    return step
