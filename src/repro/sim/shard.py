"""Device-sharded client fan-out: the simulated round over a ``clients``
mesh axis (DESIGN.md §9).

The flat simulation round materializes the M client deltas as one
[M, n_pad] matrix (core/fedzo.py §8). Here that matrix — and the vmapped
local phases that produce it — are split across devices with ``shard_map``:
each device runs M/n_dev local phases on its shard of the per-round batches
and reduces its rows first (partial fused AirComp reduce or partial masked
einsum), so the only cross-device exchange is one n_pad-sized psum of
partial means plus the [M] row norms. Everything downstream of the reduce
(Δ_max, Eq.-17 noise, momentum, metrics) runs on the replicated result with
EXACTLY the ops of ``fedzo.round_simulated`` — on a 1-device mesh the
sharded round is bit-identical to the unsharded one, which is what the
equivalence test pins.

The returned round is a drop-in ``round_fn`` for
``sim.engine.make_round_step``, so a whole sharded experiment still runs as
ONE compiled scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import FedZOConfig
from repro.core.aircomp import P_TX, mask_stats, schedule_by_channel
from repro.core.fedzo import (_flat_phase_scan, _flat_setup,
                              _wide_phase_scan, _wide_setup)
from repro.kernels import ops as kops
from repro.launch.mesh import make_clients_mesh  # noqa: F401  (re-export)
from repro.utils.flatparams import flatten, unflatten
from repro.utils.tree import tree_add


def make_sharded_round(loss_fn, cfg: FedZOConfig, mesh: Mesh, *,
                       axis: str = "clients", store=None):
    """Signature-compatible replacement for ``fedzo.round_simulated``
    (flat/wide cfg only) with the M clients sharded over ``axis``.

    The round consumes only the per-round cohort batches, so it is
    store-tier agnostic: it runs unchanged under the device-resident
    engine AND the tiered cohort stream (sim/tiered.py). Passing the
    deployment's ``store=`` (either tier, or a client list — resolved
    through ``tiered.resolve_store``) validates the mesh split against
    the population at deployment time instead of first trace."""
    if not (cfg.flat_params or cfg.batch_directions):
        raise ValueError("the sharded round runs on the flat delta matrix — "
                         "set cfg.flat_params or cfg.batch_directions")
    n_dev = mesh.shape[axis]
    if store is not None:
        from repro.sim.tiered import resolve_store
        store = resolve_store(store, tier="auto")
        if cfg.n_participating > store.n_clients:
            raise ValueError(
                f"cfg.n_participating={cfg.n_participating} exceeds the "
                f"store's population N={store.n_clients}")
        if cfg.n_participating % n_dev:
            raise ValueError(
                f"n_participating={cfg.n_participating} must divide evenly "
                f"over the {n_dev}-device '{axis}' mesh axis")

    def round_fn(loss_fn_, server_params, client_batches, client_rngs, cfg_,
                 *, channel_rng=None, momentum=None, weights=None,
                 faults=None):
        if loss_fn_ is not loss_fn or cfg_ is not cfg:
            # the mesh deployment (phase choice, geometry, device split) is
            # bound at construction — a per-call substitution would silently
            # run the old program on the new config
            raise ValueError("make_sharded_round binds loss_fn and cfg at "
                             "deployment time; build a new sharded round to "
                             "run a different loss/config")
        M = client_rngs.shape[0]
        if M % n_dev:
            raise ValueError(f"n_participating={M} must divide evenly over "
                             f"the {n_dev}-device '{axis}' mesh axis")
        spec, br = (_wide_setup(server_params, cfg) if cfg.batch_directions
                    else _flat_setup(server_params, cfg))
        buf0 = flatten(server_params, spec)

        mask = None
        noise_rng = channel_rng
        air_stats = {}
        if cfg.channel_schedule and channel_rng is not None:
            k_sched, noise_rng = jax.random.split(channel_rng)
            _, mask = schedule_by_channel(k_sched, M, cfg.h_min)
        use_air = cfg.aircomp and channel_rng is not None
        # size weighting rides the same per-row coefficient vector the mask
        # does, so the weighted round shards identically to the masked one
        use_rowcoef = mask is not None or weights is not None
        maskf, m_div, m_sched = mask_stats(mask, M, weights)

        def local_deltas(b0, params, batches_l, rngs_l):
            keys = jax.vmap(lambda r: jax.random.split(
                r, cfg.local_iters))(rngs_l)

            if cfg.batch_directions:
                def one_client(batches, ks):
                    buf, _, base = _wide_phase_scan(loss_fn, b0, spec, ks,
                                                    batches, cfg,
                                                    like=params)
                    return buf - b0, base
            else:
                def one_client(batches, ks):
                    buf, _, base = _flat_phase_scan(loss_fn, b0, spec, br,
                                                    ks, batches, cfg)
                    return buf - b0, base

            return jax.vmap(one_client)(batches_l, keys)

        def shard_body(b0, params, batches_l, rngs_l, maskf_l):
            deltas_l, losses_l = local_deltas(b0, params, batches_l, rngs_l)

            if use_air:
                part, sq_l = kops.aircomp_reduce(deltas_l, maskf_l / m_div,
                                                 spec.d, block_rows=br)
                mean = jax.lax.psum(part, axis)
            elif use_rowcoef:
                part = jnp.einsum("mn,m->n", deltas_l, maskf_l)
                mean = jax.lax.psum(part, axis) / m_div
                sq_l = jnp.zeros((deltas_l.shape[0],), jnp.float32)
            else:
                part = jnp.sum(deltas_l, axis=0)
                mean = jax.lax.psum(part, axis) / M
                sq_l = jnp.zeros((deltas_l.shape[0],), jnp.float32)
            return mean, sq_l, losses_l

        def shard_body_faults(b0, params, batches_l, rngs_l, chan_l, w_l,
                              fmask_l, corrupt_l):
            """Fault variant: the guard verdict (and with it the surviving
            cohort and the mean divisor) is only known per-shard, so the
            scrub runs on each device's rows and the divisor is a psum of
            per-shard coefficient sums — mirroring ``mask_stats`` on the
            combined channel ∧ fault mask bit-for-bit on one device."""
            deltas_l, losses_l = local_deltas(b0, params, batches_l, rngs_l)
            deltas_l, ok_l = faults.model.scrub(deltas_l, fmask_l, corrupt_l)
            combined_l = (chan_l & ok_l).astype(jnp.float32)
            n_sched = jax.lax.psum(jnp.sum(combined_l), axis)
            coef_l = combined_l * w_l
            if weights is None:
                div = jnp.maximum(n_sched, 1.0)
            else:
                div = jnp.maximum(jax.lax.psum(jnp.sum(coef_l), axis), 1e-8)
            if use_air:
                part, sq_l = kops.aircomp_reduce(deltas_l, coef_l / div,
                                                 spec.d, block_rows=br)
                mean = jax.lax.psum(part, axis)
            else:
                part = jnp.einsum("mn,m->n", deltas_l, coef_l)
                mean = jax.lax.psum(part, axis) / div
                sq_l = jnp.zeros((deltas_l.shape[0],), jnp.float32)
            return mean, sq_l, losses_l, coef_l, div, n_sched

        if faults is not None:
            chan = (jnp.ones((M,), jnp.bool_) if mask is None else mask)
            w = (jnp.ones((M,), jnp.float32) if weights is None
                 else weights.astype(jnp.float32))
            agg_flat, sq, losses, maskf, m_div, m_sched = shard_map(
                shard_body_faults, mesh=mesh,
                in_specs=(P(), P(), P(axis), P(axis), P(axis), P(axis),
                          P(axis), P(axis)),
                out_specs=(P(), P(axis), P(axis), P(axis), P(), P()),
                check_rep=False)(buf0, server_params, client_batches,
                                 client_rngs, chan, w, faults.mask,
                                 faults.corrupt)
        else:
            agg_flat, sq, losses = shard_map(
                shard_body, mesh=mesh,
                in_specs=(P(), P(), P(axis), P(axis), P(axis)),
                out_specs=(P(), P(axis), P(axis)),
                check_rep=False)(buf0, server_params, client_batches,
                                 client_rngs, maskf)

        if use_air:
            # Δ_max / Eq.-17 noise on the replicated mean: literally the
            # tail of aircomp_aggregate_flat, fed by the psum'd partials
            sigma_w2 = P_TX / (10.0 ** (cfg.snr_db / 10.0))
            delta_max = jnp.max(jnp.where(maskf > 0, sq, 0.0))
            noise_var = sigma_w2 * delta_max / (
                m_div ** 2 * float(spec.d) * P_TX * cfg.h_min ** 2)
            noise_std = jnp.sqrt(noise_var)
            agg_flat = kops.zo_walk(agg_flat, jax.random.key_data(noise_rng),
                                    jnp.zeros((2,), jnp.int32),
                                    jnp.stack([noise_std, jnp.float32(0.0)]),
                                    kind="normal", block_rows=br)
            air_stats = {"aircomp_noise_std": noise_std,
                         "delta_max": delta_max, "m_effective": m_sched}
        elif mask is not None or faults is not None:
            air_stats = {"m_effective": m_sched}
        if faults is not None:
            air_stats["m_corrupt"] = faults.n_corrupt

        agg = unflatten(agg_flat, spec)
        if momentum is not None and cfg.server_momentum > 0:
            momentum = jax.tree.map(
                lambda m, g: (cfg.server_momentum * m + g).astype(m.dtype),
                momentum, agg)
            agg = momentum
        new_params = tree_add(server_params, agg)
        metrics = {"mean_local_loss": jnp.mean(losses),
                   "first_loss": jnp.mean(losses[:, 0]), **air_stats}
        if momentum is not None:
            return new_params, metrics, momentum
        return new_params, metrics

    return round_fn
