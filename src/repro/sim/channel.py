"""Wireless scenario engine: the channel as a scanned process (DESIGN.md §16).

The paper's AirComp story (Sec. IV) draws one i.i.d. Rayleigh channel per
round (``aircomp.schedule_by_channel``). Real devices move: fading is
time-correlated, and a device's energy budget — not just its instantaneous
channel — decides whether it can transmit. ``ChannelModel`` makes both
first-class citizens of the compiled round, mirroring the ``FaultModel``
carry-state contract (DESIGN.md §12):

- **Time-correlated flat fading** — each of the N clients carries a complex
  Gauss–Markov (AR(1)) chain through the experiment carry:

      h' = ρ·h + sqrt(1 − ρ²)·w,      w ~ CN(0, 1)

  with ρ from a Doppler/mobility knob (``from_doppler``). ρ = 0 reduces
  BIT-EXACTLY to the i.i.d. per-round draw (the advance returns the fresh
  innovation itself), and the stationary law is CN(0, 1) for every ρ — the
  Rayleigh scheduling rate exp(−h_min²) is preserved, only the
  round-to-round correlation changes.
- **Energy-gated participation** (arXiv 2409.16456) — each client carries a
  battery [N], debited by the Eq.-15 transmit budget (``tx_cost``, the
  normalized d·P a device provisions for the worst-case α·Δ_max
  transmission) every round it actually transmits. A drained client is
  masked out through the shared ``aircomp.mask_stats`` convention, exactly
  like a deep-fade or faulted one, and ``m_effective`` reports the
  surviving cohort. The debit is the *budget*, not the realized per-round
  energy: participation gating must be decidable before the round runs —
  which is also what makes the chain host-replayable.

The whole per-round transition (``step``) is a pure function of
(key, state, idx) and the static config, with NO dependence on the round's
deltas — so the tiered ``CohortStream`` (DESIGN.md §15) replays the chain
on the host, arbitrarily ahead of the device, bit-identically to the
in-carry derivation. The per-round key is a dedicated stream of the round
key chain (``sim.engine.round_keys``; after the fault key when faults run);
a ``channel_model=None`` run keeps the original splits, so existing
trajectories and the golden fixtures are untouched.

Why the chain state is INTEGER fixed-point
------------------------------------------
The host replay runs the transition eagerly; the resident engine compiles
the same transition into a scan body. XLA does not compile float
arithmetic identically across those contexts: jit rewrites ``x / const``
into ``x * (1/const)``, fuses ``a·x + b·y`` into FMAs (one rounding
instead of two), and will even DUPLICATE a producer feeding both the scan
carry and an emitted output, contracting each copy differently —
``lax.optimization_barrier`` fences code motion, not duplication, so no
float formulation of the update is robustly bit-stable (we tried; the
carry lanes and the emitted lanes of the same logical tensor came back
different). Integer ops have no rounding, so the chain carries int32
fixed-point state and bitwise identity across every compilation context is
structural:

- fading ``h``: int32 [N, 2] in Q.14 per component (re, im), clipped to
  |h| < 16 (a ≥22σ event under the stationary law — the clip is an
  overflow guard, not a statistical truncation);
- AR(1) coefficients in Q.12: ``ρ_q = round(ρ·2^12)`` and
  ``σ_q = round(sqrt(2^24 − ρ_q²))``, so the stationary variance is 1 to
  within ~2^-12 of quantization;
- the CN(0, 1) innovation is a 24-term Irwin–Hall sum of raw PRNG bits
  per component (variance exactly 1/2 per component after the power-of-two
  shift; max CDF error vs the true Gaussian ~1e-3 — far below what any
  scheduling-rate statistic resolves);
- battery in Q.16 energy units; debits are integer subtractions;
- the |h| ≥ h_min truncation compares the EXACT integer magnitude
  ``re² + im²`` (Q.20) against ``round(h_min²·2^20)`` — ``h_min`` may be a
  traced sweep axis, and the float→threshold conversion uses only
  exactly-specified ops (mul, round, convert).

Floats only appear in derived per-round values (``RoundChannel.h`` as
complex64 for consumers/telemetry), produced by an int→f32 convert (exact
below 2^24) and a power-of-two scale (exact) — no rounding anywhere, in
any context. tests/test_channel.py pins eager ≡ in-scan bit-equality of
the full chain.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

# salt for the chain's round-0 state: folded into the experiment key so the
# init draw never consumes the per-round key chain (channel-off runs keep
# their exact key usage)
INIT_SALT = 0x6368  # "ch"

_FRAC_H = 14        # fading component fixed point: Q.14
_FRAC_C = 12        # AR(1) coefficient fixed point: Q.12
_FRAC_B = 16        # battery fixed point: Q.16
_FRAC_M = 20        # |h|² magnitude fixed point for the h_min compare
_CLT_DRAWS = 24     # Irwin–Hall terms per component (variance 1/2 exactly)
_H_CLIP = (1 << (_FRAC_H + 4)) - 1   # |h| < 16: int32 overflow guard


def init_key(key):
    """The channel chain's round-0 key, derived off the experiment key
    WITHOUT consuming the round key chain."""
    return jax.random.fold_in(key, INIT_SALT)


def fading(state):
    """The chain's [N] complex64 fading from its integer carry state."""
    return _to_complex(state[0])


def battery(state):
    """The chain's [N] float32 battery levels from its integer carry."""
    return state[1].astype(jnp.float32) * jnp.float32(2.0 ** -_FRAC_B)


def _to_complex(h_q):
    """Q.14 int32 [..., 2] → complex64. Exact in every context: the
    convert is exact below 2^24 and the scale is a power of two."""
    f = h_q.astype(jnp.float32) * jnp.float32(2.0 ** -_FRAC_H)
    return jax.lax.complex(f[..., 0], f[..., 1])


@dataclass(frozen=True)
class ChannelModel:
    """Static wireless-scenario configuration (hashable — safe to close
    over in jitted programs and to use as a ``run_sweep`` static axis).

    ``rho`` is the AR(1) fading correlation (0 ⇒ i.i.d. per round, → 1 ⇒
    frozen channel; quantized internally to Q.12 — ``describe()`` reports
    the effective value). ``battery`` > 0 enables energy gating with that
    initial per-client budget; ``tx_cost`` is the energy debited per
    transmission (the normalized Eq.-15 budget d·P). ``battery`` ≤ 0
    disables gating (infinite energy)."""
    rho: float = 0.0
    battery: float = 0.0
    tx_cost: float = 1.0

    def __post_init__(self):
        if not 0.0 <= self.rho < 1.0:
            raise ValueError(f"rho={self.rho} must be in [0, 1)")
        if self.tx_cost <= 0.0:
            raise ValueError(f"tx_cost={self.tx_cost} must be positive")
        if self.battery >= 30000.0 or self.tx_cost >= 30000.0:
            raise ValueError("battery/tx_cost must stay below 30000 "
                             "(Q.16 int32 energy accounting)")

    @classmethod
    def from_doppler(cls, fd_T: float, **kw) -> "ChannelModel":
        """Build from a normalized Doppler spread fd·T (Doppler frequency ×
        round duration) under the exponential-correlation mobility model
        ρ = exp(−2π·fd·T): a static device (fd_T=0) keeps its channel, a
        fast-moving one (fd_T ≳ 0.5) decorrelates to i.i.d."""
        if fd_T < 0:
            raise ValueError(f"fd_T={fd_T} must be >= 0")
        return cls(rho=math.exp(-2.0 * math.pi * fd_T), **kw)

    @property
    def gated(self) -> bool:
        """Whether energy gating is active."""
        return self.battery > 0.0

    @property
    def coherence_rounds(self) -> float:
        """Rounds until the fading autocorrelation decays to 1/e."""
        return math.inf if self.rho >= 1.0 else (
            0.0 if self.rho == 0.0 else -1.0 / math.log(self.rho))

    def _coeffs(self) -> tuple:
        """(ρ_q, σ_q) in Q.12, with σ derived from the QUANTIZED ρ so the
        stationary variance stays 1 to within quantization."""
        rho_q = min(int(round(self.rho * (1 << _FRAC_C))), (1 << _FRAC_C) - 1)
        sigma_q = int(round(math.sqrt((1 << (2 * _FRAC_C)) - rho_q ** 2)))
        return rho_q, sigma_q

    def describe(self) -> dict:
        """The scenario configuration as a plain-JSON manifest block
        (obs/manifest.py), with the derived coherence time, the effective
        (Q.12-quantized) ρ, and the gating flag so a manifest reader sees
        the mobility regime at a glance."""
        d = dataclasses.asdict(self)
        d["rho_effective"] = self._coeffs()[0] / (1 << _FRAC_C)
        d["coherence_rounds"] = self.coherence_rounds
        d["energy_gated"] = self.gated
        return d

    # -- carry state ---------------------------------------------------------
    def init_state(self, n_clients: int, key) -> tuple:
        """Round-0 chain state ``(h [N, 2] int32 Q.14, battery [N] int32
        Q.16)``.

        ``h`` starts in the AR(1) stationary law CN(0, 1) — the same
        distribution as the i.i.d. per-round channel, so round statistics
        don't depend on ρ. ``key`` should be ``init_key(experiment_key)``
        so the chain never perturbs the round key chain. The state lives in
        the experiment carry (and in durable checkpoints); on the tiered
        path it stays host-resident."""
        h0 = self._innovation(key, n_clients)
        batt = jnp.full((n_clients,),
                        int(round(max(self.battery, 0.0) * (1 << _FRAC_B))),
                        jnp.int32)
        return h0, batt

    def _innovation(self, key, n: int):
        """One CN(0, 1) draw as int32 [n, 2] Q.14, from integer ops only.

        Per component: sum 24 uniform 22-bit words (Irwin–Hall — variance
        24·2^44/12 = 2·(2^22)² in Q.22), then an arithmetic shift to Q.14
        halves the variance to exactly (2^14)²/2, i.e. CN(0, 1) overall.
        No float op ever runs, so the draw is bit-identical in every
        compilation context."""
        u = jax.random.bits(key, (n, 2, _CLT_DRAWS), jnp.uint32)
        s = jnp.sum((u >> 10).astype(jnp.int32), axis=-1)
        s = s - jnp.int32(_CLT_DRAWS // 2 * (1 << 22))
        return (s + 256) >> 9

    def advance(self, key, h):
        """One AR(1) fading transition for ALL N clients. Pure in
        (key, h). ρ=0 returns the fresh draw ITSELF (the i.i.d. channel,
        bit-exactly, by construction); ρ>0 runs the Q.12×Q.14 integer
        mul-add — products stay below 2^31 (|h| clipped to <16), the shift
        back to Q.14 rounds half-up, and the result is clipped to the
        overflow guard."""
        w = self._innovation(key, h.shape[0])
        if self.rho == 0.0:
            return w
        rho_q, sigma_q = self._coeffs()
        nxt = (rho_q * h + sigma_q * w + (1 << (_FRAC_C - 1))) >> _FRAC_C
        return jnp.clip(nxt, -_H_CLIP, _H_CLIP)

    def step(self, key, state, idx, *, h_min: float,
             schedule: bool) -> tuple:
        """Advance the chain one round and realize the round's channel for
        the sampled cohort ``idx`` ([M] client ids).

        Returns ``(new_state, RoundChannel)``. The round sees the
        POST-advance fading (the channel during this round's uplink); a
        sampled client transmits iff it is scheduled (``schedule`` ⇒
        |h| ≥ h_min — the Sec. IV-A truncation, decided on the model's own
        correlated draw) AND its battery covers ``tx_cost``; transmitting
        clients are debited. Pure in (key, state, idx) and the static
        arguments — NO delta dependence — so the tiered host replay is
        bit-identical by construction (pinned by tests/test_channel.py).
        ``h_min``/``schedule`` come from the experiment config (the single
        source of truth shared with the Eq.-17 noise scale)."""
        h, batt = state
        h = self.advance(key, h)
        h_coh = h[idx]
        mask = jnp.ones(idx.shape, jnp.bool_)
        if schedule:
            # |h|² ≥ h_min² on EXACT integer magnitudes: components to
            # Q.10 (squares and their sum stay below 2^31), threshold from
            # the (possibly traced — dynamic sweep axis) h_min via
            # exactly-specified ops only
            r = h_coh >> (_FRAC_H - 10)
            mag = r[..., 0] * r[..., 0] + r[..., 1] * r[..., 1]
            h2 = jnp.square(jnp.asarray(h_min, jnp.float32))
            thresh = jnp.int32(jnp.round(h2 * jnp.float32(1 << _FRAC_M)))
            mask = mag >= thresh
        if self.gated:
            cost = jnp.int32(int(round(self.tx_cost * (1 << _FRAC_B))))
            mask = mask & (batt[idx] >= cost)
            # idx is a permutation prefix (unique ids), so the scatter-add
            # debits each transmitting client exactly once
            batt = batt.at[idx].add(jnp.where(mask, -cost, 0))
        return (h, batt), RoundChannel(model=self, h=_to_complex(h_coh),
                                       mask=mask)

    def replace(self, **kw) -> "ChannelModel":
        return dataclasses.replace(self, **kw)


class RoundChannel(NamedTuple):
    """One round's realized channel for the M sampled clients, handed to
    the round functions by ``sim.engine.make_round_step``. ``model``
    carries the static scenario parameters; ``h``/``mask`` are traced [M]
    arrays (the post-advance cohort fading and the transmit mask —
    scheduling ∧ battery)."""
    model: ChannelModel
    h: jnp.ndarray         # [M] complex64 cohort fading this round
    mask: jnp.ndarray      # [M] bool — client transmits this round

    @property
    def m_transmitting(self):
        return jnp.sum(self.mask.astype(jnp.float32))
