"""repro.sim — the fully-jitted federation simulation engine (DESIGN.md §9).

- ``store``  — device-resident ClientStore with in-jit participation and
  minibatch sampling.
- ``engine`` — one compiled lax.scan over R communication rounds (metrics
  ring buffer, in-scan eval, donated carry).
- ``shard``  — the round fanned out over a ``clients`` mesh axis.
- ``sweep``  — vmapped scenario grids (one jit per static shape group).
- ``faults`` — in-jit fault injection (availability chains, stragglers,
  corrupted uploads) + the server-side finite-guard (DESIGN.md §12).
- ``channel`` — the wireless scenario as a scanned process: time-correlated
  AR(1) flat fading and energy-gated participation (DESIGN.md §16).
- ``tiered`` — host-resident bucketed populations behind a cohort stream:
  only the sampled cohort (+ one prefetch buffer) touches the device,
  bitwise-identical to the resident engine (DESIGN.md §15).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import FedZOConfig
from repro.sim.engine import (ExperimentResult, experiment_key,
                              history, make_cohort_round_step,
                              make_experiment_fn, make_round_step,
                              run_experiment, stream_core)
from repro.sim.channel import ChannelModel, RoundChannel
from repro.sim.faults import DivergenceError, FaultModel, RoundFaults
from repro.sim.shard import make_clients_mesh, make_sharded_round
from repro.sim.store import (ClientStore, CohortBatch, build_store,
                             sample_batches, sample_cohort_batches,
                             sample_participants)
from repro.sim.sweep import run_sweep, scenario_grid
from repro.sim.tiered import (CohortStream, HostStore, build_host_store,
                              resolve_store, run_tiered_experiment)


def fast_sim_config(cfg: FedZOConfig) -> FedZOConfig:
    """The engine's fast execution strategy for a given experiment config:
    batched-direction local phases (one [b2, n_pad] block + one vmapped
    forward batch per iterate) and the rbg bit generator for the in-scan
    direction streams. Same algorithm and distributions — only the
    execution plan and PRNG stream layout change."""
    return dataclasses.replace(cfg, batch_directions=True,
                               direction_conv="block",
                               prng_impl="unsafe_rbg")
