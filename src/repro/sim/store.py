"""Device-resident federated client store with in-jit sampling.

The host server loop (fed/server.py) samples clients with host numpy and
stacks minibatches on the host every round — a host→device round trip that
stalls the compiled round engine. ``ClientStore`` moves the WHOLE federation
onto the device once: all N client datasets stacked into padded arrays with
per-client sizes, so both per-round draws happen inside jit:

- ``sample_participants`` — the M-of-N participation draw (Algorithm 1's
  uniform sampling) as a PRNG permutation prefix. No host sync,
  bit-reproducible from the experiment key chain.
- ``sample_batches`` — H minibatches of size b1 per sampled client, uniform
  with replacement over that client's OWN rows (the same distribution as
  the host ``data.synthetic.sample_local_batches``), gathered straight from
  the stacked arrays.

Padding rows are never sampled: the per-client ``randint`` upper bound is
the client's true size, so the pad region is dead weight only
(N · (cap − n_i) rows — bounded by the most uneven client split).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ClientStore(NamedTuple):
    """All N clients' data as stacked padded arrays (a pytree with leading
    [N, cap] axes) plus the true per-client row counts [N]."""
    data: Any
    sizes: jnp.ndarray

    @property
    def n_clients(self) -> int:
        return self.sizes.shape[0]

    @property
    def capacity(self) -> int:
        return jax.tree.leaves(self.data)[0].shape[1]


def build_store(clients) -> ClientStore:
    """Stack a list of per-client dataset pytrees (e.g. {"x": [n_i, ...],
    "y": [n_i]}) into one device-resident ClientStore, zero-padding every
    client to the largest row count."""
    if not clients:
        raise ValueError("build_store needs at least one client dataset")
    sizes = []
    for i, c in enumerate(clients):
        ns = {int(np.shape(l)[0]) for l in jax.tree.leaves(c)}
        if len(ns) != 1:
            raise ValueError(
                f"client {i} has leaves with mismatched row counts: {ns}")
        sizes.append(ns.pop())
    leaves0 = jax.tree.leaves(clients[0])
    for i, c in enumerate(clients[1:], start=1):
        for j, (l0, l) in enumerate(zip(leaves0, jax.tree.leaves(c))):
            d0, d = np.asarray(l0).dtype, np.asarray(l).dtype
            if d0 != d:
                raise ValueError(
                    f"client {i} leaf {j} has dtype {d} but client 0 has "
                    f"{d0} — stacking would silently cast; make the client "
                    f"datasets dtype-uniform")
    cap = max(sizes)

    def stack(*leaves):
        out = np.zeros((len(leaves), cap) + np.shape(leaves[0])[1:],
                       np.asarray(leaves[0]).dtype)
        for i, l in enumerate(leaves):
            out[i, :len(l)] = np.asarray(l)
        return jnp.asarray(out)

    return ClientStore(data=jax.tree.map(stack, *clients),
                       sizes=jnp.asarray(sizes, jnp.int32))


def sample_participants(key, n_clients: int, m: int):
    """Uniform M-of-N draw without replacement (paper Algorithm 1) as a
    PRNG permutation prefix — [m] int32 client ids, fully in-jit."""
    return jax.random.permutation(key, n_clients)[:m]


def sample_batches(store: ClientStore, idx, key, h: int, b1: int):
    """Gather [M, H, b1, ...] stacked minibatches for the sampled clients.

    Per client: (h, b1) row indices uniform with replacement over
    [0, sizes[i]) — the in-jit twin of the host ``sample_local_batches``
    (same distribution; the PRNG stream necessarily differs).
    """
    keys = jax.random.split(key, idx.shape[0])

    def one(i, k):
        rows = jax.random.randint(k, (h, b1), 0, store.sizes[i])
        return jax.tree.map(lambda l: l[i][rows], store.data)

    return jax.vmap(one)(idx, keys)
