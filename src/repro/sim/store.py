"""Device-resident federated client store with in-jit sampling.

The host server loop (fed/server.py) samples clients with host numpy and
stacks minibatches on the host every round — a host→device round trip that
stalls the compiled round engine. ``ClientStore`` moves the WHOLE federation
onto the device once: all N client datasets stacked into padded arrays with
per-client sizes, so both per-round draws happen inside jit:

- ``sample_participants`` — the M-of-N participation draw (Algorithm 1's
  uniform sampling) as a PRNG permutation prefix. No host sync,
  bit-reproducible from the experiment key chain.
- ``sample_batches`` — H minibatches of size b1 per sampled client, uniform
  with replacement over that client's OWN rows (the same distribution as
  the host ``data.synthetic.sample_local_batches``), gathered straight from
  the stacked arrays.

Padding rows are never sampled: the per-client ``randint`` upper bound is
the client's true size, so the pad region is dead weight only
(N · (cap − n_i) rows — bounded by the most uneven client split).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ClientStore(NamedTuple):
    """All N clients' data as stacked padded arrays (a pytree with leading
    [N, cap] axes) plus the true per-client row counts [N]."""
    data: Any
    sizes: jnp.ndarray

    @property
    def n_clients(self) -> int:
        return self.sizes.shape[0]

    @property
    def capacity(self) -> int:
        return jax.tree.leaves(self.data)[0].shape[1]


class CohortBatch(NamedTuple):
    """One round's staged cohort on the tiered path (sim/tiered.py): the M
    sampled clients' rows padded to the cohort's bucket capacity, plus
    their true sizes. In a segment stream every leaf carries an extra
    leading [S] rounds axis. ``avail`` is the host-replayed availability
    slice of a fault run; ``chan_h``/``chan_mask`` the host-replayed
    wireless-scenario realization (sim/channel.py) of a
    ``cfg.channel_model`` run. All three default None — no leaf, so the
    jit signature of runs without the optional processes is unchanged."""
    data: Any              # pytree, leaves [M, cap, ...]
    sizes: jnp.ndarray     # [M] int32 true row counts
    avail: Any = None      # [M] bool fault-chain slice, or None
    chan_h: Any = None     # [M] complex64 cohort fading slice, or None
    chan_mask: Any = None  # [M] bool transmit mask (sched ∧ battery), or None


def client_sizes(clients) -> list:
    """Validated per-client row counts for a list of client dataset
    pytrees — the shared front door of ``build_store`` and the tiered
    ``build_host_store`` (leaf row counts must agree within a client,
    dtypes must agree across clients)."""
    if not clients:
        raise ValueError("need at least one client dataset")
    sizes = []
    for i, c in enumerate(clients):
        ns = {int(np.shape(l)[0]) for l in jax.tree.leaves(c)}
        if len(ns) != 1:
            raise ValueError(
                f"client {i} has leaves with mismatched row counts: {ns}")
        sizes.append(ns.pop())
    leaves0 = jax.tree.leaves(clients[0])
    for i, c in enumerate(clients[1:], start=1):
        for j, (l0, l) in enumerate(zip(leaves0, jax.tree.leaves(c))):
            d0, d = np.asarray(l0).dtype, np.asarray(l).dtype
            if d0 != d:
                raise ValueError(
                    f"client {i} leaf {j} has dtype {d} but client 0 has "
                    f"{d0} — stacking would silently cast; make the client "
                    f"datasets dtype-uniform")
    return sizes


def stack_padded(leaves, cap: int) -> np.ndarray:
    """Stack ragged per-client leaves into ONE preallocated
    ``[len(leaves), cap, ...]`` zero-padded host buffer. Rows are copied
    straight into the buffer, so peak host memory is exactly the padded
    layout (pad bytes = Σ(cap − n_i)·row_bytes) — never a transient list
    of N individually padded copies."""
    head = np.asarray(leaves[0])
    out = np.zeros((len(leaves), cap) + head.shape[1:], head.dtype)
    for i, l in enumerate(leaves):
        out[i, :len(l)] = np.asarray(l)
    return out


def build_store(clients) -> ClientStore:
    """Stack a list of per-client dataset pytrees (e.g. {"x": [n_i, ...],
    "y": [n_i]}) into one device-resident ClientStore, zero-padding every
    client to the largest row count. Each leaf is assembled in a single
    preallocated host buffer and crosses to the device in ONE
    ``jax.device_put`` (a regression test pins both)."""
    sizes = client_sizes(clients)
    cap = max(sizes)

    def stack(*leaves):
        return jax.device_put(stack_padded(leaves, cap))

    return ClientStore(data=jax.tree.map(stack, *clients),
                       sizes=jnp.asarray(sizes, jnp.int32))


def sample_participants(key, n_clients: int, m: int):
    """Uniform M-of-N draw without replacement (paper Algorithm 1) as a
    PRNG permutation prefix — [m] int32 client ids, fully in-jit."""
    return jax.random.permutation(key, n_clients)[:m]


def sample_cohort_batches(data, sizes, key, h: int, b1: int):
    """Gather [M, H, b1, ...] stacked minibatches from an ALREADY-GATHERED
    cohort: ``data`` leaves [M, cap, ...], ``sizes`` [M] true row counts.

    The streamed-cohort twin of ``sample_batches`` and bit-identical to it
    on the same draw: the per-client key fan-out and randint bound depend
    only on ``key`` and the client's true size — never on the cohort's
    padded capacity — so a bucket-padded staged cohort samples the exact
    rows the full-capacity resident store would (pad rows are unreachable
    either way)."""
    keys = jax.random.split(key, sizes.shape[0])

    def one(d, n, k):
        rows = jax.random.randint(k, (h, b1), 0, n)
        return jax.tree.map(lambda l: l[rows], d)

    return jax.vmap(one)(data, sizes, keys)


def sample_batches(store: ClientStore, idx, key, h: int, b1: int):
    """Gather [M, H, b1, ...] stacked minibatches for the sampled clients.

    Per client: (h, b1) row indices uniform with replacement over
    [0, sizes[i]) — the in-jit twin of the host ``sample_local_batches``
    (same distribution; the PRNG stream necessarily differs). Delegates to
    ``sample_cohort_batches`` over the gathered cohort, so the resident
    and tiered paths share one sampling derivation."""
    cohort = jax.tree.map(lambda l: l[idx], store.data)
    return sample_cohort_batches(cohort, store.sizes[idx], key, h, b1)
