"""In-jit fault injection + graceful degradation (DESIGN.md §12).

FedZO's convergence story (paper Sec. IV) covers partial participation and
channel-induced client masking; real federations additionally lose clients
to time-correlated outages, deadlines, and corrupted uploads. This module
makes those processes first-class citizens of the compiled round:

- **Time-correlated availability** — each of the N clients carries a
  Gilbert–Elliott up/down Markov chain through the experiment carry
  (up→down w.p. ``p_fail``, down→up w.p. ``p_recover`` per round); a
  sampled client in the down state never uploads. Stationary up-fraction
  is ``p_recover / (p_fail + p_recover)`` (pinned by a property test).
- **Stragglers** — per-round exponential latency draws; a sampled client
  whose latency exceeds ``deadline`` misses the aggregation window and is
  masked out (``m_effective`` reports the surviving cohort).
- **Corrupted uploads** — with probability ``p_corrupt`` a client's delta
  arrives poisoned: all-NaN, all-Inf, or scaled garbage (``corrupt_mode``).
- **Finite-guard** — the server-side defense: per-client deltas that are
  non-finite (or norm-exploded beyond ``guard_norm``) are zeroed and masked
  *before* aggregation, so one poisoned client cannot NaN the global model.
  With the guard on, a poisoned client is bit-identical to the same client
  channel-masked; with it off, the poison propagates (the failure mode the
  guard exists for).

All fault masks compose with channel-truncation scheduling and size
weighting through the one shared ``aircomp.mask_stats`` convention, on
every aggregation path (pytree / flat / wide / AirComp / sharded). An
all-faulted round degenerates to a zero update exactly like an all-masked
channel round (clamped divisor, zero Δ_max → zero noise).

The per-round key for the fault processes is the 6th stream of the round
key chain (``sim.engine.round_keys``); a fault-free run keeps the original
5-way split, so existing trajectories (and the golden fixtures) are
untouched.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


class DivergenceError(RuntimeError):
    """A run diverged (non-finite params or metrics) and stayed divergent
    through the bounded lr-backoff retries. Carries the structured context
    a driver needs to report or escalate."""

    def __init__(self, round_idx: int, retries: int, lr: float,
                 detail: str = ""):
        self.round = int(round_idx)
        self.retries = int(retries)
        self.lr = float(lr)
        msg = (f"experiment diverged at round {round_idx} and stayed "
               f"divergent after {retries} lr-backoff retries "
               f"(last lr={lr:g})")
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


def _row_sq_norms_tree(deltas):
    """‖Δ_i‖² over stacked pytree deltas (leading M axis) -> [M] f32."""
    leaves = jax.tree.leaves(deltas)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32)),
                       axis=tuple(range(1, l.ndim))) for l in leaves)


def _bcast(mask, leaf):
    """Reshape an [M] mask to broadcast over a leading-M leaf."""
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1))


@dataclass(frozen=True)
class FaultModel:
    """Static fault-process configuration (hashable — safe to close over in
    jitted programs). All processes default OFF; the finite-guard defaults
    ON because injection without the guard exists only to demonstrate the
    failure mode."""
    # Gilbert–Elliott availability chain (per client, per round)
    p_fail: float = 0.0        # up → down transition probability
    p_recover: float = 1.0     # down → up transition probability
    # straggler process: latency ~ Exponential(mean=straggler_mean); a
    # sampled client with latency > deadline misses the round. 0 disables.
    deadline: float = 0.0
    straggler_mean: float = 1.0
    # corrupted uploads
    p_corrupt: float = 0.0
    corrupt_mode: str = "nan"  # nan | inf | scale
    corrupt_scale: float = 1e8
    # server-side finite-guard: zero+mask non-finite (and, with
    # guard_norm > 0, norm-exploded) client deltas before aggregation
    guard: bool = True
    guard_norm: float = 0.0    # >0: additionally mask rows with ‖Δ‖ > this

    def __post_init__(self):
        if self.corrupt_mode not in ("nan", "inf", "scale"):
            raise ValueError(f"corrupt_mode must be nan|inf|scale, got "
                             f"{self.corrupt_mode!r}")
        for name in ("p_fail", "p_recover", "p_corrupt"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} is not a probability")

    @property
    def stationary_up(self) -> float:
        """Stationary availability of the Gilbert–Elliott chain."""
        denom = self.p_fail + self.p_recover
        return 1.0 if denom == 0 else self.p_recover / denom

    def describe(self) -> dict:
        """The fault-process configuration as a plain-JSON manifest block
        (obs/manifest.py), with the derived stationary availability so a
        manifest reader sees the expected up-fraction at a glance."""
        d = dataclasses.asdict(self)
        d["stationary_up"] = self.stationary_up
        return d

    # -- carry state ---------------------------------------------------------
    def init_state(self, n_clients: int):
        """Round-0 availability state: every client up. [N] bool, lives in
        the experiment carry (and in durable checkpoints)."""
        return jnp.ones((n_clients,), jnp.bool_)

    def advance(self, k_avail, state):
        """One Gilbert–Elliott transition for ALL N clients: ``state`` [N]
        bool → next-round availability [N] bool. Pure in (key, state), so
        the tiered ``CohortStream`` can replay the chain on the HOST with
        the same ``k_avail`` the in-carry path would draw — bit-identical
        by construction (pinned by tests/test_tiered.py)."""
        u = jax.random.uniform(k_avail, state.shape)
        return jnp.where(state, u >= self.p_fail, u < self.p_recover)

    def _realize(self, k_lat, k_corr, mask) -> "RoundFaults":
        """Straggler + corruption draws for a cohort whose availability
        slice ``mask`` [M] is already known — the tail shared by ``step``
        (in-carry) and ``realize`` (streamed-cohort), so the two paths
        cannot drift."""
        m = mask.shape[0]
        if self.deadline > 0:
            lat = jax.random.exponential(k_lat, (m,)) * self.straggler_mean
            mask = mask & (lat <= self.deadline)
        if self.p_corrupt > 0:
            corrupt = jax.random.uniform(k_corr, (m,)) < self.p_corrupt
        else:
            corrupt = jnp.zeros((m,), jnp.bool_)
        return RoundFaults(model=self, mask=mask, corrupt=corrupt)

    def step(self, key, state, idx) -> tuple:
        """Advance the chain one round and realize this round's faults for
        the sampled cohort ``idx`` ([M] client ids).

        Returns ``(new_state [N] bool, RoundFaults)``. Fully traceable; the
        same derivation runs in the scan engine and the host loop, so the
        two stay bitwise-identical under faults.
        """
        k_avail, k_lat, k_corr = jax.random.split(key, 3)
        up = self.advance(k_avail, state)
        return up, self._realize(k_lat, k_corr, up[idx])

    def realize(self, key, avail) -> "RoundFaults":
        """Realize one round's faults from a PRE-COMPUTED availability
        slice ``avail`` [M] bool (the tiered path: the [N] chain advanced
        host-side in the CohortStream replay, ``avail = up[idx]``). Splits
        the SAME 3-way chain as ``step`` and leaves the availability
        stream unconsumed, so the straggler/corruption draws are
        bit-identical to the in-carry derivation."""
        _, k_lat, k_corr = jax.random.split(key, 3)
        return self._realize(k_lat, k_corr, avail)

    # -- delta scrubbing (shared by every aggregation path) ------------------
    def _poisoned(self, leaf):
        if self.corrupt_mode == "scale":
            return leaf * jnp.asarray(self.corrupt_scale, leaf.dtype)
        fill = jnp.nan if self.corrupt_mode == "nan" else jnp.inf
        return jnp.full_like(leaf, fill)

    def scrub(self, deltas, mask, corrupt):
        """Corrupt-then-guard a flat [m, n] delta matrix.

        Applies the in-flight corruption to the flagged rows, then (guard
        on) zeroes and masks rows that arrive non-finite or norm-exploded.
        Returns ``(clean_deltas, ok [m] bool)`` where ``ok`` is the
        surviving-row mask (availability ∧ deadline ∧ guard) and every
        non-surviving row is exactly zero — so masked aggregation over the
        survivors is bit-identical to the same round with those clients
        channel-masked. Row-local (no cross-row reductions), so the sharded
        round can run it per device shard.
        """
        if self.p_corrupt > 0:
            deltas = jnp.where(corrupt[:, None], self._poisoned(deltas),
                               deltas)
        ok = mask
        if self.guard:
            sq = jnp.sum(jnp.square(deltas.astype(jnp.float32)), axis=1)
            good = jnp.isfinite(sq)
            if self.guard_norm > 0:
                good = good & (sq <= jnp.float32(self.guard_norm) ** 2)
            ok = ok & good
        deltas = jnp.where(ok[:, None], deltas, jnp.zeros_like(deltas))
        return deltas, ok

    def scrub_tree(self, deltas, mask, corrupt):
        """``scrub`` for stacked pytree deltas (leading [M] axes)."""
        if self.p_corrupt > 0:
            deltas = jax.tree.map(
                lambda l: jnp.where(_bcast(corrupt, l), self._poisoned(l),
                                    l), deltas)
        ok = mask
        if self.guard:
            sq = _row_sq_norms_tree(deltas)
            good = jnp.isfinite(sq)
            if self.guard_norm > 0:
                good = good & (sq <= jnp.float32(self.guard_norm) ** 2)
            ok = ok & good
        deltas = jax.tree.map(
            lambda l: jnp.where(_bcast(ok, l), l, jnp.zeros_like(l)), deltas)
        return deltas, ok

    def replace(self, **kw) -> "FaultModel":
        return dataclasses.replace(self, **kw)


class RoundFaults(NamedTuple):
    """One round's realized faults for the M sampled clients, handed to the
    round functions by ``sim.engine.make_round_step``. ``model`` carries the
    static scrub parameters; ``mask``/``corrupt`` are traced [M] arrays."""
    model: FaultModel
    mask: jnp.ndarray      # [M] bool — client reachable (up ∧ met deadline)
    corrupt: jnp.ndarray   # [M] bool — upload poisoned in flight

    def apply_flat(self, deltas):
        """Scrub a flat [M, n_pad] delta matrix -> (deltas, ok [M])."""
        return self.model.scrub(deltas, self.mask, self.corrupt)

    def apply_tree(self, deltas):
        """Scrub stacked pytree deltas -> (deltas, ok [M])."""
        return self.model.scrub_tree(deltas, self.mask, self.corrupt)

    @property
    def n_corrupt(self):
        return jnp.sum(self.corrupt.astype(jnp.float32))
