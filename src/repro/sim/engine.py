"""Fully-jitted multi-round federation engine (DESIGN.md §9).

One compiled program runs an ENTIRE experiment: ``lax.scan`` over R
communication rounds, each round drawing its participants and minibatches
from the device-resident ``ClientStore`` (sim/store.py), running the
round of the resolved ``AlgoStrategy`` (core/strategy.py — FedZO, FedAvg,
ZO-FedProx, ZO-FedDyn, ZO-SCAFFOLD; momentum, strategy state, and channel
scheduling threaded through the carry), and writing its scalar metrics
into a fixed-shape ring buffer. Evaluation runs in-scan every k rounds
behind a ``lax.cond``. The host syncs exactly once, after all R rounds.

Key-chain protocol (shared with ``FedServer.run_round`` on the store path,
so R in-jit rounds bit-match R host-driven rounds):

    key, k_part, k_batch, k_zo, k_chan = split(key, 5)      # per round

``k_part`` draws the M-of-N participation permutation, ``k_batch`` the
local minibatches, ``k_zo`` the M per-client ZO keys, ``k_chan`` the
channel realization. The chain starts at ``key(cfg.seed, impl=
cfg.prng_impl)`` so a whole experiment is bit-reproducible from the config.
With a ``FaultModel`` attached the split widens to 6 and the extra
``k_fault`` stream drives the availability/straggler/corruption draws; a
``cfg.channel_model`` (sim/channel.py) widens it once more and the last
stream ``k_chanm`` advances the wireless-scenario chain (``split_round_
keys`` is the single source of truth). Runs without the optional
processes keep their exact narrower chains, so existing trajectories (and
the golden fixtures) are untouched. Strategies draw nothing of their own:
their state updates are deterministic functions of the round, so switching
strategy never perturbs the chain.

Donation: the jitted program donates params, momentum, key, and strategy
state, so at steady state the engine updates the model in place — no
per-round host↔device traffic and no double-buffered parameter copies.

Durability (DESIGN.md §12): ``run_experiment(..., checkpoint_every=k,
checkpoint_dir=...)`` runs the same scan in k-round segments, paying ONE
host sync + one atomic snapshot of the full carry (params, momentum, key,
fault state, strategy state, metrics ring, eval buffer, round index) per
segment. A run killed between segments resumes bit-exactly
(``resume=True``), and the per-segment sync doubles as the divergence
guard: a non-finite carry rolls back to the last good snapshot with lr
backoff, bounded by ``max_retries``.

Observability (DESIGN.md §14): ``run_experiment(..., sink=obs.JsonlSink(p),
tap_every=k)`` streams every k-th round's metrics to the sink LIVE from
inside the compiled scan (an unordered ``io_callback`` behind a
``lax.cond``, so non-tap rounds pay nothing); the default ``tap_every=None``
never enters the trace and keeps the one-host-sync property bit-identical
to the golden fixtures. ``tracer=obs.Tracer(...)`` records nested
compile/execute (or per-segment) spans — compile reported exactly once per
static shape — and optionally drops a ``jax.profiler`` trace. Every result
carries an ``obs.CommsLedger`` (``history()`` rows gain per-round
wire/dense bytes and cumulative uplink/downlink totals), and runs with a
checkpoint dir or a file-backed sink emit a run manifest beside their
artifacts.
"""
from __future__ import annotations

import dataclasses
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from repro.configs.base import FedZOConfig
from repro.core import aircomp
from repro.core import strategy as strategy_mod
from repro.core.strategy import _static_positive  # noqa: F401  (re-export)
from repro.obs import manifest as obs_manifest
from repro.obs.ledger import CommsLedger
from repro.obs.taps import RoundTap
from repro.sim import channel as channel_lib
from repro.sim.channel import RoundChannel
from repro.sim.faults import DivergenceError, FaultModel
from repro.sim.store import (ClientStore, CohortBatch, sample_batches,
                             sample_cohort_batches, sample_participants)
from repro.utils.tree import tree_zeros_like


def round_keys(key):
    """(next_carry_key, k_participation, k_batches, k_zo, k_channel)."""
    ks = jax.random.split(key, 5)
    return ks[0], ks[1], ks[2], ks[3], ks[4]


def split_round_keys(key, *, faults: bool = False, channel: bool = False):
    """The per-round key split, widened by the optional extra processes:
    ``(key', k_part, k_batch, k_zo, k_chan, k_fault, k_chanm)`` with
    ``k_fault`` / ``k_chanm`` None when faults / the channel model are off.

    THE single source of truth for the widening order (fault stream first,
    channel-chain stream last), shared by the resident step, the cohort
    step, and the tiered ``CohortStream``'s host replay. A run without the
    optional processes keeps the exact narrower split — base runs the
    5-way ``round_keys`` chain, faults-only runs the historical 6-way one —
    so attaching a ``ChannelModel`` to a config never perturbs existing
    trajectories (the golden fixtures pin this)."""
    n = 5 + int(faults) + int(channel)
    ks = jax.random.split(key, n)
    k_fault = ks[5] if faults else None
    k_chanm = ks[5 + int(faults)] if channel else None
    return ks[0], ks[1], ks[2], ks[3], ks[4], k_fault, k_chanm


def experiment_key(cfg: FedZOConfig):
    """Round-0 carry key of an experiment: the one derivation both the
    engine and the FedServer store path start from."""
    return jax.random.key(cfg.seed, impl=cfg.prng_impl)


def _resolve(strategy, algo, cfg) -> strategy_mod.AlgoStrategy:
    return strategy_mod.resolve(strategy, algo, cfg)


def make_round_step(loss_fn, cfg: FedZOConfig, *, algo: Optional[str] = None,
                    strategy=None, round_fn=None,
                    faults: Optional[FaultModel] = None) -> Callable:
    """One full communication round as a pure function
    ``step((params, momentum, key, fstate, cstate, zstate), store) ->
    ((params', momentum', key', fstate', cstate', zstate'), metrics)``.

    THE round unit shared by the scan engine and by
    ``FedServer.run_round`` on the store path — sharing it is what makes
    the two trajectories bit-identical (under faults too: the fault draws
    hang off the same carried key chain). The algorithm comes from the
    strategy registry (``strategy=`` a name or ``AlgoStrategy``; the
    ``algo=`` string is a deprecated alias; default ``cfg.strategy``).
    ``round_fn`` optionally replaces ``fedzo.round_simulated`` with a
    signature-compatible deployment (the clients-axis shard_map round of
    sim/shard.py) — only for strategies without hooks. ``fstate`` is the
    fault carry (the [N] Gilbert–Elliott availability states), ``cstate``
    the wireless-scenario carry of ``cfg.channel_model`` (the [N] AR(1)
    fading chain + [N] batteries, sim/channel.py — its ``step`` realizes
    the round's ``RoundChannel`` and the transmit mask), ``zstate`` the
    strategy carry ({"client": [N, ...], "server": ...} pytree for the
    stateful strategies); all None when unused.
    """
    strat = _resolve(strategy, algo, cfg)
    strat.validate(cfg)
    if round_fn is not None and not strat.supports_round_fn:
        raise ValueError(
            f"strategy {strat.name!r} wraps the local phase with loss/state "
            f"hooks that a custom round_fn (the sharded round) cannot carry "
            f"— run it through the default fedzo round")
    weigh = cfg.weight_by_size
    channel = cfg.channel_model

    def step(state, store: ClientStore):
        params, momentum, key, fstate, cstate, zstate = state
        key, k_part, k_batch, k_zo, k_chan, k_fault, k_chanm = \
            split_round_keys(key, faults=faults is not None,
                             channel=channel is not None)
        idx = sample_participants(k_part, store.n_clients,
                                  cfg.n_participating)
        batches = sample_batches(store, idx, k_batch, cfg.local_iters,
                                 cfg.b1)
        # FedAvg-style n_i/n weights of the sampled clients (mean-1
        # normalized); only added to the round call when enabled so custom
        # round_fns without a weights kwarg keep working — the per-round
        # fault realization and channel realization ride the same pattern
        wkw = ({"weights": aircomp.size_weights(store.sizes[idx])}
               if weigh else {})
        if faults is not None:
            fstate, inj = faults.step(k_fault, fstate, idx)
            wkw["faults"] = inj
        if channel is not None:
            cstate, wkw["channel"] = channel.step(
                k_chanm, cstate, idx, h_min=cfg.h_min,
                schedule=cfg.channel_schedule)
        params, metrics, momentum, zstate = strat.run_round(
            loss_fn, params, batches, k_zo, cfg, channel_rng=k_chan,
            momentum=momentum, zstate=zstate, idx=idx, round_fn=round_fn,
            **wkw)
        return (params, momentum, key, fstate, cstate, zstate), metrics

    return step


def make_cohort_round_step(loss_fn, cfg: FedZOConfig, *,
                           algo: Optional[str] = None, strategy=None,
                           round_fn=None,
                           faults: Optional[FaultModel] = None) -> Callable:
    """One communication round as a function of a STAGED cohort instead of
    a device-resident store: ``step((params, momentum, key, zstate),
    CohortBatch) -> ((params', momentum', key', zstate'), metrics)``.

    The tiered twin of ``make_round_step`` (DESIGN.md §15). Bit-equality
    with the resident round is by construction:

    - the round walks the SAME per-round key chain (5-way split, widened
      by faults / channel) but leaves ``k_part`` unconsumed — the host
      ``CohortStream`` already spent its replica choosing which clients
      were staged — and the chain depends only on the splits, never on
      consumption;
    - minibatches come from ``sample_cohort_batches`` over the staged
      rows and TRUE sizes, the same randint draws and exact gathers the
      resident ``sample_batches`` performs;
    - faults use ``FaultModel.realize`` on the host-replayed availability
      slice (``CohortBatch.avail``), splitting the same 3-way fault chain;
    - the wireless channel (``cfg.channel_model``) is host-replayed
      WHOLLY: the chain's ``step`` is pure in (key, state, idx), so the
      stream stages the realized cohort fading + transmit mask
      (``CohortBatch.chan_h`` / ``chan_mask``) and the in-trace round
      leaves ``k_chanm`` unconsumed like ``k_part``;
    - ``zstate`` is cohort-shaped ({"client": [M, ...], "server": ...})
      and ``idx = arange(M)``, so the stateful strategies' gather/scatter
      hooks run unmodified as identity permutations — the [N] master
      lives on the host and is sliced/scattered around the trace.
    """
    strat = _resolve(strategy, algo, cfg)
    strat.validate(cfg)
    if round_fn is not None and not strat.supports_round_fn:
        raise ValueError(
            f"strategy {strat.name!r} wraps the local phase with loss/state "
            f"hooks that a custom round_fn (the sharded round) cannot carry "
            f"— run it through the default fedzo round")
    weigh = cfg.weight_by_size
    channel = cfg.channel_model

    def step(state, cohort: CohortBatch):
        params, momentum, key, zstate = state
        key, k_part, k_batch, k_zo, k_chan, k_fault, k_chanm = \
            split_round_keys(key, faults=faults is not None,
                             channel=channel is not None)
        del k_part, k_chanm  # consumed host-side by the CohortStream replay
        batches = sample_cohort_batches(cohort.data, cohort.sizes, k_batch,
                                        cfg.local_iters, cfg.b1)
        # cohort.sizes IS store.sizes[idx] (staged by the stream), so the
        # weights match the resident round bit-for-bit
        wkw = ({"weights": aircomp.size_weights(cohort.sizes)}
               if weigh else {})
        if faults is not None:
            wkw["faults"] = faults.realize(k_fault, cohort.avail)
        if channel is not None:
            wkw["channel"] = RoundChannel(model=channel, h=cohort.chan_h,
                                          mask=cohort.chan_mask)
        idx = jnp.arange(cohort.sizes.shape[0], dtype=jnp.int32)
        params, metrics, momentum, zstate = strat.run_round(
            loss_fn, params, batches, k_zo, cfg, channel_rng=k_chan,
            momentum=momentum, zstate=zstate, idx=idx, round_fn=round_fn,
            **wkw)
        return (params, momentum, key, zstate), metrics

    return step


@dataclass
class ExperimentResult:
    """Host-side container for one engine run. ``metrics`` holds the ring
    buffer (dict of [ring_size] arrays, slot = round % ring_size);
    ``evals`` the in-scan eval outputs (dict of [n_evals] arrays), one slot
    per eval round in ``eval_rounds``. ``fault_state`` carries the final
    [N] availability states when a ``FaultModel`` was attached;
    ``channel_state`` the final wireless-scenario carry (the [N] fading
    chain + [N] batteries) when ``cfg.channel_model`` is set; ``events``
    holds structured host-side rows (divergence rollbacks); ``strategy``
    the algorithm name and ``strategy_state`` its final carry (the stacked
    per-client controls/duals + server control for scaffold/feddyn).
    ``ledger`` is the run's ``obs.CommsLedger`` (``history()`` rows get the
    byte columns from it) and ``manifest`` the emitted run-manifest dict
    (None when the run had nowhere to write one). Tiered runs
    (sim/tiered.py) additionally fill ``staging`` (round -> {bucket_id,
    staged_bytes}, merged into ``history()`` rows) and ``prefetch`` (the
    stream's stall/byte accounting)."""
    params: Any
    momentum: Any
    key: Any
    metrics: dict
    evals: dict
    rounds: int
    ring_size: int
    eval_rounds: np.ndarray
    fault_state: Any = None
    channel_state: Any = None
    events: list = field(default_factory=list)
    strategy: str = "fedzo"
    strategy_state: Any = None
    ledger: Any = None
    manifest: Any = None
    staging: Any = None
    prefetch: Any = None

    def recorded_rounds(self) -> np.ndarray:
        """Round numbers still present in the ring, oldest→newest."""
        start = max(0, self.rounds - self.ring_size)
        return np.arange(start, self.rounds)

    def history(self, *, start_round: int = 0) -> list:
        """Per-round history rows (see the module-level ``history``)."""
        return history(self, start_round=start_round)


def _zero_buffers(step, state0, x0, *, eval_fn, params, ring_alloc, n_evals):
    """Zero-initialized metrics ring + eval buffer with the dtypes the
    round step / eval_fn will write — via ``jax.eval_shape`` over an
    example round input ``x0`` (the store, or a ``CohortBatch`` of
    ``ShapeDtypeStruct``s on the tiered path), so nothing is executed.
    Shared by the single-shot scan, the segment runner, and the tiered
    stream (the buffers must be identical for chunked ≡ single-shot ≡
    tiered bit-equality)."""
    m_shapes = jax.eval_shape(lambda s, x: step(s, x)[1], state0, x0)
    ring0 = {k: jnp.zeros((ring_alloc,), v.dtype)
             for k, v in m_shapes.items()}
    if eval_fn is not None and n_evals:
        e_shapes = jax.eval_shape(eval_fn, params)
        ebuf0 = {k: jnp.zeros((n_evals,), v.dtype)
                 for k, v in e_shapes.items()}
    else:
        ebuf0 = {}
    return ring0, ebuf0


def _scan_rounds(step, state0, ring, ebuf, ts, xs=None, *, ring_alloc,
                 eval_fn=None, eval_every: int = 0,
                 tap: Optional[RoundTap] = None):
    """The engine's inner per-round loop, shared by the store-resident
    ``experiment_core`` (``xs=None`` — the step closes over the store) and
    the tiered ``stream_core`` (``xs`` = the staged cohort stream, leaves
    [len(ts), ...]): scan ``step`` over the global round indices ``ts``,
    ring-buffer each round's metrics (slot = t % ring_alloc), fire the tap
    and the in-scan eval behind their ``lax.cond``s. One loop body means
    the two tiers cannot drift in ring/tap/eval semantics."""
    do_eval = eval_fn is not None and eval_every > 0

    def body(carry, inp):
        state, ring, ebuf = carry
        t, x = inp
        state, metrics = step(state, x)
        slot = jnp.mod(t, ring_alloc)
        ring = {k: ring[k].at[slot].set(metrics[k].astype(ring[k].dtype))
                for k in ring}
        if tap is not None:
            # unordered: ordered io_callbacks are unsupported under cond,
            # and every row carries its round index anyway (obs/taps.py)
            def _emit(args):
                io_callback(tap.emit, None, args[0], args[1], ordered=False)
                return jnp.int32(0)

            jax.lax.cond(jnp.mod(t, tap.every) == 0, _emit,
                         lambda args: jnp.int32(0), (t, metrics))
        if do_eval:
            def run_eval(args):
                buf, p = args
                vals = eval_fn(p)
                return {k: buf[k].at[t // eval_every].set(
                    vals[k].astype(buf[k].dtype)) for k in buf}

            ebuf = jax.lax.cond(jnp.mod(t, eval_every) == 0, run_eval,
                                lambda args: args[0], (ebuf, state[0]))
        return (state, ring, ebuf), None

    (state, ring, ebuf), _ = jax.lax.scan(body, (state0, ring, ebuf),
                                          (ts, xs))
    return state, ring, ebuf


def experiment_core(loss_fn, params, store: ClientStore, cfg: FedZOConfig,
                    rounds: int, key, momentum=None, *,
                    algo: Optional[str] = None, strategy=None, zstate=None,
                    eval_fn=None, eval_every: int = 0, ring_size: int = 0,
                    round_fn=None, faults: Optional[FaultModel] = None,
                    fault_state=None, channel_state=None, t0=0,
                    total_rounds: int = 0,
                    ring=None, ebuf=None, tap: Optional[RoundTap] = None):
    """The traceable experiment body: scan ``rounds`` round steps, ring-
    buffer the metrics, eval in-scan every ``eval_every`` rounds. Returns
    (params, momentum, key, fault_state, channel_state, zstate,
    metrics_ring, evals). Un-jitted so sweeps can vmap it over a stacked
    config axis (sim/sweep.py). ``channel_state`` is the wireless-scenario
    carry — required (``ChannelModel.init_state``) when
    ``cfg.channel_model`` is set.

    Segment mode (the checkpointed runner): ``t0``/``total_rounds`` place
    this scan as rounds [t0, t0+rounds) of a ``total_rounds``-round
    experiment — the ring/eval buffers are sized (and slotted) against the
    TOTAL, and partially-filled buffers are threaded back in via
    ``ring``/``ebuf``, so k-round segments write exactly the cells the
    uninterrupted scan would.

    ``tap`` (an ``obs.RoundTap``) streams the metrics of rounds where
    ``t % tap.every == 0`` to the tap's sink live, via an unordered
    ``io_callback`` behind a ``lax.cond``; ``tap=None`` (default) adds
    NOTHING to the trace, preserving the one-host-sync bit-exact program."""
    strat = _resolve(strategy, algo, cfg)
    total = total_rounds or rounds
    ring_alloc = min(total, ring_size) if ring_size else total
    step = make_round_step(loss_fn, cfg, strategy=strat, round_fn=round_fn,
                           faults=faults)
    do_eval = eval_fn is not None and eval_every > 0
    n_evals = (total + eval_every - 1) // eval_every if do_eval else 0

    state0 = (params, momentum, key, fault_state, channel_state, zstate)
    if ring is None or (do_eval and ebuf is None):
        ring0, ebuf0 = _zero_buffers(
            step, state0, store, eval_fn=eval_fn, params=params,
            ring_alloc=ring_alloc, n_evals=n_evals)
        ring = ring0 if ring is None else ring
        ebuf = ebuf0 if ebuf is None else ebuf
    elif ebuf is None:
        ebuf = {}

    ts = jnp.arange(rounds)
    if not (isinstance(t0, int) and t0 == 0):
        ts = ts + t0
    state, ring, ebuf = _scan_rounds(
        lambda s, _: step(s, store), state0, ring, ebuf, ts,
        ring_alloc=ring_alloc, eval_fn=eval_fn, eval_every=eval_every,
        tap=tap)
    params, momentum, key, fault_state, channel_state, zstate = state
    return (params, momentum, key, fault_state, channel_state, zstate,
            ring, ebuf)


def stream_core(loss_fn, params, cfg: FedZOConfig, key, momentum, *,
                strategy=None, zstate=None, xs: CohortBatch, t0,
                total_rounds: int, ring, ebuf, eval_fn=None,
                eval_every: int = 0, ring_size: int = 0, round_fn=None,
                faults: Optional[FaultModel] = None,
                tap: Optional[RoundTap] = None):
    """The traceable tiered-segment body (DESIGN.md §15): scan one
    ``make_cohort_round_step`` per staged round over the cohort stream
    ``xs`` (a ``CohortBatch`` whose leaves carry a leading [S] rounds
    axis). The segment covers global rounds [t0, t0+S) of a
    ``total_rounds``-round experiment; ring/eval buffers are sized and
    slotted against the TOTAL and threaded through, exactly like
    ``experiment_core``'s segment mode — the loop body IS
    ``_scan_rounds``, shared with the resident tier.

    Returns (params, momentum, key, zstate, ring, ebuf). The fault [N]
    chain and the stateful strategies' [N] client masters do NOT appear
    here — the stream host-replays the former into ``xs.avail`` and
    slices the latter into the cohort-shaped ``zstate``."""
    strat = _resolve(strategy, None, cfg)
    seg = xs.sizes.shape[0]
    ring_alloc = min(total_rounds, ring_size) if ring_size else total_rounds
    step = make_cohort_round_step(loss_fn, cfg, strategy=strat,
                                  round_fn=round_fn, faults=faults)
    state0 = (params, momentum, key, zstate)
    ts = jnp.arange(seg) + t0
    state, ring, ebuf = _scan_rounds(
        step, state0, ring, ebuf, ts, xs, ring_alloc=ring_alloc,
        eval_fn=eval_fn, eval_every=eval_every, tap=tap)
    params, momentum, key, zstate = state
    return params, momentum, key, zstate, ring, ebuf


def make_experiment_fn(loss_fn, cfg: FedZOConfig, rounds: int, *,
                       algo: Optional[str] = None, strategy=None,
                       eval_fn=None, eval_every: int = 0,
                       ring_size: int = 0, round_fn=None, faults=None,
                       donate: bool = True, tap=None) -> Callable:
    """Compile the whole experiment once: returns a jitted
    ``fn(params, momentum, key, fstate, cstate, zstate, store) ->
    (params', momentum', key', fstate', cstate', zstate', metrics_ring,
    evals)`` with the carry donated (pass ``momentum=None`` when
    cfg.server_momentum is 0, ``fstate=None`` without a fault model,
    ``cstate=None`` without ``cfg.channel_model``, and ``zstate=None`` for
    the stateless strategies). ``tap`` attaches an in-scan
    ``obs.RoundTap``."""
    strat = _resolve(strategy, algo, cfg)

    def fn(params, momentum, key, fstate, cstate, zstate, store):
        return experiment_core(loss_fn, params, store, cfg, rounds, key,
                               momentum, strategy=strat, zstate=zstate,
                               eval_fn=eval_fn, eval_every=eval_every,
                               ring_size=ring_size, round_fn=round_fn,
                               faults=faults, fault_state=fstate,
                               channel_state=cstate, tap=tap)

    return jax.jit(fn, donate_argnums=(0, 1, 2, 3, 4, 5) if donate else ())


def run_experiment(loss_fn, params, store: ClientStore, cfg: FedZOConfig,
                   rounds: int, *, algo: Optional[str] = None, strategy=None,
                   eval_fn=None, eval_every: int = 0, ring_size: int = 0,
                   key=None, momentum=None, round_fn=None, faults=None,
                   donate: bool = True, checkpoint_every: int = 0,
                   checkpoint_dir=None, resume: bool = False,
                   max_segments=None, segment_callback=None,
                   max_retries: int = 3, lr_backoff: float = 0.5,
                   sink=None, tap_every: Optional[int] = None,
                   tracer=None, stream_segment: int = 8,
                   prefetch: bool = True) -> ExperimentResult:
    """Run a whole experiment inside ONE compiled program.

    The algorithm comes from the strategy registry: ``strategy=`` (a name
    or ``AlgoStrategy`` instance) wins, the ``algo=`` string is a
    deprecated alias, and the default is ``cfg.strategy`` — so swapping
    the algorithm is one config field. Stateful strategies (feddyn,
    scaffold) get their per-client state initialized here and returned as
    ``result.strategy_state``.

    ``eval_fn(params) -> dict of scalars`` must be jit-traceable; it runs
    in-scan every ``eval_every`` rounds. ``ring_size`` bounds the metrics
    buffer (0 keeps every round). With ``donate`` the caller's params /
    momentum / key buffers are consumed — reuse the returned ones.
    ``faults`` attaches a ``sim.faults.FaultModel`` (DESIGN.md §12).

    ``checkpoint_every=k`` (with ``checkpoint_dir``) switches to the
    durable segment runner: the same scan in k-round chunks, one host sync
    + one atomic full-carry snapshot per chunk, bit-identical to the
    single-shot run. ``resume=True`` continues from the latest snapshot in
    ``checkpoint_dir`` (fresh start when there is none). A segment whose
    carry comes back non-finite rolls back to the last good snapshot with
    the lr scaled by ``lr_backoff``, at most ``max_retries`` times, then
    raises ``DivergenceError``. ``max_segments`` bounds the segments run
    this call (for tests/preemption drills); ``segment_callback(round,
    total)`` fires after every successful snapshot.

    Observability: ``sink=`` (an ``obs.MetricsSink``) + ``tap_every=k``
    stream every k-th round's metrics LIVE from inside the scan; both
    default off, which keeps the compiled program byte-identical to the
    pre-obs engine. ``tracer=`` (an ``obs.Tracer``) records compile vs
    execute/segment spans (AOT-compiled, so compile is reported exactly
    once per static shape) and optionally a jax.profiler trace. Every
    result carries ``result.ledger``; runs with a ``checkpoint_dir`` or a
    file-backed sink also write a run manifest next to their artifacts.

    A ``sim.tiered.HostStore`` is dispatched to the tiered cohort-stream
    runner (``tiered.run_tiered_experiment``) — same signature, bitwise
    the same trajectory, host-resident population. ``stream_segment`` /
    ``prefetch`` tune that tier's staging pipeline only; the resident
    scan has no staging and ignores them.
    """
    if not isinstance(store, ClientStore):
        from repro.sim import tiered
        if isinstance(store, tiered.HostStore):
            return tiered.run_tiered_experiment(
                loss_fn, params, store, cfg, rounds, algo=algo,
                strategy=strategy, eval_fn=eval_fn, eval_every=eval_every,
                ring_size=ring_size, key=key, momentum=momentum,
                round_fn=round_fn, faults=faults, donate=donate,
                checkpoint_every=checkpoint_every,
                checkpoint_dir=checkpoint_dir, resume=resume,
                max_segments=max_segments,
                segment_callback=segment_callback,
                max_retries=max_retries, lr_backoff=lr_backoff, sink=sink,
                tap_every=tap_every, tracer=tracer,
                stream_segment=stream_segment, prefetch=prefetch)
        raise TypeError(f"store must be a ClientStore or HostStore, got "
                        f"{type(store).__name__}")
    strat = _resolve(strategy, algo, cfg)
    if key is None:
        key = experiment_key(cfg)
    if momentum is None and strat.has_momentum(cfg):
        momentum = tree_zeros_like(params)
    fstate = faults.init_state(store.n_clients) if faults is not None else None
    channel = cfg.channel_model
    # the chain's round-0 key is folded OFF the experiment key (never a
    # split of the round chain), so channel-off runs keep their key usage
    cstate = (channel.init_state(store.n_clients, channel_lib.init_key(key))
              if channel is not None else None)
    zstate = strat.init_state(params, cfg, store.n_clients)
    do_eval = eval_fn is not None and eval_every > 0
    tap = None
    if tap_every is not None:
        if sink is None:
            raise ValueError("tap_every=k needs a sink= to stream into")
        tap = RoundTap(sink, tap_every)
    # the byte model reads params metadata, so build it BEFORE the run
    # donates the buffers
    ledger = CommsLedger.from_run(cfg, params, channel=channel)
    n_clients = store.n_clients
    if checkpoint_every > 0:
        return _run_checkpointed(
            loss_fn, params, store, cfg, rounds, strategy=strat,
            eval_fn=eval_fn, eval_every=eval_every, ring_size=ring_size,
            key=key, momentum=momentum, round_fn=round_fn, faults=faults,
            fstate=fstate, cstate=cstate, zstate=zstate, donate=donate,
            checkpoint_every=checkpoint_every,
            checkpoint_dir=checkpoint_dir, resume=resume,
            max_segments=max_segments, segment_callback=segment_callback,
            max_retries=max_retries, lr_backoff=lr_backoff, tap=tap,
            tracer=tracer, ledger=ledger)
    fn = make_experiment_fn(loss_fn, cfg, rounds, strategy=strat,
                            eval_fn=eval_fn, eval_every=eval_every,
                            ring_size=ring_size, round_fn=round_fn,
                            faults=faults, donate=donate, tap=tap)
    args = (params, momentum, key, fstate, cstate, zstate, store)
    if tracer is not None:
        from repro.checkpoint.checkpoint import config_hash
        ckey = ("experiment", rounds, config_hash(cfg), strat.name,
                eval_every, ring_size, donate, tap is not None)
        with tracer.profile():
            compiled = tracer.timed_compile(ckey, fn, *args)
            with tracer.span("execute", rounds=rounds):
                out = jax.block_until_ready(compiled(*args))
    else:
        out = fn(*args)
    params, momentum, key, fstate, cstate, zstate, ring, ebuf = out
    eval_rounds = np.arange(0, rounds, eval_every) if do_eval \
        else np.arange(0)
    result = ExperimentResult(params=params, momentum=momentum, key=key,
                              metrics=ring, evals=ebuf, rounds=rounds,
                              ring_size=min(rounds, ring_size) or rounds,
                              eval_rounds=eval_rounds, fault_state=fstate,
                              channel_state=cstate,
                              strategy=strat.name, strategy_state=zstate,
                              ledger=ledger)
    sink_path = getattr(sink, "path", None)
    if sink_path:
        result.manifest = obs_manifest.build_manifest(
            cfg, strategy=strat.name, rounds=rounds, n_clients=n_clients,
            ledger=ledger, faults=faults, channel=channel,
            events=result.events,
            extra={"tap_every": tap.every} if tap is not None else None)
        obs_manifest.write_manifest(f"{sink_path}.manifest.json",
                                    result.manifest)
    return result


def _carry_to_state(params, momentum, key, fstate, cstate, zstate, ring,
                    ebuf) -> dict:
    """The durable form of the full experiment carry: one pytree whose
    leaves are all plain arrays (the typed PRNG key is exported via
    ``jax.random.key_data``; ``wrap_key_data`` re-types it on restore).
    A ``None`` zstate (or cstate) contributes no leaves, so snapshots of
    runs without the optional processes keep the historical npz layout —
    and channel-on snapshots carry the fading chain + batteries, so a
    kill-and-resume continues the wireless scenario bit-exactly."""
    return {"params": params, "momentum": momentum,
            "key": jax.random.key_data(key), "fstate": fstate,
            "cstate": cstate, "zstate": zstate, "ring": ring, "ebuf": ebuf}


def _state_to_carry(state: dict, cfg: FedZOConfig):
    """Inverse of ``_carry_to_state``. Host numpy leaves are put back on
    device here so the segment fn's donation always sees jax arrays."""
    key = jax.random.wrap_key_data(jnp.asarray(state["key"]),
                                   impl=cfg.prng_impl)
    dev = [jax.tree.map(jnp.asarray, state[k])
           for k in ("params", "momentum", "fstate", "cstate", "zstate",
                     "ring", "ebuf")]
    return (dev[0], dev[1], key, dev[2], dev[3], dev[4], dev[5], dev[6])


def _finite_state(state: dict, rounds_done, ring_alloc, eval_every,
                  do_eval) -> bool:
    """Host-side divergence check on a fetched carry: every param leaf and
    every metric/eval cell written by the rounds in ``rounds_done`` must be
    finite. Boolean masks and counters pass through ``isfinite`` trivially,
    so the check is a plain sweep over the written cells."""
    for leaf in jax.tree.leaves(state["params"]):
        if not np.all(np.isfinite(leaf)):
            return False
    slots = np.unique([t % ring_alloc for t in rounds_done])
    for v in state["ring"].values():
        if np.issubdtype(v.dtype, np.floating) and \
                not np.all(np.isfinite(v[slots])):
            return False
    if do_eval:
        eslots = np.unique([t // eval_every for t in rounds_done
                            if t % eval_every == 0])
        for v in state["ebuf"].values():
            if eslots.size and np.issubdtype(v.dtype, np.floating) and \
                    not np.all(np.isfinite(v[eslots])):
                return False
    return True


def _run_checkpointed(loss_fn, params, store, cfg, rounds, *, strategy,
                      eval_fn, eval_every, ring_size, key, momentum,
                      round_fn, faults, fstate, cstate, zstate, donate,
                      checkpoint_every, checkpoint_dir, resume,
                      max_segments, segment_callback, max_retries,
                      lr_backoff, tap=None, tracer=None,
                      ledger=None) -> ExperimentResult:
    """The durable segment loop behind ``run_experiment(...,
    checkpoint_every=k)``. Invariants:

    - **Bit-equality**: segments scan global round indices into buffers
      sized against the total, so the chunked run writes exactly the cells
      (and walks exactly the key chain) of the single-shot scan. The
      strategy carry rides the same snapshot, so a resumed scaffold/feddyn
      run restores every client's control/dual bit-identically.
    - **Durability**: the full carry is snapshotted atomically after every
      segment (``checkpoint.save_run_state``: tmp dir + rename + LATEST
      pointer swap), so a SIGKILL at ANY point leaves a consistent latest
      snapshot; ``resume=True`` continues from it.
    - **Recovery**: a non-finite post-segment carry rolls the run back to
      the last good snapshot, scales lr by ``lr_backoff``, and retries —
      at most ``max_retries`` times, then ``DivergenceError``. Every
      rollback appends a structured ``{"round", "event": "rollback", ...}``
      row to ``result.events`` (and the snapshot meta, so a resumed run
      keeps the full recovery log).
    """
    from repro.checkpoint import checkpoint as ckpt

    strat = _resolve(strategy, None, cfg)
    if checkpoint_dir is None:
        raise ValueError("checkpoint_every > 0 requires checkpoint_dir")
    do_eval = eval_fn is not None and eval_every > 0
    ring_alloc = min(rounds, ring_size) if ring_size else rounds
    n_evals = (rounds + eval_every - 1) // eval_every if do_eval else 0
    orig_hash = ckpt.config_hash(cfg)

    ring, ebuf = _zero_buffers(
        make_round_step(loss_fn, cfg, strategy=strat, round_fn=round_fn,
                        faults=faults),
        (params, momentum, key, fstate, cstate, zstate), store,
        eval_fn=eval_fn, params=params, ring_alloc=ring_alloc,
        n_evals=n_evals)

    t, events, cur_lr = 0, [], cfg.lr
    if resume:
        snap = ckpt.latest_run_state(checkpoint_dir)
        if snap is not None:
            like = _carry_to_state(params, momentum, key, fstate, cstate,
                                   zstate, ring, ebuf)
            state, meta = ckpt.restore_run_state(snap, like)
            if meta.get("config_hash") not in (None, orig_hash):
                import warnings
                warnings.warn(
                    f"resuming from a snapshot of a DIFFERENT config "
                    f"(hash {meta.get('config_hash')} != {orig_hash}) — "
                    f"the continued trajectory will not match either run")
            t = int(meta["round"])
            events = list(meta.get("events", []))
            cur_lr = float(meta.get("lr", cfg.lr))
            params, momentum, key, fstate, cstate, zstate, ring, ebuf = \
                _state_to_carry(state, cfg)

    def checkpoint_meta():
        return {"round": t, "rounds_total": rounds, "algo": strat.name,
                "strategy": strat.name, "config_hash": orig_hash,
                "lr": cur_lr, "events": events}

    def write_run_manifest():
        man = obs_manifest.build_manifest(
            cfg, strategy=strat.name, rounds=rounds,
            n_clients=store.n_clients, ledger=ledger, faults=faults,
            channel=cfg.channel_model, events=events,
            extra={"checkpoint_every": checkpoint_every, "lr": cur_lr,
                   "rounds_done": t,
                   "tap_every": tap.every if tap is not None else None})
        obs_manifest.write_manifest(checkpoint_dir, man)
        return man

    if t == 0:
        # round-0 snapshot: the rollback anchor for a first-segment
        # divergence (the donated pre-segment carry is gone by then)
        state0 = jax.device_get(
            _carry_to_state(params, momentum, key, fstate, cstate, zstate,
                            ring, ebuf))
        ckpt.save_run_state(checkpoint_dir, state0, round_idx=0,
                            meta=checkpoint_meta())
    write_run_manifest()   # provisional: rewritten with final events below

    seg_fns: dict = {}

    def segment_fn(chunk):
        if chunk not in seg_fns:
            run_cfg = (cfg if cur_lr == cfg.lr
                       else dataclasses.replace(cfg, lr=cur_lr))

            def fn(params, momentum, key, fstate, cstate, zstate, ring,
                   ebuf, t0, store):
                return experiment_core(
                    loss_fn, params, store, run_cfg, chunk, key, momentum,
                    strategy=strat, zstate=zstate, eval_fn=eval_fn,
                    eval_every=eval_every, ring_size=ring_size,
                    round_fn=round_fn, faults=faults, fault_state=fstate,
                    channel_state=cstate, t0=t0, total_rounds=rounds,
                    ring=ring, ebuf=ebuf, tap=tap)

            seg_fns[chunk] = jax.jit(
                fn,
                donate_argnums=(0, 1, 2, 3, 4, 5, 6, 7) if donate else ())
        return seg_fns[chunk]

    retries, segments_done = 0, 0
    with (tracer.profile() if tracer is not None else nullcontext()):
        while t < rounds:
            chunk = min(checkpoint_every, rounds - t)
            jitted = segment_fn(chunk)
            args = (params, momentum, key, fstate, cstate, zstate, ring,
                    ebuf, jnp.int32(t), store)
            if tracer is not None:
                # one compile span per (chunk size, lr) program — reused
                # executable across same-shape segments
                run = tracer.timed_compile(
                    ("segment", chunk, cur_lr, orig_hash), jitted, *args)
                seg_span = tracer.span("segment", t0=t, chunk=chunk)
            else:
                run, seg_span = jitted, nullcontext()
            with seg_span:
                out = run(*args)
                # ONE host sync per segment: fetch the full carry, then
                # everything below (divergence check + atomic save) is
                # host-side numpy
                state = jax.device_get(_carry_to_state(*out))
            t_next = t + chunk
            if not _finite_state(state, range(t, t_next), ring_alloc,
                                 eval_every, do_eval):
                retries += 1
                if retries > max_retries:
                    raise DivergenceError(t_next, max_retries, cur_lr)
                cur_lr *= lr_backoff
                events.append({"round": t_next, "event": "rollback",
                               "from_round": t, "retry": retries,
                               "lr": cur_lr})
                seg_fns.clear()  # the backed-off lr is baked into the
                if tracer is not None:   # program (and its executable)
                    tracer.invalidate_compiled()
                snap = ckpt.latest_run_state(checkpoint_dir)
                good, _ = ckpt.restore_run_state(snap, state)
                params, momentum, key, fstate, cstate, zstate, ring, \
                    ebuf = _state_to_carry(good, cfg)
                continue
            retries = 0
            params, momentum, key, fstate, cstate, zstate, ring, ebuf = out
            t = t_next
            ckpt.save_run_state(checkpoint_dir, state, round_idx=t,
                                meta=checkpoint_meta())
            segments_done += 1
            if segment_callback is not None:
                segment_callback(t, rounds)
            if max_segments is not None and segments_done >= max_segments:
                break

    manifest = write_run_manifest()   # final: full event stream, rounds_done
    eval_rounds = np.arange(0, t, eval_every) if do_eval else np.arange(0)
    return ExperimentResult(params=params, momentum=momentum, key=key,
                            metrics=ring, evals=ebuf, rounds=t,
                            ring_size=ring_alloc, eval_rounds=eval_rounds,
                            fault_state=fstate, channel_state=cstate,
                            events=list(events),
                            strategy=strat.name, strategy_state=zstate,
                            ledger=ledger, manifest=manifest)


def history(result: ExperimentResult, *, start_round: int = 0) -> list:
    """FedServer-style per-round history from an engine result: ONE host
    sync for everything (metrics ring + evals), then plain python floats.
    Every row carries the run's ``strategy`` name so multi-algorithm
    sweeps/comparisons stay distinguishable once rows are pooled.

    Eval rounds evicted from the metrics ring (a long run with a small
    ``ring_size``) still surface as eval-only rows — the in-scan evals live
    in their own [n_evals] buffer, so the full accuracy curve survives
    however small the ring is.

    Results carrying a comms ledger (every ``run_experiment`` result) get
    the byte columns appended host-side: per-round ``wire_bytes`` /
    ``dense_bytes`` / ``downlink_bytes``, cumulative ``wire_bytes_total``
    / ``downlink_bytes_total``, ``compression_ratio``, and
    ``wire_bytes_effective`` on rows that report ``m_effective``. They are
    annotations, NOT ring contents — the in-scan metric set (and thus the
    compiled program and the golden fixtures) is untouched. Tiered runs
    additionally carry ``result.staging`` (round -> bucket id / staged
    bytes), merged into the same rows by the ledger."""
    mets = jax.device_get(result.metrics)
    evals = jax.device_get(result.evals)
    ev_by_round = {int(t): {k: float(v[i]) for k, v in evals.items()}
                   for i, t in enumerate(result.eval_rounds)}
    ring_start = max(0, result.rounds - result.ring_size)
    out = []
    for t in sorted(ev_by_round):
        if t < ring_start:                  # evicted from the ring: eval-only
            out.append({"round": start_round + t,
                        "strategy": result.strategy, **ev_by_round[t]})
    for t in result.recorded_rounds():
        row = {"round": start_round + int(t), "strategy": result.strategy}
        slot = int(t) % result.ring_size
        row.update({k: float(v[slot]) for k, v in mets.items()})
        row.update(ev_by_round.get(int(t), {}))
        out.append(row)
    # structured host-side events (divergence rollbacks) interleave by
    # round — a rollback at round t sorts before round t's successful retry
    if result.events:
        out.extend({**e, "round": start_round + int(e["round"])}
                   for e in result.events)
        out.sort(key=lambda r: (r["round"], "event" not in r))
    if result.ledger is not None:
        result.ledger.annotate(out, staging=result.staging,
                               start_round=start_round)
    return out
