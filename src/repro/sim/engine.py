"""Fully-jitted multi-round federation engine (DESIGN.md §9).

One compiled program runs an ENTIRE experiment: ``lax.scan`` over R
communication rounds, each round drawing its participants and minibatches
from the device-resident ``ClientStore`` (sim/store.py), running the
existing simulated round (``fedzo.round_simulated`` /
``fedavg.round_simulated`` — momentum and channel scheduling threaded
through the carry), and writing its scalar metrics into a fixed-shape ring
buffer. Evaluation runs in-scan every k rounds behind a ``lax.cond``. The
host syncs exactly once, after all R rounds.

Key-chain protocol (shared with ``FedServer.run_round`` on the store path,
so R in-jit rounds bit-match R host-driven rounds):

    key, k_part, k_batch, k_zo, k_chan = split(key, 5)      # per round

``k_part`` draws the M-of-N participation permutation, ``k_batch`` the
local minibatches, ``k_zo`` the M per-client ZO keys, ``k_chan`` the
channel realization. The chain starts at ``key(cfg.seed, impl=
cfg.prng_impl)`` so a whole experiment is bit-reproducible from the config.

Donation: the jitted program donates params, momentum, and the key, so at
steady state the engine updates the model in place — no per-round
host↔device traffic and no double-buffered parameter copies.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedZOConfig
from repro.core import aircomp, fedavg, fedzo
from repro.sim.store import ClientStore, sample_batches, sample_participants
from repro.utils.tree import tree_zeros_like


def round_keys(key):
    """(next_carry_key, k_participation, k_batches, k_zo, k_channel)."""
    ks = jax.random.split(key, 5)
    return ks[0], ks[1], ks[2], ks[3], ks[4]


def experiment_key(cfg: FedZOConfig):
    """Round-0 carry key of an experiment: the one derivation both the
    engine and the FedServer store path start from."""
    return jax.random.key(cfg.seed, impl=cfg.prng_impl)


def make_round_step(loss_fn, cfg: FedZOConfig, *, algo: str = "fedzo",
                    round_fn=None) -> Callable:
    """One full communication round as a pure function
    ``step((params, momentum, key), store) -> ((params', momentum', key'),
    metrics)``.

    THE round unit shared by the scan engine and by
    ``FedServer.run_round`` on the store path — sharing it is what makes
    the two trajectories bit-identical. ``round_fn`` optionally replaces
    ``fedzo.round_simulated`` with a signature-compatible deployment (the
    clients-axis shard_map round of sim/shard.py).
    """
    has_momentum = algo == "fedzo" and _static_positive(cfg.server_momentum)
    fz_round = round_fn if round_fn is not None else fedzo.round_simulated
    weigh = cfg.weight_by_size

    def step(state, store: ClientStore):
        params, momentum, key = state
        key, k_part, k_batch, k_zo, k_chan = round_keys(key)
        idx = sample_participants(k_part, store.n_clients,
                                  cfg.n_participating)
        batches = sample_batches(store, idx, k_batch, cfg.local_iters,
                                 cfg.b1)
        # FedAvg-style n_i/n weights of the sampled clients (mean-1
        # normalized); only added to the round call when enabled so custom
        # round_fns without a weights kwarg keep working
        wkw = ({"weights": aircomp.size_weights(store.sizes[idx])}
               if weigh else {})
        if algo == "fedavg":
            params, metrics = fedavg.round_simulated(
                loss_fn, params, batches, cfg, channel_rng=k_chan, **wkw)
        else:
            rngs = jax.random.split(k_zo, cfg.n_participating)
            if has_momentum:
                params, metrics, momentum = fz_round(
                    loss_fn, params, batches, rngs, cfg, channel_rng=k_chan,
                    momentum=momentum, **wkw)
            else:
                params, metrics = fz_round(
                    loss_fn, params, batches, rngs, cfg, channel_rng=k_chan,
                    **wkw)
        return (params, momentum, key), metrics

    return step


def _static_positive(x) -> bool:
    """cfg fields compared against 0 at trace time must be static — a
    sweep-vmapped (traced) value here would silently change the program
    structure, so reject it loudly."""
    if isinstance(x, jax.core.Tracer):
        raise ValueError("server_momentum selects the carry structure and "
                         "cannot be swept/vmapped — keep it static")
    return x > 0


@dataclass
class ExperimentResult:
    """Host-side container for one engine run. ``metrics`` holds the ring
    buffer (dict of [ring_size] arrays, slot = round % ring_size);
    ``evals`` the in-scan eval outputs (dict of [n_evals] arrays), one slot
    per eval round in ``eval_rounds``."""
    params: Any
    momentum: Any
    key: Any
    metrics: dict
    evals: dict
    rounds: int
    ring_size: int
    eval_rounds: np.ndarray

    def recorded_rounds(self) -> np.ndarray:
        """Round numbers still present in the ring, oldest→newest."""
        start = max(0, self.rounds - self.ring_size)
        return np.arange(start, self.rounds)


def experiment_core(loss_fn, params, store: ClientStore, cfg: FedZOConfig,
                    rounds: int, key, momentum=None, *, algo: str = "fedzo",
                    eval_fn=None, eval_every: int = 0, ring_size: int = 0,
                    round_fn=None):
    """The traceable experiment body: scan ``rounds`` round steps, ring-
    buffer the metrics, eval in-scan every ``eval_every`` rounds. Returns
    (params, momentum, key, metrics_ring, evals). Un-jitted so sweeps can
    vmap it over a stacked config axis (sim/sweep.py)."""
    ring_size = min(rounds, ring_size) if ring_size else rounds
    step = make_round_step(loss_fn, cfg, algo=algo, round_fn=round_fn)
    do_eval = eval_fn is not None and eval_every > 0
    n_evals = (rounds + eval_every - 1) // eval_every if do_eval else 0

    state0 = (params, momentum, key)
    m_shapes = jax.eval_shape(lambda s: step(s, store)[1], state0)
    ring0 = {k: jnp.zeros((ring_size,), v.dtype)
             for k, v in m_shapes.items()}
    if do_eval:
        e_shapes = jax.eval_shape(eval_fn, params)
        ebuf0 = {k: jnp.zeros((n_evals,), v.dtype)
                 for k, v in e_shapes.items()}
    else:
        ebuf0 = {}

    def body(carry, t):
        state, ring, ebuf = carry
        state, metrics = step(state, store)
        slot = jnp.mod(t, ring_size)
        ring = {k: ring[k].at[slot].set(metrics[k].astype(ring[k].dtype))
                for k in ring}
        if do_eval:
            def run_eval(args):
                buf, p = args
                vals = eval_fn(p)
                return {k: buf[k].at[t // eval_every].set(
                    vals[k].astype(buf[k].dtype)) for k in buf}

            ebuf = jax.lax.cond(jnp.mod(t, eval_every) == 0, run_eval,
                                lambda args: args[0], (ebuf, state[0]))
        return (state, ring, ebuf), None

    (state, ring, ebuf), _ = jax.lax.scan(
        body, (state0, ring0, ebuf0), jnp.arange(rounds))
    params, momentum, key = state
    return params, momentum, key, ring, ebuf


def make_experiment_fn(loss_fn, cfg: FedZOConfig, rounds: int, *,
                       algo: str = "fedzo", eval_fn=None, eval_every: int = 0,
                       ring_size: int = 0, round_fn=None,
                       donate: bool = True) -> Callable:
    """Compile the whole experiment once: returns a jitted
    ``fn(params, momentum, key, store) -> (params', momentum', key',
    metrics_ring, evals)`` with params/momentum/key donated (pass
    ``momentum=None`` when cfg.server_momentum is 0)."""
    def fn(params, momentum, key, store):
        return experiment_core(loss_fn, params, store, cfg, rounds, key,
                               momentum, algo=algo, eval_fn=eval_fn,
                               eval_every=eval_every, ring_size=ring_size,
                               round_fn=round_fn)

    return jax.jit(fn, donate_argnums=(0, 1, 2) if donate else ())


def run_experiment(loss_fn, params, store: ClientStore, cfg: FedZOConfig,
                   rounds: int, *, algo: str = "fedzo", eval_fn=None,
                   eval_every: int = 0, ring_size: int = 0, key=None,
                   momentum=None, round_fn=None,
                   donate: bool = True) -> ExperimentResult:
    """Run a whole experiment inside ONE compiled program.

    ``eval_fn(params) -> dict of scalars`` must be jit-traceable; it runs
    in-scan every ``eval_every`` rounds. ``ring_size`` bounds the metrics
    buffer (0 keeps every round). With ``donate`` the caller's params /
    momentum / key buffers are consumed — reuse the returned ones.
    """
    if key is None:
        key = experiment_key(cfg)
    if momentum is None and algo == "fedzo" and cfg.server_momentum > 0:
        momentum = tree_zeros_like(params)
    fn = make_experiment_fn(loss_fn, cfg, rounds, algo=algo, eval_fn=eval_fn,
                            eval_every=eval_every, ring_size=ring_size,
                            round_fn=round_fn, donate=donate)
    params, momentum, key, ring, ebuf = fn(params, momentum, key, store)
    eval_rounds = (np.arange(0, rounds, eval_every)
                   if (eval_fn is not None and eval_every > 0)
                   else np.arange(0))
    return ExperimentResult(params=params, momentum=momentum, key=key,
                            metrics=ring, evals=ebuf, rounds=rounds,
                            ring_size=min(rounds, ring_size) or rounds,
                            eval_rounds=eval_rounds)


def history(result: ExperimentResult, *, start_round: int = 0) -> list:
    """FedServer-style per-round history from an engine result: ONE host
    sync for everything (metrics ring + evals), then plain python floats.

    Eval rounds evicted from the metrics ring (a long run with a small
    ``ring_size``) still surface as eval-only rows — the in-scan evals live
    in their own [n_evals] buffer, so the full accuracy curve survives
    however small the ring is."""
    mets = jax.device_get(result.metrics)
    evals = jax.device_get(result.evals)
    ev_by_round = {int(t): {k: float(v[i]) for k, v in evals.items()}
                   for i, t in enumerate(result.eval_rounds)}
    ring_start = max(0, result.rounds - result.ring_size)
    out = []
    for t in sorted(ev_by_round):
        if t < ring_start:                  # evicted from the ring: eval-only
            out.append({"round": start_round + t, **ev_by_round[t]})
    for t in result.recorded_rounds():
        row = {"round": start_round + int(t)}
        slot = int(t) % result.ring_size
        row.update({k: float(v[slot]) for k, v in mets.items()})
        row.update(ev_by_round.get(int(t), {}))
        out.append(row)
    return out
