"""Tiered client store: host-resident populations behind a cohort stream
(DESIGN.md §15).

The device-resident ``ClientStore`` caps the federation at device memory
and pads every client to the global max row count — fatal for the paper's
own regime, where only M of N clients matter per round and N is 10⁵–10⁶.
This module flips the storage/engine boundary: the population lives on the
HOST and only the sampled cohort (plus one prefetch buffer) ever touches
the device.

- ``HostStore`` — all N clients in host numpy (optionally memory-mapped
  ``.npy``) arrays, grouped into K **bucketed padding groups**: clients are
  binned by row count at size quantiles and each bucket is stacked at its
  OWN capacity, so pad waste is per-bucket, not global, and the engine
  compiles one program per bucket shape instead of one per round.
- ``CohortStream`` — replays the engine's participation key chain ON THE
  HOST: the same ``split(key, 5)`` (6 with faults) and the same
  ``sample_participants`` permutation the compiled round would draw, so
  the stream knows round t's M-cohort before the device reaches round t
  (bit-identical by construction; pinned by tests/test_tiered.py). Fault
  runs also host-replay the [N] Gilbert–Elliott chain via
  ``FaultModel.advance`` and stream only the [M] availability slice.
- ``run_tiered_experiment`` — the driver: double-buffered async staging
  (the next segment's ``jax.device_put`` overlaps the compiled current
  segment), segmented scans through ``engine.stream_core`` (the PR 6
  t0/total-rounds machinery, so chunked ≡ single-shot bitwise), durable
  checkpoints and divergence rollback matching the resident runner, and a
  prefetch-stall ledger for sim_bench.

[N]-sized carry state never enters the trace: the fault chain, the
wireless-scenario chain (``cfg.channel_model`` — host-replayed wholesale,
only the [M] realized fading + transmit mask is staged), and the stateful
strategies' per-client masters ({"client": [N, ...]}) are host-resident;
each segment slices the cohort's [M] rows in and scatters the returned
rows back. Snapshots keep the SAME npz leaf layout as the resident
engine's (params/momentum/key/fstate/cstate/zstate/ring/ebuf), so a
tiered run can resume a resident run's checkpoint and vice versa.

The central acceptance proof (tests/test_tiered.py): a ``HostStore`` run
is bitwise-identical to the ``ClientStore`` run on the same config —
including under faults, FedDyn/SCAFFOLD state, chunking, and
SIGKILL-and-resume — because every traced value is derived identically
and the host replica consumes exactly the key streams the trace leaves
unconsumed.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from concurrent.futures import ThreadPoolExecutor
from contextlib import nullcontext
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedZOConfig
from repro.core import strategy as strategy_mod
from repro.obs import manifest as obs_manifest
from repro.obs.ledger import CommsLedger
from repro.obs.taps import RoundTap
from repro.sim import channel as channel_lib
from repro.sim import engine
from repro.sim.faults import DivergenceError, FaultModel
from repro.sim.store import (ClientStore, CohortBatch, build_store,
                             client_sizes, sample_participants, stack_padded)
from repro.utils.tree import tree_zeros_like


# -- bucketed host population -------------------------------------------------

@dataclass
class Bucket:
    """One padding group: the clients whose row counts fall at or under
    this bucket's capacity (and over the previous bucket's), stacked
    [n_b, cap, ...] at the bucket's OWN cap."""
    ids: np.ndarray   # [n_b] int64 global client ids, ascending
    cap: int          # padded row capacity of this bucket
    data: Any         # pytree, leaves [n_b, cap, ...] host (maybe mmap)


def bucket_caps(sizes, n_buckets: int) -> list:
    """Deterministic bucket capacities: the size quantiles of the
    population (method="higher", so every cap is an actual client size and
    the last cap is the max), deduplicated ascending. Uniform populations
    collapse to one bucket."""
    qs = np.quantile(np.asarray(sizes),
                     np.linspace(0.0, 1.0, int(n_buckets) + 1)[1:],
                     method="higher")
    return sorted({int(q) for q in qs})


@dataclass
class HostStore:
    """All N clients host-resident in K bucketed padding groups, plus the
    index maps the cohort stream needs: ``sizes`` [N] true row counts,
    ``bucket_of`` [N] bucket index, ``row_of`` [N] row within the bucket."""
    buckets: list
    sizes: np.ndarray
    bucket_of: np.ndarray
    row_of: np.ndarray

    @property
    def n_clients(self) -> int:
        return int(self.sizes.shape[0])

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def capacity(self) -> int:
        return max(b.cap for b in self.buckets)

    @property
    def nbytes(self) -> int:
        """Host bytes of the bucketed population (data leaves only)."""
        return int(sum(l.nbytes for b in self.buckets
                       for l in jax.tree.leaves(b.data)))

    def client(self, i: int):
        """Client i's UNPADDED rows (host views — no copy off mmap)."""
        b = self.buckets[int(self.bucket_of[i])]
        r, n = int(self.row_of[i]), int(self.sizes[i])
        return jax.tree.map(lambda l: l[r, :n], b.data)

    # -- staging -------------------------------------------------------------
    def stage(self, idx_rounds) -> tuple:
        """Assemble the host-side cohort stream for a segment:
        ``idx_rounds`` [S, M] client ids -> (data pytree with leaves
        [S, M, cap, ...], sizes [S, M] int32, meta). ``cap`` is the max
        bucket capacity present in the segment, so the staged buffer is as
        small as the sampled cohorts allow while keeping ONE jit shape per
        (segment length, bucket cap). ``meta`` reports the cap, per-round
        dominating ``bucket_ids`` [S], and staged byte counts."""
        idx = np.asarray(idx_rounds, np.int64)
        s, m = idx.shape
        b_of = self.bucket_of[idx]                       # [S, M]
        rows = self.row_of[idx]                          # [S, M]
        present = np.unique(b_of)
        cap = max(self.buckets[int(b)].cap for b in present)
        treedef = jax.tree.structure(self.buckets[0].data)
        bleaves = [jax.tree.leaves(b.data) for b in self.buckets]
        out_leaves, nbytes = [], 0
        for j in range(treedef.num_leaves):
            head = bleaves[int(present[0])][j]
            out = np.zeros((s, m, cap) + head.shape[2:], head.dtype)
            for b in present:
                sel = np.nonzero(b_of == b)
                out[sel[0], sel[1], :self.buckets[int(b)].cap] = \
                    bleaves[int(b)][j][rows[sel]]
            nbytes += out.nbytes
            out_leaves.append(out)
        data = jax.tree.unflatten(treedef, out_leaves)
        sizes = self.sizes[idx].astype(np.int32)
        nbytes += sizes.nbytes
        meta = {"cap": int(cap),
                "bucket_ids": b_of.max(axis=1),
                "bytes": int(nbytes),
                "round_bytes": int(nbytes // max(1, s))}
        return data, sizes, meta

    def cohort_struct(self, m: int, *, with_avail: bool,
                      with_channel: bool = False) -> CohortBatch:
        """A ``ShapeDtypeStruct`` CohortBatch at the max capacity — the
        ``jax.eval_shape`` input for sizing the metrics ring."""
        cap = self.capacity
        data = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((m, cap) + tuple(l.shape[2:]),
                                           l.dtype),
            self.buckets[0].data)
        return CohortBatch(
            data=data, sizes=jax.ShapeDtypeStruct((m,), jnp.int32),
            avail=(jax.ShapeDtypeStruct((m,), jnp.bool_)
                   if with_avail else None),
            chan_h=(jax.ShapeDtypeStruct((m,), jnp.complex64)
                    if with_channel else None),
            chan_mask=(jax.ShapeDtypeStruct((m,), jnp.bool_)
                       if with_channel else None))

    # -- tier conversion -----------------------------------------------------
    def to_resident(self) -> ClientStore:
        """Materialize the device-resident tier: bit-identical to
        ``build_store`` over the same clients (each bucket's zero-padded
        rows land in the zero-initialized global-cap buffer, so the pad
        regions agree exactly)."""
        cap = int(self.sizes.max())
        n = self.n_clients
        treedef = jax.tree.structure(self.buckets[0].data)
        bleaves = [jax.tree.leaves(b.data) for b in self.buckets]
        out_leaves = []
        for j in range(treedef.num_leaves):
            head = bleaves[0][j]
            out = np.zeros((n, cap) + head.shape[2:], head.dtype)
            for bi, b in enumerate(self.buckets):
                out[b.ids, :b.cap] = bleaves[bi][j]
            out_leaves.append(jax.device_put(out))
        return ClientStore(data=jax.tree.unflatten(treedef, out_leaves),
                           sizes=jnp.asarray(self.sizes, jnp.int32))

    # -- durability ----------------------------------------------------------
    def save(self, path: str) -> str:
        """Persist the bucketed population as one ``.npy`` per leaf (the
        layout ``load(..., mmap=True)`` memory-maps) plus index arrays and
        a JSON manifest. Client pytrees must be (nested) dicts — the
        repo's client dataset format."""
        os.makedirs(path, exist_ok=True)
        np.save(os.path.join(path, "sizes.npy"), self.sizes)
        np.save(os.path.join(path, "bucket_of.npy"), self.bucket_of)
        np.save(os.path.join(path, "row_of.npy"), self.row_of)
        names = _leaf_names(self.buckets[0].data)
        for bi, b in enumerate(self.buckets):
            np.save(os.path.join(path, f"bucket{bi}_ids.npy"), b.ids)
            for name, leaf in zip(names, jax.tree.leaves(b.data)):
                np.save(os.path.join(path, f"bucket{bi}__{name}.npy"),
                        np.asarray(leaf))
        with open(os.path.join(path, "hoststore.json"), "w") as f:
            json.dump({"version": 1, "leaves": names,
                       "caps": [b.cap for b in self.buckets]}, f, indent=1)
        return path

    @classmethod
    def load(cls, path: str, *, mmap: bool = True) -> "HostStore":
        """Reopen a saved population. ``mmap=True`` memory-maps every data
        leaf, so a load costs index arrays only and ``stage()`` reads just
        the sampled cohorts' rows off disk — populations far beyond host
        RAM stay usable."""
        with open(os.path.join(path, "hoststore.json")) as f:
            man = json.load(f)
        mode = "r" if mmap else None
        buckets = []
        for bi, cap in enumerate(man["caps"]):
            ids = np.load(os.path.join(path, f"bucket{bi}_ids.npy"))
            leaves = [np.load(os.path.join(path, f"bucket{bi}__{n}.npy"),
                              mmap_mode=mode) for n in man["leaves"]]
            buckets.append(Bucket(ids=ids, cap=int(cap),
                                  data=_nest_leaves(man["leaves"], leaves)))
        return cls(buckets=buckets,
                   sizes=np.load(os.path.join(path, "sizes.npy")),
                   bucket_of=np.load(os.path.join(path, "bucket_of.npy")),
                   row_of=np.load(os.path.join(path, "row_of.npy")))


def _leaf_names(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for kp, _v in flat:
        parts = []
        for k in kp:
            if not isinstance(k, jax.tree_util.DictKey):
                raise ValueError(
                    "HostStore.save supports dict-structured client "
                    f"pytrees; got key {k!r}")
            parts.append(str(k.key))
        names.append("/".join(parts))
    return names


def _nest_leaves(names: list, leaves: list):
    out: dict = {}
    for name, leaf in zip(names, leaves):
        node, parts = out, name.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return out


def build_host_store(clients, n_buckets: int = 4) -> HostStore:
    """Bucket a list of per-client dataset pytrees into a ``HostStore``.

    Capacities come from ``bucket_caps`` (size quantiles); each client
    lands in the smallest bucket whose cap covers its row count, keeping
    its rows exactly once (the partition invariants the hypothesis test
    pins). Stacking reuses ``stack_padded`` — one preallocated buffer per
    (bucket, leaf), never transient padded copies."""
    sizes = np.asarray(client_sizes(clients), np.int64)
    caps = bucket_caps(sizes, n_buckets)
    assign = np.searchsorted(caps, sizes, side="left")
    n = sizes.shape[0]
    bucket_of = np.zeros(n, np.int64)
    row_of = np.zeros(n, np.int64)
    buckets = []
    for cap in caps:
        ids = np.nonzero(assign == caps.index(cap))[0]
        if ids.size == 0:      # dedup can orphan a quantile; drop it
            continue
        bucket_of[ids] = len(buckets)
        row_of[ids] = np.arange(ids.size)
        data = jax.tree.map(lambda *ls, c=cap: stack_padded(ls, c),
                            *[clients[int(i)] for i in ids])
        buckets.append(Bucket(ids=ids, cap=int(cap), data=data))
    return HostStore(buckets=buckets, sizes=sizes, bucket_of=bucket_of,
                     row_of=row_of)


def resolve_store(store, *, tier: str = "auto"):
    """The one seam through which drivers accept either store tier.

    ``tier="resident"`` always returns a device-resident ``ClientStore``
    (a ``HostStore`` is materialized via ``to_resident()``, bit-identical
    to ``build_store`` on the same clients — so ``FedServer``, ``sweep``,
    and the sharded round run unchanged on either input). ``tier="host"``
    builds/keeps the host tier. ``tier="auto"`` keeps whatever tier was
    passed; a plain list of client datasets builds the resident tier."""
    if isinstance(store, ClientStore):
        return store
    if isinstance(store, HostStore):
        return store.to_resident() if tier == "resident" else store
    if isinstance(store, (list, tuple)):
        return (build_host_store(list(store)) if tier == "host"
                else build_store(list(store)))
    raise TypeError(f"not a client store or client list: "
                    f"{type(store).__name__}")


# -- host key-chain replay ----------------------------------------------------

class CohortStream:
    """Host replica of the engine's per-round key chain.

    Each ``next_round()`` performs the EXACT splits the compiled round
    performs on its carry key — ``engine.split_round_keys``, the shared
    single source of truth — and consumes the streams the trace leaves
    unconsumed: ``k_part`` draws the participation permutation
    (``sample_participants``, same Threefry path, eager instead of traced
    — bit-identical), on fault runs the availability substream of
    ``k_fault`` advances the [N] chain (``FaultModel.advance``), and on
    wireless-scenario runs ``k_chanm`` advances the WHOLE channel chain
    (``ChannelModel.step`` is pure in (key, state, idx) with no delta
    dependence, so the host replay — fading, scheduling, battery debits —
    is the in-carry derivation, not an approximation of it). The stream's
    key therefore stays in lockstep with the device carry key round for
    round (pinned by test), which is what lets staging run arbitrarily
    far ahead of the device."""

    def __init__(self, store: HostStore, cfg: FedZOConfig, key, *,
                 faults: Optional[FaultModel] = None, fstate=None,
                 cstate=None):
        self.store, self.cfg = store, cfg
        self.key = key
        self.faults = faults
        self.fstate = fstate
        self.channel = cfg.channel_model
        self.cstate = cstate

    def next_round(self) -> tuple:
        """Advance one round: -> (idx [M] int64, avail [M] bool | None,
        chan_h [M] complex64 | None, chan_mask [M] bool | None)."""
        self.key, k_part, _kb, _kz, _kc, k_fault, k_chanm = \
            engine.split_round_keys(self.key,
                                    faults=self.faults is not None,
                                    channel=self.channel is not None)
        idx = np.asarray(sample_participants(
            k_part, self.store.n_clients, self.cfg.n_participating),
            np.int64)
        avail = None
        if self.faults is not None:
            k_avail = jax.random.split(k_fault, 3)[0]
            self.fstate = self.faults.advance(k_avail, self.fstate)
            avail = np.asarray(self.fstate)[idx]
        chan_h = chan_mask = None
        if self.channel is not None:
            self.cstate, rchan = self.channel.step(
                k_chanm, self.cstate, jnp.asarray(idx),
                h_min=self.cfg.h_min, schedule=self.cfg.channel_schedule)
            chan_h = np.asarray(rchan.h)
            chan_mask = np.asarray(rchan.mask)
        return idx, avail, chan_h, chan_mask

    def plan(self, n: int) -> tuple:
        """Replay ``n`` rounds ahead: -> (idx [n, M], avail [n, M]|None,
        chan_h [n, M]|None, chan_mask [n, M]|None)."""
        drawn = [self.next_round() for _ in range(n)]
        idx = np.stack([d[0] for d in drawn])
        avail = (np.stack([d[1] for d in drawn])
                 if self.faults is not None else None)
        chan_h = (np.stack([d[2] for d in drawn])
                  if self.channel is not None else None)
        chan_mask = (np.stack([d[3] for d in drawn])
                     if self.channel is not None else None)
        return idx, avail, chan_h, chan_mask


class _Ready:
    """Future-shaped wrapper for the prefetch-off path."""

    def __init__(self, value):
        self._value = value

    def result(self):
        return self._value


# -- the tiered experiment runner ---------------------------------------------

def run_tiered_experiment(loss_fn, params, store: HostStore,
                          cfg: FedZOConfig, rounds: int, *,
                          algo: Optional[str] = None, strategy=None,
                          eval_fn=None, eval_every: int = 0,
                          ring_size: int = 0, key=None, momentum=None,
                          round_fn=None,
                          faults: Optional[FaultModel] = None,
                          donate: bool = True, checkpoint_every: int = 0,
                          checkpoint_dir=None, resume: bool = False,
                          max_segments=None, segment_callback=None,
                          max_retries: int = 3, lr_backoff: float = 0.5,
                          sink=None, tap_every: Optional[int] = None,
                          tracer=None, stream_segment: int = 8,
                          prefetch: bool = True) -> engine.ExperimentResult:
    """``run_experiment`` over a host-resident population.

    Same contract and (bitwise) the same trajectory as the resident
    runner on the equivalent ``ClientStore`` — checkpointing, divergence
    rollback with lr backoff, taps, tracer spans, ledger and manifest all
    included — but the device only ever holds the in-flight segment's
    cohorts plus ONE prefetch buffer:

    - the ``CohortStream`` plans ``stream_segment`` rounds ahead on the
      main thread (key-chain replay), a single worker thread stages and
      ``jax.device_put``s the next segment while the device runs the
      current compiled segment (double buffering; ``prefetch=False``
      serializes, for measurement);
    - stateful strategies force ``stream_segment=1``: their [N] client
      master lives in host numpy, the cohort's [M] rows are sliced in and
      scattered back every round (overlapping cohorts would read stale
      state otherwise). The fault chain needs no such clamp — the stream
      replays it forward;
    - ``result.staging`` records each round's dominating bucket id and
      staged bytes (merged into ``history()`` rows by the ledger), and
      ``result.prefetch`` the stall accounting sim_bench reports
      (``stall_pct`` = time the main loop blocked waiting on staging /
      total wall time, cold-start segment excluded).
    """
    from repro.checkpoint import checkpoint as ckpt

    strat = strategy_mod.resolve(strategy, algo, cfg)
    strat.validate(cfg)
    if key is None:
        key = engine.experiment_key(cfg)
    if momentum is None and strat.has_momentum(cfg):
        momentum = tree_zeros_like(params)
    n_clients = store.n_clients
    m = cfg.n_participating
    do_eval = eval_fn is not None and eval_every > 0
    tap = None
    if tap_every is not None:
        if sink is None:
            raise ValueError("tap_every=k needs a sink= to stream into")
        tap = RoundTap(sink, tap_every)
    channel = cfg.channel_model
    ledger = CommsLedger.from_run(cfg, params, channel=channel)
    if checkpoint_every > 0 and checkpoint_dir is None:
        raise ValueError("checkpoint_every > 0 requires checkpoint_dir")

    # host-resident [N] halves of the carry
    fstate = faults.init_state(n_clients) if faults is not None else None
    # wireless-scenario chain (sim/channel.py): host-resident like fstate —
    # the stream replays it and stages only the [M] realization per round
    cstate = (channel.init_state(n_clients, channel_lib.init_key(key))
              if channel is not None else None)
    z_template = strat.init_state(params, cfg, 1)
    stateful = z_template is not None
    if stateful:
        client_master = jax.tree.map(
            lambda l: np.zeros((n_clients,) + tuple(l.shape[1:]),
                               np.asarray(l).dtype), z_template["client"])
        z_server = jax.tree.map(jnp.asarray, z_template["server"])
        seg_len = 1
    else:
        client_master, z_server = None, None
        seg_len = max(1, int(stream_segment))

    ring_alloc = min(rounds, ring_size) if ring_size else rounds
    n_evals = (rounds + eval_every - 1) // eval_every if do_eval else 0
    step = engine.make_cohort_round_step(loss_fn, cfg, strategy=strat,
                                         round_fn=round_fn, faults=faults)
    zc_struct = None
    if stateful:
        zc_struct = {"client": jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((m,) + tuple(l.shape[1:]),
                                           l.dtype), z_template["client"]),
            "server": z_server}
    ring, ebuf = engine._zero_buffers(
        step, (params, momentum, key, zc_struct),
        store.cohort_struct(m, with_avail=faults is not None,
                            with_channel=channel is not None),
        eval_fn=eval_fn, params=params, ring_alloc=ring_alloc,
        n_evals=n_evals)

    t, events, cur_lr = 0, [], cfg.lr
    orig_hash = ckpt.config_hash(cfg)

    def pack_state():
        # SAME leaf layout as the resident engine's _carry_to_state: the
        # host-resident halves slot into the fstate/cstate/zstate keys, so
        # tiered and resident snapshots of one run interchange
        return {"params": params, "momentum": momentum,
                "key": jax.random.key_data(key), "fstate": fstate,
                "cstate": cstate,
                "zstate": ({"client": client_master, "server": z_server}
                           if stateful else None),
                "ring": ring, "ebuf": ebuf}

    if checkpoint_every > 0 and resume:
        snap = ckpt.latest_run_state(checkpoint_dir)
        if snap is not None:
            state_r, meta = ckpt.restore_run_state(snap, pack_state())
            if meta.get("config_hash") not in (None, orig_hash):
                import warnings
                warnings.warn(
                    f"resuming from a snapshot of a DIFFERENT config "
                    f"(hash {meta.get('config_hash')} != {orig_hash}) — "
                    f"the continued trajectory will not match either run")
            t = int(meta["round"])
            events = list(meta.get("events", []))
            cur_lr = float(meta.get("lr", cfg.lr))
            params, momentum, key, fstate, cstate, client_master, \
                z_server, ring, ebuf = _unpack_state(state_r, cfg, stateful)

    stream = CohortStream(store, cfg, key, faults=faults, fstate=fstate,
                          cstate=cstate)

    def checkpoint_meta():
        return {"round": t, "rounds_total": rounds, "algo": strat.name,
                "strategy": strat.name, "config_hash": orig_hash,
                "lr": cur_lr, "events": events}

    def tiered_block():
        return {"tiered": {"n_buckets": store.n_buckets,
                           "stream_segment": seg_len,
                           "host_bytes": store.nbytes,
                           "prefetch": bool(prefetch)}}

    def write_run_manifest():
        man = obs_manifest.build_manifest(
            cfg, strategy=strat.name, rounds=rounds, n_clients=n_clients,
            ledger=ledger, faults=faults, channel=channel, events=events,
            extra={"checkpoint_every": checkpoint_every, "lr": cur_lr,
                   "rounds_done": t,
                   "tap_every": tap.every if tap is not None else None,
                   **tiered_block()})
        obs_manifest.write_manifest(checkpoint_dir, man)
        return man

    if checkpoint_every > 0:
        if t == 0:
            ckpt.save_run_state(checkpoint_dir,
                                jax.device_get(pack_state()),
                                round_idx=0, meta=checkpoint_meta())
        write_run_manifest()

    seg_fns: dict = {}

    def segment_fn():
        if cur_lr not in seg_fns:
            run_cfg = (cfg if cur_lr == cfg.lr
                       else dataclasses.replace(cfg, lr=cur_lr))

            def fn(params, momentum, key, zstate, ring, ebuf, t0, xs):
                return engine.stream_core(
                    loss_fn, params, run_cfg, key, momentum, strategy=strat,
                    zstate=zstate, xs=xs, t0=t0, total_rounds=rounds,
                    ring=ring, ebuf=ebuf, eval_fn=eval_fn,
                    eval_every=eval_every, ring_size=ring_size,
                    round_fn=round_fn, faults=faults, tap=tap)

            seg_fns[cur_lr] = jax.jit(
                fn, donate_argnums=(0, 1, 2, 3, 4, 5) if donate else ())
        return seg_fns[cur_lr]

    def stage_put(idx, avail, chan_h, chan_mask):
        data, sizes, meta = store.stage(idx)
        xb = CohortBatch(data=data, sizes=sizes, avail=avail,
                         chan_h=chan_h, chan_mask=chan_mask)
        return jax.device_put(xb), meta

    pool = ThreadPoolExecutor(max_workers=1) if prefetch else None

    def submit(start):
        end = min(start + seg_len, rounds)
        if checkpoint_every > 0:
            end = min(end,
                      (start // checkpoint_every + 1) * checkpoint_every)
        idx, avail, chan_h, chan_mask = stream.plan(end - start)
        fut = (pool.submit(stage_put, idx, avail, chan_h, chan_mask)
               if pool is not None
               else _Ready(stage_put(idx, avail, chan_h, chan_mask)))
        # the chain state AS OF round `end` — stream.fstate/.cstate race
        # ahead with the prefetch, snapshots must not
        return fut, idx, end, stream.fstate, stream.cstate

    staging_rows: dict = {}
    prefetch_stats = {"stall_s": 0.0, "wall_s": 0.0, "stall_pct": 0.0,
                      "staged_bytes": 0, "host_bytes": store.nbytes,
                      "device_segment_bytes_max": 0,
                      "stream_segment": seg_len,
                      "n_buckets": store.n_buckets}
    retries, segments_done, last_ckpt = 0, 0, t
    cold = True
    wall0 = time.perf_counter()
    pending = submit(t)
    try:
        with (tracer.profile() if tracer is not None else nullcontext()):
            while t < rounds:
                fut, idx, end, seg_fstate, seg_cstate = pending
                w0 = time.perf_counter()
                xs, smeta = fut.result()
                waited = time.perf_counter() - w0
                if cold:
                    cold = False    # nothing to overlap the first wait with
                else:
                    prefetch_stats["stall_s"] += waited
                if end < rounds:
                    pending = submit(end)
                seg = end - t
                zc = ({"client": jax.tree.map(
                          lambda a: jnp.asarray(a[idx[0]]), client_master),
                       "server": z_server} if stateful else None)
                jitted = segment_fn()
                args = (params, momentum, key, zc, ring, ebuf,
                        jnp.int32(t), xs)
                if tracer is not None:
                    run = tracer.timed_compile(
                        ("tiered_segment", seg, smeta["cap"], stateful,
                         cur_lr, orig_hash), jitted, *args)
                    span = tracer.span("tiered_segment", t0=t, chunk=seg,
                                       bucket_cap=smeta["cap"])
                else:
                    run, span = jitted, nullcontext()
                with span:
                    out = run(*args)
                params, momentum, key, zc_out, ring, ebuf = out
                fstate = seg_fstate
                cstate = seg_cstate
                if stateful:
                    host_rows = jax.device_get(zc_out["client"])
                    jax.tree.map(lambda a, v: a.__setitem__(idx[0], v),
                                 client_master, host_rows)
                    z_server = zc_out["server"]
                for j in range(seg):
                    staging_rows[t + j] = {
                        "bucket_id": int(smeta["bucket_ids"][j]),
                        "staged_bytes": int(smeta["round_bytes"])}
                prefetch_stats["staged_bytes"] += int(smeta["bytes"])
                prefetch_stats["device_segment_bytes_max"] = max(
                    prefetch_stats["device_segment_bytes_max"],
                    int(smeta["bytes"]))
                t = end
                if checkpoint_every > 0 and \
                        (t % checkpoint_every == 0 or t >= rounds):
                    state = jax.device_get(pack_state())
                    if not engine._finite_state(state, range(last_ckpt, t),
                                                ring_alloc, eval_every,
                                                do_eval):
                        retries += 1
                        if retries > max_retries:
                            raise DivergenceError(t, max_retries, cur_lr)
                        cur_lr *= lr_backoff
                        events.append({"round": t, "event": "rollback",
                                       "from_round": last_ckpt,
                                       "retry": retries, "lr": cur_lr})
                        seg_fns.clear()   # backed-off lr is baked in
                        if tracer is not None:
                            tracer.invalidate_compiled()
                        snap = ckpt.latest_run_state(checkpoint_dir)
                        good, gm = ckpt.restore_run_state(snap, state)
                        params, momentum, key, fstate, cstate, \
                            client_master, z_server, ring, ebuf = \
                            _unpack_state(good, cfg, stateful)
                        t = int(gm["round"])
                        last_ckpt = t
                        stream = CohortStream(store, cfg, key,
                                              faults=faults, fstate=fstate,
                                              cstate=cstate)
                        pending = submit(t)
                        cold = True
                        continue
                    retries = 0
                    ckpt.save_run_state(checkpoint_dir, state, round_idx=t,
                                        meta=checkpoint_meta())
                    last_ckpt = t
                    segments_done += 1
                    if segment_callback is not None:
                        segment_callback(t, rounds)
                    if max_segments is not None and \
                            segments_done >= max_segments:
                        break
    finally:
        if pool is not None:
            pool.shutdown(wait=True, cancel_futures=True)

    jax.block_until_ready(jax.tree.leaves(params)[0])
    wall = time.perf_counter() - wall0
    prefetch_stats["wall_s"] = wall
    prefetch_stats["stall_pct"] = (100.0 * prefetch_stats["stall_s"] / wall
                                   if wall > 0 else 0.0)

    manifest = write_run_manifest() if checkpoint_every > 0 else None
    eval_rounds = np.arange(0, t, eval_every) if do_eval else np.arange(0)
    result = engine.ExperimentResult(
        params=params, momentum=momentum, key=key, metrics=ring,
        evals=ebuf, rounds=t, ring_size=ring_alloc,
        eval_rounds=eval_rounds,
        fault_state=(jnp.asarray(fstate) if faults is not None else None),
        channel_state=(jax.tree.map(jnp.asarray, cstate)
                       if channel is not None else None),
        events=list(events), strategy=strat.name,
        strategy_state=({"client": jax.tree.map(jnp.asarray, client_master),
                         "server": z_server} if stateful else None),
        ledger=ledger, manifest=manifest, staging=staging_rows,
        prefetch=prefetch_stats)
    sink_path = getattr(sink, "path", None)
    if sink_path:
        result.manifest = obs_manifest.build_manifest(
            cfg, strategy=strat.name, rounds=rounds, n_clients=n_clients,
            ledger=ledger, faults=faults, channel=channel,
            events=result.events,
            extra={**({"tap_every": tap.every} if tap is not None else {}),
                   **tiered_block()})
        obs_manifest.write_manifest(f"{sink_path}.manifest.json",
                                    result.manifest)
    return result


def _unpack_state(state: dict, cfg: FedZOConfig, stateful: bool) -> tuple:
    """Split a restored snapshot back into the tiered carry: device halves
    as jax arrays, host-resident halves as WRITABLE numpy (the [N] client
    master is scattered into in place every segment)."""
    key = jax.random.wrap_key_data(jnp.asarray(state["key"]),
                                   impl=cfg.prng_impl)
    params = jax.tree.map(jnp.asarray, state["params"])
    momentum = (None if state["momentum"] is None
                else jax.tree.map(jnp.asarray, state["momentum"]))
    fstate = (None if state["fstate"] is None
              else jnp.asarray(state["fstate"]))
    cstate = (None if state.get("cstate") is None
              else jax.tree.map(jnp.asarray, state["cstate"]))
    if stateful:
        client_master = jax.tree.map(
            lambda a: np.array(jax.device_get(a)), state["zstate"]["client"])
        z_server = jax.tree.map(jnp.asarray, state["zstate"]["server"])
    else:
        client_master, z_server = None, None
    ring = jax.tree.map(jnp.asarray, state["ring"])
    ebuf = jax.tree.map(jnp.asarray, state["ebuf"])
    return (params, momentum, key, fstate, cstate, client_master, z_server,
            ring, ebuf)
