"""Vmapped scenario sweeps: the paper's experiment grids in one jit each.

Sec. V sweeps {H, M, b2, SNR} over hundreds of rounds. Two kinds of knobs:

- **Shape-static** fields (``local_iters``, ``n_participating``, ``b2``,
  the aircomp/scheduling flags, ``batch_directions``…) change array shapes
  or program structure — each distinct combination is its own compile.
- **Value-dynamic** fields (``snr_db``, ``lr``, ``mu``, ``h_min``, and the
  seed) only change numbers — they vmap over a stacked config axis.

``run_sweep`` groups the scenario list by its static signature and runs
each group as ONE jitted, vmapped ``engine.experiment_core`` — e.g. the
paper's whole Fig. 1c/5 SNR curve family (one static shape × many SNRs ×
many seeds) is a single compiled program. Results land in ``results/`` as
long-format CSV (scenario, round, metric, value).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedZOConfig
from repro.core import strategy as strategy_mod
from repro.sim import engine
from repro.sim.store import ClientStore

# fields that vmap over the stacked config axis (everything else is static)
# (strategy selectors — cfg.strategy, prox_mu, dyn_alpha — are deliberately
# static: they change the traced round program, so they group/compile)
DYNAMIC_FIELDS = ("snr_db", "lr", "mu", "h_min")


def scenario_grid(**axes) -> list:
    """Cartesian product of config-override axes into scenario dicts:
    ``scenario_grid(local_iters=(1, 5), snr_db=(-5.0, 0.0))`` → 4 dicts."""
    names = list(axes)
    out = []
    for combo in itertools.product(*(axes[n] for n in names)):
        out.append(dict(zip(names, combo)))
    return out


def _freeze(key, value):
    """Hashable form of one static override: the static signature is a
    dict key (the compile-group index), so every value must hash.
    Sequences (e.g. shape lists) normalize to tuples; anything else
    unhashable raises naming the offending field instead of the opaque
    ``TypeError: unhashable type`` the group dict would throw."""
    if isinstance(value, (list, tuple)):
        return tuple(_freeze(key, v) for v in value)
    try:
        hash(value)
    except TypeError:
        raise TypeError(
            f"scenario field {key!r} has an unhashable static value of "
            f"type {type(value).__name__} — static overrides group "
            f"compiles by value, so pass a hashable (lists are "
            f"normalized to tuples automatically)") from None
    return value


def _split(scenario: dict):
    dyn = {k: v for k, v in scenario.items() if k in DYNAMIC_FIELDS
           or k == "seed"}
    static = tuple(sorted((k, _freeze(k, v)) for k, v in scenario.items()
                          if k not in dyn))
    return static, dyn


def run_sweep(loss_fn, params, store: ClientStore, base_cfg: FedZOConfig,
              scenarios: Sequence[dict], rounds: int, *,
              algo: Optional[str] = None, strategy=None, eval_fn=None,
              eval_every: int = 0, ring_size: int = 0,
              out_csv: Optional[str] = None, tracer=None) -> list:
    """Run every scenario (dicts of FedZOConfig overrides) for ``rounds``
    rounds; one jit per static-shape group, the dynamic axis vmapped.

    The algorithm resolves per static group — an explicit ``strategy=``
    (name or ``AlgoStrategy``; ``algo=`` is the deprecated string alias)
    applies to every scenario, otherwise each group's ``cfg.strategy``
    decides, so ``scenario_grid(strategy=("fedzo", "fedprox"))`` sweeps
    the algorithm itself as a static axis.

    ``tracer=`` (an ``obs.Tracer``) records one ``compile`` span per
    static-shape group plus an ``execute`` span per group run, so a grid's
    wall time decomposes into its per-program compiles — the number the
    static/dynamic split exists to control. (In-scan taps don't apply
    here: the per-scenario streams are interleaved under vmap.)

    Returns one record per scenario:
    ``{"scenario": dict, "strategy": name, "metrics": {name: [ring]
    np.ndarray}, "evals": {name: [n_evals] np.ndarray},
    "eval_rounds": np.ndarray}``.
    """
    # either store tier plugs in; the vmapped scan closes over a device
    # store, so a tiered HostStore materializes (bit-identical) here
    from repro.sim.tiered import resolve_store
    store = resolve_store(store, tier="resident")
    groups: dict = {}
    for s in scenarios:
        static, dyn = _split(s)
        groups.setdefault(static, []).append((s, dyn))

    records = []
    for static, members in groups.items():
        cfg = dataclasses.replace(base_cfg, **dict(static))
        strat = strategy_mod.resolve(strategy, algo, cfg)
        if strat.has_momentum(cfg):
            raise ValueError("sweeps keep the carry momentum-free; run "
                             "momentum configs through run_experiment")
        dyn_stack = {f: jnp.asarray(
            [m[1].get(f, getattr(base_cfg, f)) for m in members],
            jnp.float32) for f in DYNAMIC_FIELDS}
        seeds = jnp.asarray([m[1].get("seed", base_cfg.seed)
                             for m in members], jnp.uint32)

        def one(dyn, seed, cfg=cfg, strat=strat):
            c = dataclasses.replace(cfg, **dyn)
            key = jax.random.key(seed, impl=cfg.prng_impl)
            zstate = strat.init_state(params, c, store.n_clients)
            # the wireless scenario sweeps as a STATIC axis (the hashable
            # frozen ChannelModel changes the traced round program); its
            # chain state inits per scenario off the fold-in key, exactly
            # like run_experiment
            from repro.sim import channel as channel_lib
            cstate = (c.channel_model.init_state(
                store.n_clients, channel_lib.init_key(key))
                if c.channel_model is not None else None)
            out = engine.experiment_core(
                loss_fn, params, store, c, rounds, key, None, strategy=strat,
                zstate=zstate, channel_state=cstate, eval_fn=eval_fn,
                eval_every=eval_every, ring_size=ring_size)
            return out[6], out[7]

        jitted = jax.jit(jax.vmap(one))
        if tracer is not None:
            run = tracer.timed_compile(
                ("sweep", static, strat.name, rounds, len(members)),
                jitted, dyn_stack, seeds)
            with tracer.span("execute", group=str(dict(static)),
                             scenarios=len(members)):
                ring, ebuf = jax.block_until_ready(run(dyn_stack, seeds))
        else:
            ring, ebuf = jitted(dyn_stack, seeds)
        ring = jax.device_get(ring)
        ebuf = jax.device_get(ebuf)
        eval_rounds = (np.arange(0, rounds, eval_every)
                       if (eval_fn is not None and eval_every > 0)
                       else np.arange(0))
        for g, (scenario, _) in enumerate(members):
            records.append({
                "scenario": dict(scenario),
                "strategy": strat.name,
                "metrics": {k: np.asarray(v[g]) for k, v in ring.items()},
                "evals": {k: np.asarray(v[g]) for k, v in ebuf.items()},
                "eval_rounds": eval_rounds,
            })

    if out_csv:
        save_csv(records, out_csv, rounds=rounds, ring_size=ring_size)
    return records


def save_csv(records, path, *, rounds: int, ring_size: int = 0) -> None:
    """Long-format curve dump: scenario,round,metric,value — the raw
    material for the paper's figure-style plots. The scenario tag always
    carries a ``strategy=`` entry, so rows from multi-algorithm sweeps
    pooled into one results/ file stay distinguishable."""
    ring = min(rounds, ring_size) if ring_size else rounds
    start = rounds - ring
    with open(path, "w") as f:
        f.write("scenario,round,metric,value\n")
        for rec in records:
            items = dict(rec["scenario"])
            items.setdefault("strategy", rec.get("strategy", "fedzo"))
            tag = ";".join(f"{k}={v}" for k, v in sorted(items.items()))
            for name, arr in rec["metrics"].items():
                for t in range(start, rounds):
                    f.write(f"{tag},{t},{name},{float(arr[t % ring])}\n")
            for name, arr in rec["evals"].items():
                for i, t in enumerate(rec["eval_rounds"]):
                    f.write(f"{tag},{t},{name},{float(arr[i])}\n")
