"""SSM layers: RWKV-6 time/channel mix and a selective (Mamba-style) SSM.

RWKV-6 WKV (data-dependent per-channel decay, matrix state per head):
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
    o_t = r_t · (S_{t-1} + diag(u) k_t ⊗ v_t)
computed with a *chunked* parallel form: within a chunk of C tokens the
pairwise factor exp(l_{t-1} − l_j) (l = running log-decay) is formed directly
inside an einsum — with the log-decay clamped to [−DECAY_CLAMP, −1e−6] per
step and C=16, every factor stays within fp32 range (worst exponent
C·DECAY_CLAMP = 64 → e^64 ≈ 6e27 ≪ fp32 max). Chunks are chained by a
lax.scan carrying the [B, H, dk, dv] state. Decode is the one-step recurrence
on the cached state — O(1) per token, which is why rwkv6 runs long_500k
natively.

The selective SSM uses a diagonal state [B, d, n]: intra-chunk
lax.associative_scan + inter-chunk lax.scan, memory-bounded by the chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_norm, norm_fwd

WKV_CHUNK = 16
DECAY_CLAMP = 4.0
SSM_CHUNK = 256


# ---------------------------------------------------------------------------
# RWKV-6


def init_rwkv_tmix(rng, cfg, dtype):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(rng, 10)
    lora = 64 if d >= 512 else 16
    return {
        "mu": 0.5 * jnp.ones((5, d), dtype),        # static lerp for r,k,v,g,w
        "w0": jnp.zeros((d,), jnp.float32),          # decay bias
        "w_lora_a": dense_init(ks[0], d, lora, dtype, scale=0.01),
        "w_lora_b": dense_init(ks[1], lora, d, dtype, scale=0.01),
        "wr": dense_init(ks[2], d, d, dtype),
        "wk": dense_init(ks[3], d, d, dtype),
        "wv": dense_init(ks[4], d, d, dtype),
        "wg": dense_init(ks[5], d, d, dtype),
        "wo": dense_init(ks[6], d, d, dtype),
        "u": jnp.zeros((H, hd), jnp.float32),        # per-head bonus
        "ln_x": init_norm(d, "layernorm", dtype),    # group-norm on heads out
    }


def _tmix_project(p, cfg, x, x_prev):
    """Token-shift lerp + projections. x [B, T, d]; x_prev [B, T, d]."""
    delta = x_prev - x
    xr, xk, xv, xg, xw = (x + delta * p["mu"][i] for i in range(5))
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    r = (xr @ p["wr"]).reshape(B, T, H, hd)
    k = (xk @ p["wk"]).reshape(B, T, H, hd)
    v = (xv @ p["wv"]).reshape(B, T, H, hd)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay (the RWKV-6 signature feature)
    w_raw = p["w0"] + (jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)
    logw = -jnp.exp(w_raw)                           # < 0
    logw = jnp.clip(logw, -DECAY_CLAMP, -1e-6).reshape(B, T, H, hd)
    return r, k, v, g, logw


def wkv_chunked(r, k, v, logw, u, s0):
    """Chunked WKV. r/k/v [B, T, H, hd]; logw same; u [H, hd]; s0 [B, H, hd, hd].

    Returns (out [B, T, H, hd], s_final).
    """
    B, T, H, hd = r.shape
    C = min(WKV_CHUNK, T)
    pad = (-T) % C
    if pad:  # identity-pad: w=1 (logw=0), k=0 -> state passes through unchanged
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = zpad(r), zpad(k), zpad(v), zpad(logw)
    T_p = T + pad
    n = T_p // C
    rs = r.astype(jnp.float32).reshape(B, n, C, H, hd).transpose(1, 0, 3, 2, 4)
    ks_ = k.astype(jnp.float32).reshape(B, n, C, H, hd).transpose(1, 0, 3, 2, 4)
    vs = v.astype(jnp.float32).reshape(B, n, C, H, hd).transpose(1, 0, 3, 2, 4)
    lw = logw.reshape(B, n, C, H, hd).transpose(1, 0, 3, 2, 4)  # [n,B,H,C,hd]

    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)

    def body(s, inp):
        rc, kc, vc, lc = inp                       # [B, H, C, hd]
        l_inc = jnp.cumsum(lc, axis=2)             # inclusive running log decay
        l_exc = l_inc - lc                         # exclusive (l_{t-1})
        r_dec = rc * jnp.exp(l_exc)                # decay factors ≤ 1
        k_grow = kc * jnp.exp(-l_inc)              # bounded by C·CLAMP in exp
        A = jnp.einsum("bhtd,bhjd->bhtj", r_dec, k_grow)
        A = jnp.where(tri[None, None], A, 0.0)
        out = jnp.einsum("bhtj,bhjv->bhtv", A, vc)
        out = out + jnp.einsum("bhtd,bhdv->bhtv", r_dec, s)       # carry-in
        diag = jnp.einsum("bhtd,bhtd->bht", rc, kc * u[None, :, None])
        out = out + diag[..., None] * vc                           # bonus term
        l_tot = l_inc[:, :, -1:, :]                                # [B,H,1,hd]
        k_dec = kc * jnp.exp(l_tot - l_inc)
        s_new = jnp.exp(l_tot[:, :, 0])[..., None] * s + \
            jnp.einsum("bhjd,bhjv->bhdv", k_dec, vc)
        return s_new, out

    s_fin, outs = jax.lax.scan(body, s0.astype(jnp.float32), (rs, ks_, vs, lw))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, T_p, H, hd)[:, :T]
    return out, s_fin


def wkv_step(r, k, v, logw, u, s):
    """One decode step. r/k/v/logw [B, H, hd]; s [B, H, hd, hd]."""
    kv = jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32), v.astype(jnp.float32))
    out = jnp.einsum("bhd,bhdv->bhv", r.astype(jnp.float32),
                     s + u[None, ..., None] * kv)
    s_new = jnp.exp(logw.astype(jnp.float32))[..., None] * s + kv
    return out, s_new


def rwkv_tmix_fwd(p, cfg, x, *, state=None, x_prev_last=None):
    """Full-sequence time-mix. Returns (out, (s_final, last_x))."""
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    prev0 = jnp.zeros((B, 1, d), x.dtype) if x_prev_last is None \
        else x_prev_last[:, None, :]
    x_prev = jnp.concatenate([prev0, x[:, :-1]], axis=1)
    r, k, v, g, logw = _tmix_project(p, cfg, x, x_prev)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32) if state is None else state
    out, s_fin = wkv_chunked(r, k, v, logw, p["u"], s0)
    out = norm_fwd(p["ln_x"], out.reshape(B, T, d).astype(x.dtype), "layernorm")
    out = (out * g) @ p["wo"]
    return out, (s_fin, x[:, -1])


def rwkv_tmix_step(p, cfg, x, state, x_prev):
    """Decode step. x [B, 1, d]; state [B,H,hd,hd]; x_prev [B, d]."""
    B, _, d = x.shape
    r, k, v, g, logw = _tmix_project(p, cfg, x, x_prev[:, None])
    out, s_new = wkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0], p["u"], state)
    out = norm_fwd(p["ln_x"], out.reshape(B, 1, d).astype(x.dtype), "layernorm")
    out = (out * g) @ p["wo"]
    return out, (s_new, x[:, 0])


def init_rwkv_cmix(rng, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(rng, 3)
    return {"mu_k": 0.5 * jnp.ones((d,), dtype),
            "mu_r": 0.5 * jnp.ones((d,), dtype),
            "wk": dense_init(ks[0], d, cfg.d_ff, dtype),
            "wv": dense_init(ks[1], cfg.d_ff, d, dtype),
            "wr": dense_init(ks[2], d, d, dtype)}


def rwkv_cmix_fwd(p, x, x_prev):
    """Channel mix with token shift. x, x_prev [B, T, d]."""
    delta = x_prev - x
    xk = x + delta * p["mu_k"]
    xr = x + delta * p["mu_r"]
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (h @ p["wv"])


# ---------------------------------------------------------------------------
# Selective (Mamba-style) diagonal SSM — used by the Hymba hybrid.


def init_mamba(rng, cfg, dtype):
    d, n = cfg.d_model, cfg.ssm_state
    ks = jax.random.split(rng, 6)
    return {
        "w_in": dense_init(ks[0], d, 2 * d, dtype),     # x and gate z
        "w_bcdt": dense_init(ks[1], d, 2 * n + 1, dtype),
        "a_log": jnp.zeros((d, n), jnp.float32),         # A = -exp(a_log)
        "dt_bias": jnp.zeros((d,), jnp.float32),
        "d_skip": jnp.ones((d,), jnp.float32),
        "w_out": dense_init(ks[2], d, d, dtype),
    }


def _mamba_abc(p, xz):
    """Common projections. xz [B, T, d] (the `x` branch, pre-SSM)."""
    n = p["a_log"].shape[1]
    bcdt = xz @ p["w_bcdt"]
    Bm, Cm, dt = bcdt[..., :n], bcdt[..., n:2 * n], bcdt[..., 2 * n]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].mean())[..., None]
    A = -jnp.exp(p["a_log"])                                 # [d, n], < 0
    a = jnp.exp(dt[..., None] * A)                           # [B,T,d,n] decay
    b = (dt * Bm.astype(jnp.float32))[:, :, None, :] * xz.astype(jnp.float32)[..., None]
    return a, b, Cm


def diag_ssm_scan(a, b, s0, chunk=SSM_CHUNK):
    """h_t = a_t ⊙ h_{t-1} + b_t over T; a,b [B,T,d,n]; s0 [B,d,n].

    Intra-chunk associative_scan, inter-chunk lax.scan (bounds peak memory to
    O(chunk · d · n)). Returns (h [B,T,d,n], s_final).
    """
    B, T, d, n = a.shape
    C = min(chunk, T)
    pad = (-T) % C
    if pad:  # identity elements: a=1, b=0
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
    T_p = T + pad
    nc = T_p // C
    a_c = a.reshape(B, nc, C, d, n).transpose(1, 0, 2, 3, 4)
    b_c = b.reshape(B, nc, C, d, n).transpose(1, 0, 2, 3, 4)

    def combine(e1, e2):
        (a1, b1), (a2, b2) = e1, e2
        return a2 * a1, a2 * b1 + b2

    def body(s, inp):
        ac, bc = inp                        # [B, C, d, n]
        aa, bb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h = aa * s[:, None] + bb
        return h[:, -1], h

    s_fin, hs = jax.lax.scan(body, s0, (a_c, b_c))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, T_p, d, n)[:, :T]
    return h, s_fin


def mamba_fwd(p, cfg, x, *, state=None):
    """Full-sequence selective SSM. x [B, T, d] -> (out, s_final)."""
    B, T, d = x.shape
    xz = x @ p["w_in"]
    xs, z = xz[..., :d], xz[..., d:]
    xs = jax.nn.silu(xs)
    a, b, Cm = _mamba_abc(p, xs)
    s0 = jnp.zeros((B, d, cfg.ssm_state), jnp.float32) if state is None else state
    h, s_fin = diag_ssm_scan(a, b, s0)
    y = jnp.einsum("btdn,btn->btd", h, Cm.astype(jnp.float32))
    y = y + p["d_skip"] * xs.astype(jnp.float32)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return out, s_fin


def mamba_step(p, cfg, x, state):
    """One decode step. x [B, 1, d]; state [B, d, n]."""
    B, _, d = x.shape
    xz = x @ p["w_in"]
    xs, z = jax.nn.silu(xz[..., :d]), xz[..., d:]
    a, b, Cm = _mamba_abc(p, xs)
    s_new = a[:, 0] * state + b[:, 0]
    y = jnp.einsum("bdn,bn->bd", s_new, Cm[:, 0].astype(jnp.float32))
    y = y + p["d_skip"] * xs[:, 0].astype(jnp.float32)
    out = (y[:, None].astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return out, s_new
