"""Mixture-of-Experts with sort-based capacity dispatch and expert parallelism.

Design (see DESIGN.md §5):
- Tokens are sharded over the ``data`` mesh axis, experts over ``model``.
- Dispatch is *local masked*: every device routes its own tokens, keeps only
  assignments that land on its locally-owned experts, scatters them into a
  fixed-capacity [E_local, C, d] buffer (deterministic shapes under jit),
  runs the expert FFNs as batched matmuls, scatters back, and psums partial
  outputs over ``model``. No all_to_all is needed because activations are
  replicated along ``model`` (standard tensor-parallel residual stream).
- Expert weights are additionally FSDP-sharded over ``data`` on the FFN dim
  and all-gathered just-in-time (per layer, inside the scan) — this is what
  makes 671B fit 16 GB/chip.
- The token gather/scatter runs in ``top_k`` chunks of T tokens each so the
  transient dispatch values stay at [T, d] instead of [T·k, d] (7.5 GB/device
  for DeepSeek-V3 at train_4k — the chunking is load-bearing).

The same ``_route_and_compute`` body runs unsharded for CPU smoke tests
(mesh=None), so the distributed path is covered by the single-device oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _act, dense_init, init_mlp, mlp_fwd

try:  # jax.shard_map (with axis_names) landed after 0.4.x
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def _shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
        # pre-AxisType jax: every mesh axis is manual inside shard_map,
        # which is exactly what the axis_names sets used here request
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)


def init_moe(rng, cfg, dtype):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(rng, 5)
    def ew(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / fan_in ** 0.5).astype(dtype)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),  # router kept fp32
        "w_gate": ew(ks[1], (E, d, f), d),
        "w_up": ew(ks[2], (E, d, f), d),
        "w_down": ew(ks[3], (E, f, d), f),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, (cfg.moe_d_ff or cfg.d_ff) * cfg.n_shared_experts,
                               cfg.act, dtype)
    return p


def _capacity(n_tokens, cfg, e_local):
    per_expert = n_tokens * cfg.top_k / cfg.n_experts
    c = int(per_expert * cfg.capacity_factor) + 1
    return max(c, cfg.top_k)  # floor so tiny smoke shapes don't drop everything


def _route_and_compute(x_flat, p_router, w_gate, w_up, w_down, *,
                       cfg, e_offset, e_local, capacity):
    """Dispatch tokens in x_flat [T, d] to local experts [e_offset, e_offset+e_local).

    Returns (partial_out [T, d], (me, ce) partial load-balance stats).
    """
    T, d = x_flat.shape
    k = cfg.top_k
    # router matmul in activation dtype (upcasting x_flat materializes a
    # fp32 copy of the full token stream — 1.75 GB/layer at train_4k);
    # softmax accumulates in fp32 on the small [T, E] logits.
    logits = (x_flat @ p_router.astype(x_flat.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                    # [T, k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)  # renormalize top-k

    fe = idx.reshape(-1)                                    # [T*k] expert ids
    ft = jnp.tile(jnp.arange(T), (k, 1)).T.reshape(-1)      # token of each slot
    fg = gates.reshape(-1)
    is_local = (fe >= e_offset) & (fe < e_offset + e_local)
    le = jnp.where(is_local, fe - e_offset, e_local)        # e_local = dustbin
    order = jnp.argsort(le, stable=True)
    se, st, sg = le[order], ft[order], fg[order]
    counts = jnp.bincount(se, length=e_local + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * k) - starts[se]
    keep = (se < e_local) & (pos < capacity)
    se_c = jnp.where(keep, se, e_local)
    pos_c = jnp.where(keep, pos, 0)

    # chunked scatter: k rounds of [T]-sized gather+scatter keep transients at
    # [T, d] (instead of one [T*k, d] gather).
    se_k, st_k = se_c.reshape(k, T), st.reshape(k, T)
    pos_k, keep_k, sg_k = pos_c.reshape(k, T), keep.reshape(k, T), sg.reshape(k, T)
    buf = jnp.zeros((e_local + 1, capacity, d), x_flat.dtype)
    for j in range(k):
        vals = jnp.where(keep_k[j][:, None], x_flat[st_k[j]], 0)
        buf = buf.at[se_k[j], pos_k[j]].add(vals)
    h_in = buf[:e_local]                                     # [E_l, C, d]

    if cfg.act in ("swiglu", "geglu"):
        h = _act(jnp.einsum("ecd,edf->ecf", h_in, w_gate), cfg.act) \
            * jnp.einsum("ecd,edf->ecf", h_in, w_up)
    else:
        h = _act(jnp.einsum("ecd,edf->ecf", h_in, w_up), cfg.act)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down)          # [E_l, C, d]
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((1, capacity, d), out_buf.dtype)], axis=0)

    out = jnp.zeros((T, d), x_flat.dtype)
    for j in range(k):
        w = jnp.where(keep_k[j], sg_k[j], 0).astype(x_flat.dtype)
        out = out.at[st_k[j]].add(out_buf[se_k[j], pos_k[j]] * w[:, None])

    # Switch-style load-balance stats (partial; caller normalizes):
    me = jnp.sum(probs, axis=0)                              # [E]
    ce = jnp.bincount(fe, length=cfg.n_experts).astype(jnp.float32)
    return out, (me, ce)


def moe_fwd(p, cfg, x, mesh=None, data_axes=None, model_axis="model"):
    """x [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    E = cfg.n_experts
    if mesh is not None and data_axes is None:
        # batch axes of this mesh ('pod' is a batch axis for the forward)
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    if mesh is None:
        cap = _capacity(B * S, cfg, E)
        out, (me, ce) = _route_and_compute(
            x_flat, p["router"], p["w_gate"], p["w_up"], p["w_down"],
            cfg=cfg, e_offset=0, e_local=E, capacity=cap)
    else:
        n_data = 1
        for a in data_axes:
            n_data *= mesh.shape[a]
        n_model = mesh.shape[model_axis]
        shard_tokens = (B * S) % n_data == 0 and n_data > 1
        fsdp_axis = data_axes[-1]

        if shard_tokens:
            # train/prefill layout: tokens over data, experts over model,
            # expert FFN dim FSDP over data (gathered just-in-time).
            e_local = max(E // n_model, 1)
            cap = _capacity((B * S) // n_data, cfg, e_local)

            def body(xl, rw, wg, wu, wd):
                wg = jax.lax.all_gather(wg, fsdp_axis, axis=2, tiled=True)
                wu = jax.lax.all_gather(wu, fsdp_axis, axis=2, tiled=True)
                wd = jax.lax.all_gather(wd, fsdp_axis, axis=1, tiled=True)
                e_off = jax.lax.axis_index(model_axis) * e_local
                out, (me, ce) = _route_and_compute(
                    xl, rw, wg, wu, wd, cfg=cfg, e_offset=e_off,
                    e_local=e_local, capacity=cap)
                out = jax.lax.psum(out, model_axis)
                me = jax.lax.psum(me, data_axes)
                ce = jax.lax.psum(ce, data_axes)
                return out, me, ce

            dspec = P(data_axes if len(data_axes) > 1 else data_axes[0], None)
            in_specs = (dspec, P(None, None),
                        P(model_axis, None, fsdp_axis),
                        P(model_axis, None, fsdp_axis),
                        P(model_axis, fsdp_axis, None))
        else:
            # decode layout (tiny token count): tokens replicated across the
            # mesh, experts sharded over ``model`` (weights resharded by the
            # in_specs from their FSDP at-rest layout). out needs the psum
            # over model; the router stats are computed identically on every
            # device (replicated tokens + replicated router) so they need no
            # collective at all.
            e_local = max(E // n_model, 1)
            cap = _capacity(B * S, cfg, e_local)

            def body(xl, rw, wg, wu, wd):
                e_off = jax.lax.axis_index(model_axis) * e_local
                out, (me, ce) = _route_and_compute(
                    xl, rw, wg, wu, wd, cfg=cfg, e_offset=e_off,
                    e_local=e_local, capacity=cap)
                out = jax.lax.psum(out, model_axis)
                return out, me, ce

            dspec = P(None, None)
            in_specs = (dspec, P(None, None),
                        P(model_axis, None, None), P(model_axis, None, None),
                        P(model_axis, None, None))

        out, me, ce = _shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(dspec, P(None), P(None)),
            axis_names={*data_axes, model_axis},
        )(x_flat, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    n_tok = B * S
    me = me / n_tok
    ce = ce / (n_tok * cfg.top_k)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    out = out.reshape(B, S, d)
    if cfg.n_shared_experts:
        out = out + mlp_fwd(p["shared"], x, cfg.act)
    return out, aux
