"""Models for the paper's own experiments (Sec. V).

- ``softmax_regression``: the Fashion-MNIST multinomial classifier of Sec V-B.
- ``smallcnn_*``: a trainable LeNet-style SmallCNN — the Sec V-B CNN track
  (conv → pool → conv → pool → linear head), a first-class FedZO *workload*
  via ``repro.workloads.neural``.
- ``cnn_*``: a small conv classifier standing in for the pretrained
  CIFAR-10 network of Carlini & Wagner used in Sec V-A (the container is
  offline; we train this surrogate in-repo on synthetic CIFAR-like data).
- ``cw_attack_loss``: the Carlini-Wagner federated black-box attack loss,
  Eq. (21) — the *optimization variable* is the shared perturbation x, the
  classifier is a frozen black box.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mean_xent(logits, y):
    """Mean cross-entropy of integer labels — shared by every classifier
    loss here so they stay numerically identical formulations."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)


# ---------------------------------------------------------------------------
# softmax regression (Sec V-B)


def softmax_init(rng, n_features=784, n_classes=10):
    return {"w": jnp.zeros((n_features, n_classes), jnp.float32),
            "b": jnp.zeros((n_classes,), jnp.float32)}


def softmax_logits(params, x):
    return x @ params["w"] + params["b"]


def softmax_loss(params, batch):
    """batch: {"x": [B, F], "y": [B]} -> mean cross-entropy."""
    return mean_xent(softmax_logits(params, batch["x"]), batch["y"])


def softmax_accuracy(params, batch):
    pred = jnp.argmax(softmax_logits(params, batch["x"]), axis=-1)
    return jnp.mean((pred == batch["y"]).astype(jnp.float32))


# ---------------------------------------------------------------------------
# trainable LeNet-style SmallCNN (Sec V-B CNN track)


def _conv_pool(h, w):
    h = jax.lax.conv_general_dilated(h, w, (1, 1), "SAME",
                                     dimension_numbers=("NHWC", "HWIO",
                                                        "NHWC"))
    h = jax.nn.relu(h)
    return jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def smallcnn_init(rng, image_shape=(28, 28, 1), n_classes=10, width=8):
    """LeNet-style trainable classifier: 3×3 conv → 2×2 pool, twice, then a
    linear head. ``image_shape`` is free (grayscale F-MNIST-like by
    default); the head size follows the two VALID pools (s → ⌊s/2⌋)."""
    h, w, cin = image_shape
    fh, fw = (h // 2) // 2, (w // 2) // 2
    ks = jax.random.split(rng, 3)

    def conv(k, ci, co):
        return (jax.random.normal(k, (3, 3, ci, co), jnp.float32)
                * (2.0 / (9 * ci)) ** 0.5)

    return {"c1": conv(ks[0], cin, width),
            "c2": conv(ks[1], width, 2 * width),
            "w": jax.random.normal(ks[2], (2 * width * fh * fw, n_classes),
                                   jnp.float32) * 0.01,
            "b": jnp.zeros((n_classes,), jnp.float32)}


def smallcnn_logits(params, images):
    """images [B, H, W, C] in [0, 1] -> logits [B, n_classes]."""
    h = images * 2.0 - 1.0
    h = _conv_pool(h, params["c1"])
    h = _conv_pool(h, params["c2"])
    return h.reshape(h.shape[0], -1) @ params["w"] + params["b"]


def smallcnn_loss(params, batch):
    return mean_xent(smallcnn_logits(params, batch["x"]), batch["y"])


def smallcnn_accuracy(params, batch):
    pred = jnp.argmax(smallcnn_logits(params, batch["x"]), axis=-1)
    return jnp.mean((pred == batch["y"]).astype(jnp.float32))


# ---------------------------------------------------------------------------
# small CNN classifier (black-box target for the attack task)


def cnn_init(rng, n_classes=10, width=16):
    ks = jax.random.split(rng, 4)
    def conv(k, cin, cout):
        return (jax.random.normal(k, (3, 3, cin, cout), jnp.float32)
                * (2.0 / (9 * cin)) ** 0.5)
    return {"c1": conv(ks[0], 3, width), "c2": conv(ks[1], width, 2 * width),
            "w": jax.random.normal(ks[2], (2 * width * 8 * 8, n_classes),
                                   jnp.float32) * 0.01,
            "b": jnp.zeros((n_classes,), jnp.float32)}


def cnn_logits(params, images):
    """images [B, 32, 32, 3] in [0, 1] -> logits [B, C]."""
    h = images * 2.0 - 1.0
    h = jax.lax.conv_general_dilated(h, params["c1"], (1, 1), "SAME",
                                     dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = jax.nn.relu(h)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = jax.lax.conv_general_dilated(h, params["c2"], (1, 1), "SAME",
                                     dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = jax.nn.relu(h)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    return h @ params["w"] + params["b"]


def cnn_loss(params, batch):
    return mean_xent(cnn_logits(params, batch["x"]), batch["y"])


# ---------------------------------------------------------------------------
# Carlini-Wagner federated black-box attack loss (Eq. 21)


def _tanh_example(z, x):
    """Adversarial example 0.5*tanh(atanh(2z-1) + x) in [0,1] image space.

    The paper writes images in [-1/2, 1/2]; we keep [0,1] pixels and map
    through the same bijection.
    """
    z_c = jnp.clip(z * 2.0 - 1.0, -1 + 1e-6, 1 - 1e-6)
    return 0.5 * (jnp.tanh(jnp.arctanh(z_c) + x) + 1.0)


def cw_attack_loss(x_pert, batch, classifier_params, c=1.0):
    """Eq. (21): mean over the device's images of
       max(Φ_y(adv) - max_{j≠y} Φ_j(adv), 0) + c‖adv - z‖².

    ``x_pert`` [32*32*3] is the shared perturbation (the FedZO variable);
    the classifier is queried as a black box (no grad taken through it by
    the ZO optimizer).
    """
    z, y = batch["x"], batch["y"]
    adv = _tanh_example(z, x_pert.reshape(1, 32, 32, 3))
    logits = cnn_logits(classifier_params, adv)
    conf_true = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    masked = logits - 1e9 * jax.nn.one_hot(y, logits.shape[-1])
    conf_best_other = jnp.max(masked, axis=-1)
    margin = jnp.maximum(conf_true - conf_best_other, 0.0)
    dist = jnp.sum(jnp.square(adv - z), axis=(1, 2, 3))
    return jnp.mean(margin + c * dist)


def attack_success(x_pert, batch, classifier_params):
    z, y = batch["x"], batch["y"]
    adv = _tanh_example(z, x_pert.reshape(1, 32, 32, 3))
    pred = jnp.argmax(cnn_logits(classifier_params, adv), axis=-1)
    return jnp.mean((pred != y).astype(jnp.float32))
