"""Attention variants: GQA/MQA self-attention, DeepSeek MLA, cross-attention.

Cache conventions
-----------------
Self-attention KV caches are ring buffers of width ``W``:
  {"k": [B, W, Hkv, D], "v": [B, W, Hkv, D]}
``W == seq_len`` gives the ordinary full cache (decode_32k); ``W == window``
gives the sliding-window cache used for ``long_500k`` on attention archs.
``pos`` is the absolute position of the token being decoded; the slot written
is ``pos % W`` and the validity mask is derived from ``pos`` alone, so decode
steps are pure functions of (cache, pos).

MLA caches the *compressed* latent (c_kv ++ k_rope) — [B, W, kv_lora + rope] —
and uses the absorbed-matmul decode form, which is what makes the
DeepSeek-V3 @ 32k/500k decode shapes fit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (apply_rope, chunked_attention,
                                 decode_attention, dense_init, init_norm,
                                 norm_fwd, rope_angles)

# ---------------------------------------------------------------------------
# GQA self-attention


def init_attention(rng, cfg, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = {"wq": dense_init(ks[0], d, hq * hd, dtype),
         "wk": dense_init(ks[1], d, hkv * hd, dtype),
         "wv": dense_init(ks[2], d, hkv * hd, dtype),
         "wo": dense_init(ks[3], hq * hd, d, dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_norm(hd, "rmsnorm", dtype)
        p["k_norm"] = init_norm(hd, "rmsnorm", dtype)
    return p


def _qkv(p, cfg, x):
    B, S, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, hq, hd)
    k = k.reshape(B, S, hkv, hd)
    v = v.reshape(B, S, hkv, hd)
    if cfg.qk_norm:
        q = norm_fwd(p["q_norm"], q)
        k = norm_fwd(p["k_norm"], k)
    return q, k, v


def attention_fwd(p, cfg, x, *, positions=None, window=None, causal=True):
    """Full-sequence attention (train / prefill / encoder). x [B, S, d]."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, cfg, x)
    cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    w = cfg.sliding_window if window is None else window
    out = chunked_attention(q, k, v, causal=causal, window=w if causal else 0)
    return out.reshape(B, S, -1) @ p["wo"]


def init_kv_cache(cfg, batch, width, dtype):
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, width, hkv, hd), dtype),
            "v": jnp.zeros((batch, width, hkv, hd), dtype)}


def attention_prefill(p, cfg, x, width):
    """Prefill: full attention + return the cache of the last ``width`` KVs."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    positions = jnp.arange(S)[None, :]
    cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    out = chunked_attention(q, k, v, causal=True, window=cfg.sliding_window)
    out = out.reshape(B, S, -1) @ p["wo"]
    if width >= S:  # straight copy into slots [0, S)
        pad = width - S
        cache = {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                 "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))}
    else:  # ring layout: slot = pos % width for the last `width` positions
        last_k, last_v = k[:, -width:], v[:, -width:]
        shift = S % width
        cache = {"k": jnp.roll(last_k, shift, axis=1),
                 "v": jnp.roll(last_v, shift, axis=1)}
    return out, cache


def attention_decode(p, cfg, x, cache, pos, *, window=0):
    """One-token decode. x [B, 1, d]; pos scalar int32 absolute position."""
    B = x.shape[0]
    W = cache["k"].shape[1]
    q, k, v = _qkv(p, cfg, x)
    cos, sin = rope_angles(pos[None, None], cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    slot = pos % W
    k_cache = cache["k"].at[:, slot].set(k[:, 0])
    v_cache = cache["v"].at[:, slot].set(v[:, 0])
    idx = jnp.arange(W)
    valid = (idx <= pos) | (pos >= W)
    if window:
        w = min(window, W)
        # ring buffer holds the last W positions; restrict to last `w`
        age = (slot - idx) % W
        valid &= age < w
    out = decode_attention(q, k_cache, v_cache, valid[None, :].repeat(B, 0))
    out = out.reshape(B, 1, -1) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Cross-attention (VLM image layers; enc-dec decoder)


def init_cross_attention(rng, cfg, dtype, kv_dim=None):
    d, hq, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    kv_dim = kv_dim or d
    ks = jax.random.split(rng, 4)
    return {"wq": dense_init(ks[0], d, hq * hd, dtype),
            "wk": dense_init(ks[1], kv_dim, hq * hd, dtype),
            "wv": dense_init(ks[2], kv_dim, hq * hd, dtype),
            "wo": dense_init(ks[3], hq * hd, d, dtype),
            "q_norm": init_norm(hd, "rmsnorm", dtype),
            "k_norm": init_norm(hd, "rmsnorm", dtype)}


def cross_kv(p, cfg, memory):
    """Precompute cross K/V from encoder/vision memory [B, S_m, kv_dim]."""
    B, Sm, _ = memory.shape
    hq, hd = cfg.n_heads, cfg.head_dim
    k = norm_fwd(p["k_norm"], (memory @ p["wk"]).reshape(B, Sm, hq, hd))
    v = (memory @ p["wv"]).reshape(B, Sm, hq, hd)
    return {"k": k, "v": v}


def cross_attention_fwd(p, cfg, x, kv):
    """x [B, S, d] attends over precomputed cross KV (no causality)."""
    B, S, _ = x.shape
    hq, hd = cfg.n_heads, cfg.head_dim
    q = norm_fwd(p["q_norm"], (x @ p["wq"]).reshape(B, S, hq, hd))
    out = chunked_attention(q, kv["k"], kv["v"], causal=False)
    return out.reshape(B, S, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# DeepSeek Multi-head Latent Attention


def init_mla(rng, cfg, dtype):
    m, d, h = cfg.mla, cfg.d_model, cfg.n_heads
    ks = jax.random.split(rng, 6)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": init_norm(m.q_lora_rank, "rmsnorm", dtype),
        "wq_b": dense_init(ks[1], m.q_lora_rank, h * qk_dim, dtype),
        "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_dim, dtype),
        "kv_norm": init_norm(m.kv_lora_rank, "rmsnorm", dtype),
        "wk_b": dense_init(ks[3], m.kv_lora_rank, h * m.qk_nope_dim, dtype),
        "wv_b": dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "wo": dense_init(ks[5], h * m.v_head_dim, d, dtype),
    }


def _mla_q(p, cfg, x, positions):
    m, h = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    q = norm_fwd(p["q_norm"], x @ p["wq_a"]) @ p["wq_b"]
    q = q.reshape(B, S, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    cos, sin = rope_angles(positions, m.qk_rope_dim, cfg.rope_theta)
    return q_nope, apply_rope(q_rope, cos, sin)


def mla_fwd(p, cfg, x, *, positions=None, window=0):
    """Train/prefill MLA in decompressed form. Returns (out, latent)."""
    m, h = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    kv = x @ p["wkv_a"]
    c_kv = norm_fwd(p["kv_norm"], kv[..., :m.kv_lora_rank])
    k_rope = kv[..., m.kv_lora_rank:]
    cos, sin = rope_angles(positions, m.qk_rope_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # 1 shared rope head
    k_nope = (c_kv @ p["wk_b"]).reshape(B, S, h, m.qk_nope_dim)
    v = (c_kv @ p["wv_b"]).reshape(B, S, h, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, S, h, m.qk_rope_dim))], axis=-1)
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    out = chunked_attention(q, k, v, causal=True, window=window, scale=scale)
    out = out.reshape(B, S, -1) @ p["wo"]
    latent = jnp.concatenate([c_kv, k_rope[:, :, 0]], axis=-1)
    return out, latent


def init_mla_cache(cfg, batch, width, dtype):
    m = cfg.mla
    return {"latent": jnp.zeros((batch, width, m.kv_lora_rank + m.qk_rope_dim),
                                dtype)}


def mla_prefill(p, cfg, x, width):
    B, S, _ = x.shape
    out, latent = mla_fwd(p, cfg, x)
    if width >= S:
        latent = jnp.pad(latent, ((0, 0), (0, width - S), (0, 0)))
    else:
        latent = jnp.roll(latent[:, -width:], S % width, axis=1)
    return out, {"latent": latent}


def mla_decode(p, cfg, x, cache, pos, *, window=0):
    """Absorbed-form decode: scores/values against the latent cache only."""
    m, h = cfg.mla, cfg.n_heads
    B = x.shape[0]
    W = cache["latent"].shape[1]
    q_nope, q_rope = _mla_q(p, cfg, x, pos[None, None])
    kv = x @ p["wkv_a"]
    c_kv = norm_fwd(p["kv_norm"], kv[..., :m.kv_lora_rank])
    k_rope = kv[..., m.kv_lora_rank:]
    cos, sin = rope_angles(pos[None, None], m.qk_rope_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0]
    new_latent = jnp.concatenate([c_kv, k_rope], axis=-1)[:, 0]
    slot = pos % W
    latent = cache["latent"].at[:, slot].set(new_latent)
    c_cache = latent[..., :m.kv_lora_rank]          # [B, W, r]
    r_cache = latent[..., m.kv_lora_rank:]          # [B, W, rope]
    # absorb W_k^b into q: q_eff[b,h,r] = sum_n q_nope[b,h,n] * wk_b[r, h, n]
    wk_b = p["wk_b"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    q_eff = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    s = jnp.einsum("bhr,bwr->bhw", q_eff, c_cache.astype(jnp.float32))
    s = s + jnp.einsum("bhr,bwr->bhw", q_rope[:, 0].astype(jnp.float32),
                       r_cache.astype(jnp.float32))
    idx = jnp.arange(W)
    valid = (idx <= pos) | (pos >= W)
    if window:
        age = (slot - idx) % W
        valid &= age < min(window, W)
    s = jnp.where(valid[None, None], s * scale, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    out_c = jnp.einsum("bhw,bwr->bhr", pr, c_cache.astype(jnp.float32))
    wv_b = p["wv_b"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhv->bhv", out_c, wv_b.astype(jnp.float32))
    out = out.reshape(B, 1, h * m.v_head_dim).astype(x.dtype) @ p["wo"]
    return out, {"latent": latent}
