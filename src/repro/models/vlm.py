"""VLM backbone (Llama-3.2-Vision style): self-attn decoder with interleaved
gated cross-attention layers consuming stubbed vision embeddings.

Structure: G groups of (cross_attn_every - 1) self layers + 1 gated cross
layer, scanned over groups (outer) and self layers (inner). The vision
frontend (ViT + projector) is the allowed stub — ``input_specs`` supplies
post-projector patch embeddings [B, n_img, d_model].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import transformer as tfm
from repro.models.layers import (embed_fwd, init_embed, init_mlp, init_norm,
                                 mlp_fwd, norm_fwd, softmax_xent, unembed_fwd)
from repro.utils.shardutil import constrain, constrain_batch, dp_axes


def _n_groups(cfg):
    assert cfg.n_layers % cfg.cross_attn_every == 0
    return cfg.n_layers // cfg.cross_attn_every


def init_cross_block(rng, cfg, dtype):
    ks = jax.random.split(rng, 3)
    return {"norm1": init_norm(cfg.d_model, cfg.norm, dtype),
            "norm2": init_norm(cfg.d_model, cfg.norm, dtype),
            "xattn": attn.init_cross_attention(ks[0], cfg, dtype),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype),
            "gate_attn": jnp.zeros((), jnp.float32),
            "gate_mlp": jnp.zeros((), jnp.float32)}


def init_params(rng, cfg):
    dtype = jnp.dtype(cfg.dtype)
    G = _n_groups(cfg)
    n_self = cfg.cross_attn_every - 1
    ks = jax.random.split(rng, 4)

    def group(k):
        return tfm._stack_init(k, n_self, lambda kk: tfm.init_block(kk, cfg, dtype))

    return {
        "embed": init_embed(ks[0], cfg.vocab, cfg.d_model, dtype, cfg.tie_embeddings),
        "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
        "self_blocks": tfm._stack_init(ks[1], G, group),        # [G, n_self, ...]
        "cross_blocks": tfm._stack_init(
            ks[2], G, lambda k: init_cross_block(k, cfg, dtype)),  # [G, ...]
    }


def param_specs(cfg):
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def cross_block_fwd(p, cfg, h, vision):
    hn = norm_fwd(p["norm1"], h, cfg.norm)
    kv = attn.cross_kv(p["xattn"], cfg, vision)
    ga = jnp.tanh(p["gate_attn"]).astype(h.dtype)  # keep the carry dtype
    gm = jnp.tanh(p["gate_mlp"]).astype(h.dtype)
    h = h + ga * attn.cross_attention_fwd(p["xattn"], cfg, hn, kv)
    hn = norm_fwd(p["norm2"], h, cfg.norm)
    return h + gm * mlp_fwd(p["mlp"], hn, cfg.act)


def backbone(params, cfg, h, vision, mesh=None, window=None):
    def group_body(h, lp):
        selfs, cross = lp

        def self_body(h, sp):
            h, _ = tfm.block_fwd(sp, cfg, h, mesh, window=window)
            return h, None

        h, _ = jax.lax.scan(self_body, h, selfs)
        h = cross_block_fwd(cross, cfg, h, vision)
        return constrain_batch(h, mesh), None

    h, _ = jax.lax.scan(group_body, h,
                        (params["self_blocks"], params["cross_blocks"]))
    return norm_fwd(params["final_norm"], h, cfg.norm)


def loss_fn(params, batch, cfg, mesh=None, n_groups=1):
    h = embed_fwd(params["embed"], batch["tokens"], mesh)
    h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    h = constrain_batch(h, mesh)
    hf = backbone(params, cfg, h, batch["vision_embeds"], mesh)
    logits = unembed_fwd(params["embed"], hf, cfg.tie_embeddings, cfg.vocab)
    return softmax_xent(logits, batch["labels"], n_groups)


# ---------------------------------------------------------------------------
# prefill / decode


def init_cache(cfg, batch, width):
    dtype = jnp.dtype(cfg.dtype)
    G = _n_groups(cfg)
    n_self = cfg.cross_attn_every - 1
    kv = attn.init_kv_cache(cfg, batch, width, dtype)
    self_kv = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (G, n_self) + x.shape), kv)
    hq, hd = cfg.n_heads, cfg.head_dim
    xkv = jnp.zeros((G, batch, cfg.n_frontend_tokens, hq, hd), dtype)
    return {"self": self_kv, "cross_k": xkv, "cross_v": xkv}


def prefill(params, tokens, vision, cfg, width, mesh=None):
    h = embed_fwd(params["embed"], tokens, mesh)
    h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)

    def group_body(h, lp):
        selfs, cross = lp

        def self_body(h, sp):
            hn = norm_fwd(sp["norm1"], h, cfg.norm)
            o, c = attn.attention_prefill(sp["attn"], cfg, hn, width)
            h = h + o
            hn = norm_fwd(sp["norm2"], h, cfg.norm)
            h = h + mlp_fwd(sp["mlp"], hn, cfg.act)
            return h, c

        h, self_c = jax.lax.scan(self_body, h, selfs)
        kv = attn.cross_kv(cross["xattn"], cfg, vision)
        h = cross_block_fwd(cross, cfg, h, vision)
        return h, (self_c, kv["k"], kv["v"])

    h, (self_c, xk, xv) = jax.lax.scan(
        group_body, h, (params["self_blocks"], params["cross_blocks"]))
    hf = norm_fwd(params["final_norm"], h, cfg.norm)
    logits = unembed_fwd(params["embed"], hf[:, -1:], cfg.tie_embeddings, cfg.vocab)
    return logits[:, 0], {"self": self_c, "cross_k": xk, "cross_v": xv}


def decode_step(params, token, cache, pos, cfg, mesh=None, window=0):
    h = embed_fwd(params["embed"], token, mesh)
    h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)

    def group_body(h, lp):
        selfs, cross, self_c, xk, xv = lp

        def self_body(h, inp):
            sp, c = inp
            hn = norm_fwd(sp["norm1"], h, cfg.norm)
            o, nc = attn.attention_decode(sp["attn"], cfg, hn, c, pos,
                                          window=window)
            h = h + o
            hn = norm_fwd(sp["norm2"], h, cfg.norm)
            return h + mlp_fwd(sp["mlp"], hn, cfg.act), nc

        h, new_self = jax.lax.scan(self_body, h, (selfs, self_c))
        hn = norm_fwd(cross["norm1"], h, cfg.norm)
        B = h.shape[0]
        hq, hd = cfg.n_heads, cfg.head_dim
        q = norm_fwd(cross["xattn"]["q_norm"],
                     (hn @ cross["xattn"]["wq"]).reshape(B, 1, hq, hd))
        from repro.models.layers import chunked_attention
        o = chunked_attention(q, xk, xv, causal=False)
        h = h + jnp.tanh(cross["gate_attn"]).astype(h.dtype) * (
            o.reshape(B, 1, -1) @ cross["xattn"]["wo"])
        hn = norm_fwd(cross["norm2"], h, cfg.norm)
        h = h + jnp.tanh(cross["gate_mlp"]).astype(h.dtype) *             mlp_fwd(cross["mlp"], hn, cfg.act)
        return h, new_self

    h, new_self = jax.lax.scan(
        group_body, h,
        (params["self_blocks"], params["cross_blocks"],
         cache["self"], cache["cross_k"], cache["cross_v"]))
    hf = norm_fwd(params["final_norm"], h, cfg.norm)
    logits = unembed_fwd(params["embed"], hf, cfg.tie_embeddings, cfg.vocab)
    return logits[:, 0], {"self": new_self, "cross_k": cache["cross_k"],
                          "cross_v": cache["cross_v"]}
