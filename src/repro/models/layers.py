"""Shared building blocks: norms, RoPE, MLPs, embeddings, chunked attention.

Everything is functional: ``init_*`` builds a params dict, ``*_fwd`` applies
it. Params are plain nested dicts so the whole model is a pytree that FedZO's
estimator can perturb leafwise.

The attention here is the pure-jnp *chunked online-softmax* (flash-style)
implementation — it never materializes the [S, S] score matrix, which is what
makes the 32k-prefill dry-runs lowerable. The Pallas kernel in
``repro.kernels.flash_attention`` is the TPU-runtime twin of this math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# init helpers


def dense_init(rng, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_norm(d, kind="rmsnorm", dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_fwd(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE


def rope_angles(positions, head_dim, theta):
    """positions [*, S] -> (cos, sin) [*, S, head_dim/2] in fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [B, S, H, D]; cos/sin [B?, S, D/2] broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # broadcast [B, S, 1, D/2] over heads
    c = jnp.expand_dims(cos, -2).astype(jnp.float32)
    s = jnp.expand_dims(sin, -2).astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP


def init_mlp(rng, d_model, d_ff, act, dtype):
    ks = jax.random.split(rng, 3)
    if act in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], d_model, d_ff, dtype),
                "w_up": dense_init(ks[1], d_model, d_ff, dtype),
                "w_down": dense_init(ks[2], d_ff, d_model, dtype)}
    return {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
            "w_down": dense_init(ks[1], d_ff, d_model, dtype)}


def _act(h, act):
    if act == "relu_sq":
        return jnp.square(jax.nn.relu(h))
    return {"gelu": jax.nn.gelu, "silu": jax.nn.silu,
            "swiglu": jax.nn.silu, "geglu": jax.nn.gelu}[act](h)


def mlp_fwd(p, x, act):
    if act in ("swiglu", "geglu"):
        h = _act(x @ p["w_gate"], act) * (x @ p["w_up"])
    else:
        h = _act(x @ p["w_up"], act)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding


VOCAB_PAD = 32  # pad vocab rows so the table always shards over `model`


def padded_vocab(vocab):
    return vocab + (-vocab) % VOCAB_PAD


def init_embed(rng, vocab, d_model, dtype, tie):
    """Embedding (+ unembedding) with the vocab dim padded to a multiple of
    VOCAB_PAD: a non-divisible vocab (seamless: 256206) would otherwise leave
    the logits un-shardable over ``model`` — that single detail cost
    180 GB/device at train_4k (§Perf iteration 1). Padded logit columns are
    masked to -inf in unembed_fwd."""
    vp = padded_vocab(vocab)
    ks = jax.random.split(rng, 2)
    p = {"tok": dense_init(ks[0], vp, d_model, dtype, scale=0.02)}
    if not tie:
        p["unembed"] = dense_init(ks[1], d_model, vp, dtype)
    return p


def embed_fwd(p, tokens, mesh=None):
    """Token embedding lookup (vocab-parallel table: rows over ``model``).

    Plain take: with the table sharded P("model", None), GSPMD partitions the
    gather as a local masked lookup + psum over model — the Megatron
    vocab-parallel pattern. (Tables sharded on *both* dims crash the XLA
    partitioner when a manual mesh axis is present; the P("model", None)
    layout avoids that and matches the vocab-parallel logits matmul.)
    """
    del mesh
    return jnp.take(p["tok"], tokens, axis=0)


def unembed_fwd(p, x, tie, vocab=None):
    """Logits in param dtype (fp32 accumulation happens inside the loss
    reductions). Padded vocab columns are masked to a large negative so both
    the softmax and any argmax sampling ignore them."""
    w = p["tok"].T if tie else p["unembed"]
    logits = x @ w
    vp = logits.shape[-1]
    if vocab is not None and vocab != vp:
        v_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
        logits = jnp.where(v_iota < vocab, logits,
                           jnp.asarray(NEG_INF, logits.dtype))
    return logits


def softmax_xent(logits, labels, n_groups=1):
    """Token cross-entropy; logits [.., V] (any float), labels int [..].

    The label pick is a masked reduction (iota == label) rather than a
    take_along_axis: under GSPMD a gather across a model-sharded vocab dim
    would all-gather the logits (tens of GB/device at 1M tokens); the masked
    sum partitions as partial-sum + scalar psum. fp32 accumulation happens
    inside the reductions so no fp32 copy of the logits is materialized.

    ``n_groups > 1`` splits the leading (batch) dim into G groups and returns
    per-group mean losses [G] — the cross-silo pods of the multi-pod round
    (each group's tokens live on one pod; the group means are the only
    cross-pod reduction).
    """
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(lf - m[..., None]), axis=-1))
    v_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.where(v_iota == labels[..., None], lf, 0.0)
    ll = jnp.sum(picked, axis=-1)
    tok_loss = lse - ll
    if n_groups == 1:
        return jnp.mean(tok_loss)
    return jnp.mean(tok_loss.reshape(n_groups, -1), axis=1)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — pure jnp, partitions under GSPMD.


NEG_INF = -1e30


def chunked_attention(q, k, v, *, causal, q_offset=0, kv_offset=0,
                      window=0, kv_chunk=1024, scale=None):
    """Online-softmax attention without materializing [Sq, Sk] scores.

    q [B, Sq, Hq, D]; k/v [B, Sk, Hkv, D(v)]. GQA via head repetition on the
    score einsum (no materialized repeat). ``window`` > 0 applies a sliding
    window over absolute positions; ``*_offset`` give absolute positions of
    q[0] / k[0].
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, G, D)
    q_pos = q_offset + jnp.arange(Sq)

    kv_chunk = min(kv_chunk, Sk)
    n_chunks = (Sk + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, kv_chunk, Hkv, D)
    vc = v.reshape(B, n_chunks, kv_chunk, Hkv, Dv)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        ki, vi, ci = inp
        k_pos = kv_offset + ci * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, ki.astype(jnp.float32))
        mask = jnp.ones((Sq, kv_chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        if pad:
            mask &= (k_pos < kv_offset + Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vi.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, G, Sq, Dv), jnp.float32)
    if n_chunks == 1:
        (m, l, acc), _ = body((m0, l0, acc0),
                              (kc[:, 0], vc[:, 0], jnp.asarray(0)))
    else:
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, acc0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dv)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length_mask, scale=None):
    """Single-token attention against a (possibly ring-buffer) cache.

    q [B, 1, Hq, D]; caches [B, W, Hkv, D]; length_mask [B, W] bool marks
    valid cache slots (handles both unfilled slots and ring-buffer wrap).
    """
    B, _, Hq, D = q.shape
    _, W, Hkv, Dv = v_cache.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    s = jnp.where(length_mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, Dv).astype(q.dtype)
