"""Encoder-decoder backbone (SeamlessM4T style): bidirectional encoder over
stubbed audio-frame embeddings + causal decoder with per-layer cross-attention.

The audio frontend (mel spectrogram + conv codec) is the allowed stub —
``input_specs`` supplies frame embeddings [B, n_frames, d_model].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import transformer as tfm
from repro.models.layers import (embed_fwd, init_embed, init_mlp, init_norm,
                                 mlp_fwd, norm_fwd, softmax_xent, unembed_fwd)
from repro.utils.shardutil import constrain_batch


def init_enc_block(rng, cfg, dtype):
    ks = jax.random.split(rng, 2)
    return {"norm1": init_norm(cfg.d_model, cfg.norm, dtype),
            "norm2": init_norm(cfg.d_model, cfg.norm, dtype),
            "attn": attn.init_attention(ks[0], cfg, dtype),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)}


def init_dec_block(rng, cfg, dtype):
    ks = jax.random.split(rng, 3)
    return {"norm1": init_norm(cfg.d_model, cfg.norm, dtype),
            "norm2": init_norm(cfg.d_model, cfg.norm, dtype),
            "norm3": init_norm(cfg.d_model, cfg.norm, dtype),
            "attn": attn.init_attention(ks[0], cfg, dtype),
            "xattn": attn.init_cross_attention(ks[1], cfg, dtype),
            "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dtype)}


def init_params(rng, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    return {
        "embed": init_embed(ks[0], cfg.vocab, cfg.d_model, dtype, cfg.tie_embeddings),
        "enc_blocks": tfm._stack_init(
            ks[1], cfg.encoder_layers, lambda k: init_enc_block(k, cfg, dtype)),
        "dec_blocks": tfm._stack_init(
            ks[2], cfg.n_layers, lambda k: init_dec_block(k, cfg, dtype)),
        "enc_norm": init_norm(cfg.d_model, cfg.norm, dtype),
        "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
    }


def param_specs(cfg):
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def encode(params, cfg, src_embeds, mesh=None):
    """Bidirectional encoder over frame embeddings [B, S_src, d]."""
    src_embeds = constrain_batch(src_embeds, mesh)
    def body(h, lp):
        hn = norm_fwd(lp["norm1"], h, cfg.norm)
        h = h + attn.attention_fwd(lp["attn"], cfg, hn, causal=False)
        hn = norm_fwd(lp["norm2"], h, cfg.norm)
        return h + mlp_fwd(lp["mlp"], hn, cfg.act), None

    h, _ = jax.lax.scan(body, src_embeds, params["enc_blocks"])
    return norm_fwd(params["enc_norm"], h, cfg.norm)


def _dec_block(lp, cfg, h, memory_kv, window=None):
    hn = norm_fwd(lp["norm1"], h, cfg.norm)
    h = h + attn.attention_fwd(lp["attn"], cfg, hn, window=window)
    hn = norm_fwd(lp["norm2"], h, cfg.norm)
    h = h + attn.cross_attention_fwd(lp["xattn"], cfg, hn, memory_kv)
    hn = norm_fwd(lp["norm3"], h, cfg.norm)
    return h + mlp_fwd(lp["mlp"], hn, cfg.act)


def loss_fn(params, batch, cfg, mesh=None, n_groups=1):
    memory = encode(params, cfg, batch["src_embeds"], mesh)
    h = embed_fwd(params["embed"], batch["tokens"], mesh)
    h = constrain_batch(h, mesh)

    def body(h, lp):
        kv = attn.cross_kv(lp["xattn"], cfg, memory)
        return _dec_block(lp, cfg, h, kv), None

    h, _ = jax.lax.scan(body, h, params["dec_blocks"])
    hf = norm_fwd(params["final_norm"], h, cfg.norm)
    logits = unembed_fwd(params["embed"], hf, cfg.tie_embeddings, cfg.vocab)
    return softmax_xent(logits, batch["labels"], n_groups)


# ---------------------------------------------------------------------------
# prefill / decode


def init_cache(cfg, batch, width):
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    kv = attn.init_kv_cache(cfg, batch, width, dtype)
    self_kv = jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), kv)
    hq, hd = cfg.n_heads, cfg.head_dim
    xkv = jnp.zeros((L, batch, cfg.n_frontend_tokens, hq, hd), dtype)
    return {"self": self_kv, "cross_k": xkv, "cross_v": xkv}


def prefill(params, tokens, src_embeds, cfg, width, mesh=None):
    """Encode source + prefill decoder self/cross caches."""
    memory = encode(params, cfg, src_embeds, mesh)
    h = embed_fwd(params["embed"], tokens, mesh)
    h = constrain_batch(h, mesh)

    def body(h, lp):
        kv = attn.cross_kv(lp["xattn"], cfg, memory)
        hn = norm_fwd(lp["norm1"], h, cfg.norm)
        o, c = attn.attention_prefill(lp["attn"], cfg, hn, width)
        h = h + o
        hn = norm_fwd(lp["norm2"], h, cfg.norm)
        h = h + attn.cross_attention_fwd(lp["xattn"], cfg, hn, kv)
        hn = norm_fwd(lp["norm3"], h, cfg.norm)
        h = h + mlp_fwd(lp["mlp"], hn, cfg.act)
        return h, (c, kv["k"], kv["v"])

    h, (self_c, xk, xv) = jax.lax.scan(body, h, params["dec_blocks"])
    hf = norm_fwd(params["final_norm"], h, cfg.norm)
    logits = unembed_fwd(params["embed"], hf[:, -1:], cfg.tie_embeddings, cfg.vocab)
    return logits[:, 0], {"self": self_c, "cross_k": xk, "cross_v": xv}


def decode_step(params, token, cache, pos, cfg, mesh=None, window=0):
    from repro.models.layers import chunked_attention
    h = embed_fwd(params["embed"], token, mesh)
    hq, hd = cfg.n_heads, cfg.head_dim

    def body(h, inp):
        lp, c, xk, xv = inp
        hn = norm_fwd(lp["norm1"], h, cfg.norm)
        o, nc = attn.attention_decode(lp["attn"], cfg, hn, c, pos,
                                      window=window)
        h = h + o
        hn = norm_fwd(lp["norm2"], h, cfg.norm)
        B = h.shape[0]
        q = norm_fwd(lp["xattn"]["q_norm"],
                     (hn @ lp["xattn"]["wq"]).reshape(B, 1, hq, hd))
        o = chunked_attention(q, xk, xv, causal=False)
        h = h + o.reshape(B, 1, -1) @ lp["xattn"]["wo"]
        hn = norm_fwd(lp["norm3"], h, cfg.norm)
        return h + mlp_fwd(lp["mlp"], hn, cfg.act), nc

    h, new_self = jax.lax.scan(
        body, h, (params["dec_blocks"], cache["self"],
                  cache["cross_k"], cache["cross_v"]))
    hf = norm_fwd(params["final_norm"], h, cfg.norm)
    logits = unembed_fwd(params["embed"], hf, cfg.tie_embeddings, cfg.vocab)
    return logits[:, 0], {"self": new_self, "cross_k": cache["cross_k"],
                          "cross_v": cache["cross_v"]}
