"""Decoder-only transformer assembly for the dense / moe / hybrid / ssm families.

Layers are *stacked* (one pytree with a leading [L] axis per leaf) and driven
by ``jax.lax.scan`` so the HLO stays O(1) in depth — this is what keeps the
100-layer dry-run compiles fast. Heterogeneous stacks (DeepSeek's 3 leading
dense layers, Hymba's parallel branches) are separate stacked groups.

Three entry points per model:
  loss(params, batch, cfg, mesh)           — train forward (FedZO queries this)
  prefill(params, tokens, cfg, width, mesh) — build decode caches
  decode(params, token, cache, pos, cfg, mesh) — one token, updates caches

FedZO never calls jax.grad, so there is no remat policy here: forward-only
training IS the paper's memory story (see DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm
from repro.models.layers import (embed_fwd, init_embed, init_mlp, init_norm,
                                 mlp_fwd, norm_fwd, softmax_xent, unembed_fwd)
from repro.models.moe import init_moe, moe_fwd
from repro.utils.shardutil import constrain, constrain_batch, dp_axes


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# per-layer init


def init_block(rng, cfg, dtype, *, moe_layer=False):
    ks = jax.random.split(rng, 4)
    p = {"norm1": init_norm(cfg.d_model, cfg.norm, dtype),
         "norm2": init_norm(cfg.d_model, cfg.norm, dtype)}
    if cfg.family == "ssm":  # rwkv6
        p["tmix"] = ssm.init_rwkv_tmix(ks[0], cfg, dtype)
        p["cmix"] = ssm.init_rwkv_cmix(ks[1], cfg, dtype)
        return p
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
    if cfg.family == "hybrid":
        p["mamba"] = ssm.init_mamba(ks[1], cfg, dtype)
    if moe_layer:
        p["moe"] = init_moe(ks[2], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def _stack_init(rng, n, init_fn):
    if n == 0:
        return None
    ps = [init_fn(jax.random.fold_in(rng, i)) for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def init_params(rng, cfg):
    dtype = _dtype(cfg)
    ks = jax.random.split(rng, 6)
    p = {"embed": init_embed(ks[0], cfg.vocab, cfg.d_model, dtype,
                             cfg.tie_embeddings),
         "final_norm": init_norm(cfg.d_model, cfg.norm, dtype)}
    n_moe = 0
    if cfg.n_experts:
        n_moe = cfg.n_layers - cfg.n_dense_layers
        p["dense_blocks"] = _stack_init(
            ks[1], cfg.n_dense_layers,
            lambda k: init_block(k, cfg, dtype, moe_layer=False))
        p["moe_blocks"] = _stack_init(
            ks[2], n_moe, lambda k: init_block(k, cfg, dtype, moe_layer=True))
    else:
        p["blocks"] = _stack_init(
            ks[1], cfg.n_layers, lambda k: init_block(k, cfg, dtype))
    if cfg.mtp:
        p["mtp_block"] = init_block(ks[3], cfg, dtype, moe_layer=False)
        p["mtp_norm"] = init_norm(cfg.d_model, cfg.norm, dtype)
    return p


def param_specs(cfg):
    """ShapeDtypeStructs of the params — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# block forward (full sequence)


def block_fwd(p, cfg, h, mesh, *, moe_layer=False, window=None):
    """Pre-norm block on h [B, S, d]. Returns (h, aux)."""
    h = constrain_batch(h, mesh)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        o, _ = ssm.rwkv_tmix_fwd(p["tmix"], cfg, norm_fwd(p["norm1"], h, cfg.norm))
        h = h + o
        hn = norm_fwd(p["norm2"], h, cfg.norm)
        B, T, d = hn.shape
        prev = jnp.concatenate([jnp.zeros((B, 1, d), hn.dtype), hn[:, :-1]], 1)
        h = h + ssm.rwkv_cmix_fwd(p["cmix"], hn, prev)
        return h, aux

    hn = norm_fwd(p["norm1"], h, cfg.norm)
    if cfg.mla is not None:
        o, _ = attn.mla_fwd(p["attn"], cfg, hn,
                            window=(window or 0))
    else:
        o = attn.attention_fwd(p["attn"], cfg, hn, window=window)
    if cfg.family == "hybrid":
        o2, _ = ssm.mamba_fwd(p["mamba"], cfg, hn)
        o = 0.5 * (o + o2)
    h = h + o
    hn = norm_fwd(p["norm2"], h, cfg.norm)
    if moe_layer:
        o, aux = moe_fwd(p["moe"], cfg, hn, mesh=mesh)
    else:
        o = mlp_fwd(p["mlp"], hn, cfg.act)
    return h + o, aux


def _scan_blocks(stacked, cfg, h, mesh, *, moe_layer=False, window=None):
    if stacked is None:
        return h, jnp.zeros((), jnp.float32)

    def body(carry, lp):
        h, aux = carry
        h, a = block_fwd(lp, cfg, h, mesh, moe_layer=moe_layer, window=window)
        return (h, aux + a), None

    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), stacked)
    return h, aux


def backbone(params, cfg, h, mesh, *, window=None):
    """Embeddings already applied; h [B, S, d] -> (h_normed, aux)."""
    if cfg.n_experts:
        h, a1 = _scan_blocks(params.get("dense_blocks"), cfg, h, mesh,
                             window=window)
        h, a2 = _scan_blocks(params["moe_blocks"], cfg, h, mesh,
                             moe_layer=True, window=window)
        aux = a1 + a2
    else:
        h, aux = _scan_blocks(params["blocks"], cfg, h, mesh, window=window)
    return norm_fwd(params["final_norm"], h, cfg.norm), aux


def loss_fn(params, batch, cfg, mesh=None, n_groups=1):
    """Mean next-token cross entropy (+ MoE aux, + MTP aux). FedZO's F(x, ξ).

    ``n_groups > 1`` returns per-pod-group losses [G] (multi-pod round)."""
    tokens, labels = batch["tokens"], batch["labels"]
    h = embed_fwd(params["embed"], tokens, mesh)
    h = constrain_batch(h, mesh)
    if cfg.d_model >= 1024:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)  # gemma-style scale
    hf, aux = backbone(params, cfg, h, mesh)
    logits = unembed_fwd(params["embed"], hf, cfg.tie_embeddings, cfg.vocab)
    logits = constrain(logits, mesh, dp_axes(mesh), None, "model") \
        if mesh is not None else logits
    loss = softmax_xent(logits, labels, n_groups)
    if cfg.mtp:
        # multi-token prediction: one extra block predicts token t+2 from
        # (h_t, embed(token_{t+1})) — DeepSeek-V3 style, depth 1.
        emb_next = jnp.concatenate([h[:, 1:], h[:, -1:]], axis=1)
        h2 = norm_fwd(params["mtp_norm"], hf + emb_next, cfg.norm)
        h2, _ = block_fwd(params["mtp_block"], cfg, h2, mesh)
        logits2 = unembed_fwd(params["embed"], h2, cfg.tie_embeddings, cfg.vocab)
        labels2 = jnp.concatenate([labels[:, 1:], labels[:, -1:]], axis=1)
        loss = loss + 0.3 * softmax_xent(logits2, labels2, n_groups)
    return loss + aux


# ---------------------------------------------------------------------------
# tiny classifier head (the neural FedZO workload's transformer track,
# DESIGN.md §11): images chopped into patch tokens, the SAME stacked-block
# backbone as the LM, mean-pooled into a linear head. No vocab, no causal
# masking requirement beyond what the blocks impose — FedZO only ever sees
# ``classifier_loss(params, batch) -> scalar``.


def init_classifier(rng, cfg, *, n_patches, patch_dim, n_classes):
    """Patch-embed + positional table + cfg.n_layers stacked blocks + head."""
    from repro.models.layers import dense_init

    dtype = _dtype(cfg)
    ks = jax.random.split(rng, 3)
    return {"patch": dense_init(ks[0], patch_dim, cfg.d_model, dtype),
            "pos": jnp.zeros((n_patches, cfg.d_model), dtype),
            "blocks": _stack_init(ks[1], cfg.n_layers,
                                  lambda k: init_block(k, cfg, dtype)),
            "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
            "head": dense_init(ks[2], cfg.d_model, n_classes, dtype)}


def classifier_logits(params, cfg, x):
    """x [B, n_patches·patch_dim] (or [B, n_patches, patch_dim]) → logits."""
    n_p, d = params["pos"].shape
    h = x.reshape(x.shape[0], n_p, -1).astype(_dtype(cfg))
    h = h @ params["patch"] + params["pos"]
    h, _ = _scan_blocks(params["blocks"], cfg, h, None)
    h = norm_fwd(params["final_norm"], h, cfg.norm)
    return jnp.mean(h, axis=1) @ params["head"]


def classifier_loss(params, batch, cfg):
    from repro.models.simple import mean_xent

    return mean_xent(classifier_logits(params, cfg, batch["x"]), batch["y"])


def classifier_accuracy(params, batch, cfg):
    pred = jnp.argmax(classifier_logits(params, cfg, batch["x"]), axis=-1)
    return jnp.mean((pred == batch["y"]).astype(jnp.float32))


# ---------------------------------------------------------------------------
# prefill / decode with caches


def init_cache(cfg, batch, width):
    """Zeroed decode cache for one block family, stacked over layers."""
    dtype = _dtype(cfg)
    d = cfg.d_model

    def one(moe_layer=False):
        if cfg.family == "ssm":
            return {"s": jnp.zeros((batch, cfg.n_heads, cfg.head_dim,
                                    cfg.head_dim), jnp.float32),
                    "ts_att": jnp.zeros((batch, d), dtype),
                    "ts_ffn": jnp.zeros((batch, d), dtype)}
        if cfg.mla is not None:
            c = attn.init_mla_cache(cfg, batch, width, dtype)
        else:
            c = attn.init_kv_cache(cfg, batch, width, dtype)
        if cfg.family == "hybrid":
            c["s"] = jnp.zeros((batch, d, cfg.ssm_state), jnp.float32)
        return c

    def stack(n, **kw):
        if n == 0:
            return None
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape),
                            one(**kw))

    if cfg.n_experts:
        return {"dense": stack(cfg.n_dense_layers),
                "moe": stack(cfg.n_layers - cfg.n_dense_layers)}
    return {"blocks": stack(cfg.n_layers)}


def block_prefill(p, cfg, h, width, mesh, *, moe_layer=False):
    """Full-seq forward that also returns this block's decode cache."""
    h = constrain_batch(h, mesh)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        hn = norm_fwd(p["norm1"], h, cfg.norm)
        o, (s, last1) = ssm.rwkv_tmix_fwd(p["tmix"], cfg, hn)
        h = h + o
        hn = norm_fwd(p["norm2"], h, cfg.norm)
        B, T, d = hn.shape
        prev = jnp.concatenate([jnp.zeros((B, 1, d), hn.dtype), hn[:, :-1]], 1)
        h = h + ssm.rwkv_cmix_fwd(p["cmix"], hn, prev)
        return h, {"s": s, "ts_att": last1, "ts_ffn": hn[:, -1]}, aux

    hn = norm_fwd(p["norm1"], h, cfg.norm)
    if cfg.mla is not None:
        o, cache = attn.mla_prefill(p["attn"], cfg, hn, width)
    else:
        o, cache = attn.attention_prefill(p["attn"], cfg, hn, width)
    if cfg.family == "hybrid":
        o2, s = ssm.mamba_fwd(p["mamba"], cfg, hn)
        o = 0.5 * (o + o2)
        cache["s"] = s
    h = h + o
    hn = norm_fwd(p["norm2"], h, cfg.norm)
    if moe_layer:
        o, aux = moe_fwd(p["moe"], cfg, hn, mesh=mesh)
    else:
        o = mlp_fwd(p["mlp"], hn, cfg.act)
    return h + o, cache, aux


def block_decode(p, cfg, h, cache, pos, mesh, *, moe_layer=False, window=0):
    h = constrain_batch(h, mesh)
    if cfg.family == "ssm":
        hn = norm_fwd(p["norm1"], h, cfg.norm)
        o, (s, last1) = ssm.rwkv_tmix_step(p["tmix"], cfg, hn, cache["s"],
                                           cache["ts_att"])
        h = h + o
        hn = norm_fwd(p["norm2"], h, cfg.norm)
        h = h + ssm.rwkv_cmix_fwd(p["cmix"], hn, cache["ts_ffn"][:, None])
        return h, {"s": s, "ts_att": last1, "ts_ffn": hn[:, 0]}

    hn = norm_fwd(p["norm1"], h, cfg.norm)
    if cfg.mla is not None:
        o, new_cache = attn.mla_decode(p["attn"], cfg, hn,
                                       {"latent": cache["latent"]}, pos,
                                       window=window)
    else:
        o, new_cache = attn.attention_decode(
            p["attn"], cfg, hn, {"k": cache["k"], "v": cache["v"]}, pos,
            window=window or cfg.sliding_window)
    if cfg.family == "hybrid":
        o2, s = ssm.mamba_step(p["mamba"], cfg, hn, cache["s"])
        o = 0.5 * (o + o2)
        new_cache["s"] = s
    h = h + o
    hn = norm_fwd(p["norm2"], h, cfg.norm)
    if moe_layer:
        o, _ = moe_fwd(p["moe"], cfg, hn, mesh=mesh)
    else:
        o = mlp_fwd(p["mlp"], hn, cfg.act)
    return h + o, new_cache


def _scan_prefill(stacked, cfg, h, width, mesh, **kw):
    if stacked is None:
        return h, None, jnp.zeros((), jnp.float32)

    def body(carry, lp):
        h, aux = carry
        h, cache, a = block_prefill(lp, cfg, h, width, mesh, **kw)
        return (h, aux + a), cache

    (h, aux), caches = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                    stacked)
    return h, caches, aux


def _scan_decode(stacked, caches, cfg, h, pos, mesh, **kw):
    if stacked is None:
        return h, None

    def body(h, inp):
        lp, c = inp
        h, nc = block_decode(lp, cfg, h, c, pos, mesh, **kw)
        return h, nc

    return jax.lax.scan(body, h, (stacked, caches))


def prefill(params, tokens, cfg, width, mesh=None):
    """tokens [B, S] -> (last-token logits [B, V], cache)."""
    h = embed_fwd(params["embed"], tokens, mesh)
    h = constrain_batch(h, mesh)
    if cfg.d_model >= 1024:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    if cfg.n_experts:
        h, c1, _ = _scan_prefill(params.get("dense_blocks"), cfg, h, width, mesh)
        h, c2, _ = _scan_prefill(params["moe_blocks"], cfg, h, width, mesh,
                                 moe_layer=True)
        cache = {"dense": c1, "moe": c2}
    else:
        h, c, _ = _scan_prefill(params["blocks"], cfg, h, width, mesh)
        cache = {"blocks": c}
    hf = norm_fwd(params["final_norm"], h, cfg.norm)
    logits = unembed_fwd(params["embed"], hf[:, -1:], cfg.tie_embeddings, cfg.vocab)
    return logits[:, 0], cache


def decode_step(params, token, cache, pos, cfg, mesh=None, window=0):
    """token [B, 1] int32; pos scalar int32 -> (logits [B, V], new cache)."""
    h = embed_fwd(params["embed"], token, mesh)
    h = constrain_batch(h, mesh)
    if cfg.d_model >= 1024:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    if cfg.n_experts:
        h, c1 = _scan_decode(params.get("dense_blocks"), cache["dense"], cfg,
                             h, pos, mesh, window=window)
        h, c2 = _scan_decode(params["moe_blocks"], cache["moe"], cfg, h, pos,
                             mesh, moe_layer=True, window=window)
        new_cache = {"dense": c1, "moe": c2}
    else:
        h, c = _scan_decode(params["blocks"], cache["blocks"], cfg, h, pos,
                            mesh, window=window)
        new_cache = {"blocks": c}
    hf = norm_fwd(params["final_norm"], h, cfg.norm)
    logits = unembed_fwd(params["embed"], hf, cfg.tie_embeddings, cfg.vocab)
    return logits[:, 0], new_cache
