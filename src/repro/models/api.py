"""Unified model API over all families.

``build(cfg)`` returns a ``Model`` bundle of pure functions:
  init(rng) -> params                       param_specs() -> ShapeDtypeStructs
  loss(params, batch, mesh) -> scalar       (what FedZO queries)
  prefill(params, batch, width, mesh) -> (logits, cache)
  decode(params, batch, cache, pos, mesh, window) -> (logits, cache)
  init_cache(batch_size, width) -> zeroed cache
  batch_shapes(shape_cfg) -> {name: (shape, dtype)} for input_specs/dry-run

Batches are dicts; LM batches have "tokens"/"labels", VLM adds
"vision_embeds", enc-dec adds "src_embeds" (the stubbed modality frontends).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer, vlm


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    param_specs: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable
    batch_shapes: Callable


def _lm_batch_shapes(cfg, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"tokens": ((B, S), jnp.int32), "labels": ((B, S), jnp.int32)}
    if shape.kind == "prefill":
        return {"tokens": ((B, S), jnp.int32)}
    return {"tokens": ((B, 1), jnp.int32)}  # decode


def build(cfg: ModelConfig) -> Model:
    dtype = jnp.dtype(cfg.dtype)

    if cfg.family == "vlm":
        def batch_shapes(shape):
            d = _lm_batch_shapes(cfg, shape)
            d["vision_embeds"] = ((shape.global_batch, cfg.n_frontend_tokens,
                                   cfg.d_model), dtype)
            return d

        return Model(
            cfg=cfg,
            init=lambda rng: vlm.init_params(rng, cfg),
            param_specs=lambda: vlm.param_specs(cfg),
            loss=lambda p, b, mesh=None, n_groups=1: vlm.loss_fn(p, b, cfg, mesh, n_groups),
            prefill=lambda p, b, width, mesh=None: vlm.prefill(
                p, b["tokens"], b["vision_embeds"], cfg, width, mesh),
            decode=lambda p, b, cache, pos, mesh=None, window=0: vlm.decode_step(
                p, b["tokens"], cache, pos, cfg, mesh, window),
            init_cache=lambda batch, width: vlm.init_cache(cfg, batch, width),
            batch_shapes=batch_shapes,
        )

    if cfg.family == "encdec":
        def batch_shapes(shape):
            d = _lm_batch_shapes(cfg, shape)
            # source frames scale with the target length for train/prefill
            n_src = cfg.n_frontend_tokens
            d["src_embeds"] = ((shape.global_batch, n_src, cfg.d_model), dtype)
            if shape.kind == "decode":
                del d["src_embeds"]  # decode runs off the cached cross-KV
            return d

        return Model(
            cfg=cfg,
            init=lambda rng: encdec.init_params(rng, cfg),
            param_specs=lambda: encdec.param_specs(cfg),
            loss=lambda p, b, mesh=None, n_groups=1: encdec.loss_fn(p, b, cfg, mesh, n_groups),
            prefill=lambda p, b, width, mesh=None: encdec.prefill(
                p, b["tokens"], b["src_embeds"], cfg, width, mesh),
            decode=lambda p, b, cache, pos, mesh=None, window=0: encdec.decode_step(
                p, b["tokens"], cache, pos, cfg, mesh, window),
            init_cache=lambda batch, width: encdec.init_cache(cfg, batch, width),
            batch_shapes=batch_shapes,
        )

    # dense / moe / hybrid / ssm share the decoder-only assembly
    return Model(
        cfg=cfg,
        init=lambda rng: transformer.init_params(rng, cfg),
        param_specs=lambda: transformer.param_specs(cfg),
        loss=lambda p, b, mesh=None, n_groups=1: transformer.loss_fn(p, b, cfg, mesh, n_groups),
        prefill=lambda p, b, width, mesh=None: transformer.prefill(
            p, b["tokens"], cfg, width, mesh),
        decode=lambda p, b, cache, pos, mesh=None, window=0: transformer.decode_step(
            p, b["tokens"], cache, pos, cfg, mesh, window),
        init_cache=lambda batch, width: transformer.init_cache(cfg, batch, width),
        batch_shapes=lambda shape: _lm_batch_shapes(cfg, shape),
    )


def make_batch(model: Model, shape: ShapeConfig, rng):
    """Concrete random batch matching batch_shapes (smoke tests / examples)."""
    out = {}
    for i, (name, (shp, dt)) in enumerate(sorted(model.batch_shapes(shape).items())):
        k = jax.random.fold_in(rng, i)
        if jnp.issubdtype(dt, jnp.integer):
            out[name] = jax.random.randint(k, shp, 0, model.cfg.vocab, dt)
        else:
            out[name] = jax.random.normal(k, shp, dt)
    return out


def decode_width(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """KV-cache width used for a decode shape: full for 32k, sliding window
    for long_500k on attention archs (DESIGN.md long-context policy)."""
    if shape.seq_len > 65_536:
        return min(cfg.long_context_window, shape.seq_len)
    return shape.seq_len
