"""DeepSeek-V3 671B — MLA + 1 shared / 256 routed top-8 MoE + MTP.

[arXiv:2412.19437] 61L (first 3 dense, d_ff=18432), d_model=7168, 128 heads,
MLA (q_lora 1536, kv_lora 512, nope 128, rope 64, v 128), MoE intermediate
2048, vocab=129280, MTP depth 1.
"""
from repro.configs.base import ModelConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe", source="arXiv:2412.19437 (DeepSeek-V3)",
    n_layers=61, d_model=7168, d_ff=18432, vocab=129280,
    n_heads=128, n_kv_heads=128, head_dim=128,
    n_experts=256, top_k=8, n_shared_experts=1, moe_d_ff=2048, n_dense_layers=3,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    mtp=True,
)
