"""Config system: architecture + input-shape + run configs, with a registry.

Every assigned architecture gets one module in this package defining
``CONFIG = ModelConfig(...)`` with the exact assigned hyperparameters and a
source citation. ``reduced()`` derives the CPU smoke-test variant of the same
family (≤2 layers, d_model ≤ 512, ≤4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    source: str                 # citation for the assigned config
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0      # 0 = full causal; >0 = window size
    long_context_window: int = 16_384  # window used for long_500k decode
    # ffn flavor
    act: str = "swiglu"          # swiglu | geglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0            # expert intermediate size
    n_dense_layers: int = 0      # leading dense layers (DeepSeek: 3)
    router_aux_coef: float = 0.001
    capacity_factor: float = 1.25
    # MLA / MTP (DeepSeek)
    mla: Optional[MLAConfig] = None
    mtp: bool = False            # multi-token-prediction extra head
    # SSM
    ssm_kind: str = ""           # rwkv6 | mamba (hybrid uses mamba)
    ssm_state: int = 0
    # enc-dec / VLM
    encoder_layers: int = 0
    cross_attn_every: int = 0    # vlm: 1 cross-attn layer per this many self layers
    n_frontend_tokens: int = 0   # stubbed modality tokens (audio frames / image patches)
    # misc
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def kv_groups(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: tiny dims, same structure."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=min(self.d_model, 128),
            d_ff=min(self.d_ff, 256),
            vocab=min(self.vocab, 512),
            dtype="float32",
            long_context_window=64,
        )
        if self.n_heads:
            kw["n_heads"] = min(self.n_heads, 4)
            kw["n_kv_heads"] = min(self.n_kv_heads, 2)
            kw["head_dim"] = min(self.head_dim, 32) if self.head_dim else 0
        if self.n_experts:
            kw["n_experts"] = min(self.n_experts, 4)
            kw["top_k"] = min(self.top_k, 2)
            kw["moe_d_ff"] = min(self.moe_d_ff, 128)
            kw["n_dense_layers"] = min(self.n_dense_layers, 1)
            # ample capacity so smoke tests see no token dropping (capacity
            # drops legitimately differ between batched prefill and decode)
            kw["capacity_factor"] = 4.0
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
        if self.ssm_state:
            kw["ssm_state"] = min(self.ssm_state, 8)
        if self.encoder_layers:
            kw["encoder_layers"] = 2
        if self.cross_attn_every:
            kw["cross_attn_every"] = 2
        if self.n_frontend_tokens:
            kw["n_frontend_tokens"] = 16
        if self.sliding_window:
            kw["sliding_window"] = 32
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass
class FedZOConfig:
    """Paper Algorithm 1 hyperparameters."""
    n_devices: int = 50        # N
    n_participating: int = 10  # M (<= N); == N means full participation
    local_iters: int = 5       # H
    lr: float = 1e-3           # eta
    mu: float = 1e-3           # smoothing step size
    b1: int = 25               # data minibatch size
    b2: int = 20               # number of perturbation directions
    estimator: str = "sphere"  # sphere (paper) | gaussian | rademacher | coordinate
    central: bool = False      # two-sided difference (O(mu^2) bias, +1 query)
    direction_dtype: str = "float32"  # bfloat16 halves perturbation HBM traffic
    # flat-buffer hot path (DESIGN.md §7): fuse perturb/update into Pallas
    # streaming kernels over one padded 1-D parameter buffer, directions
    # regenerated in-kernel from the counter convention
    flat_params: bool = False
    # direction convention for the *pytree* path: "tree" (per-leaf threefry,
    # the original) or "counter" (the flat path's convention — used to prove
    # old-vs-new trajectory equivalence). The flat path is always "counter".
    # The batched-direction (wide) path additionally accepts "block": one
    # PRNG call per iterate for the whole [b2, n_pad] direction block.
    direction_conv: str = "tree"
    # batched-direction ("wide") local phase for the simulation engine
    # (repro.sim, DESIGN.md §9): materialize each iterate's b2 directions as
    # ONE [b2, n_pad] block, run the b2 perturbed forwards as one vmap, and
    # apply the update as one matvec. Statistically identical to the loop
    # estimator; bit-identical directions when direction_conv="tree".
    batch_directions: bool = False
    # PRNG implementation for the simulation engine's key chain
    # (threefry2x32 | rbg | unsafe_rbg). threefry is the default everywhere;
    # rbg/unsafe_rbg trade threefry's splittability guarantees for ~2-4x
    # faster in-scan direction generation (simulation-scale only).
    prng_impl: str = "threefry2x32"
    flat_block_rows: int = 0   # kernel grid rows per block; 0 = default (512)
    server_momentum: float = 0.0  # FedOpt-style momentum on aggregated deltas
    seed: int = 0
    # AirComp (Section IV); snr_db=None disables the channel simulation
    aircomp: bool = False
    snr_db: float = 0.0        # P / sigma_w^2
    h_min: float = 0.8
    # channel-truncation scheduling (Sec. IV-A): draw Rayleigh channels per
    # round and exclude clients with |h| < h_min from the aggregation (mask
    # into both the mean and Δ_max; m_effective reported per round)
    channel_schedule: bool = False
    # wireless scenario model (sim/channel.py, DESIGN.md §16): a
    # ``sim.ChannelModel`` makes the channel a scanned process — per-client
    # AR(1) time-correlated fading riding the experiment carry (scheduling
    # draws come from the chain instead of the i.i.d. Rayleigh draw) and
    # optional per-client energy budgets gating participation. None (the
    # default) keeps today's i.i.d. draw bit-exactly. Typed Any to avoid an
    # import cycle; hashable (frozen dataclass), so it sweeps as a static
    # run_sweep axis.
    channel_model: object = None
    # FedAvg-style size-weighted aggregation: weight each sampled client's
    # delta by n_i/n (its true row count over the sampled total) instead of
    # the uniform 1/M — realistic for the uneven/label-skew partitions of
    # the gradient-free workloads (repro.workloads). Threads through every
    # aggregation path incl. masked/AirComp via a weighted mask_stats.
    weight_by_size: bool = False
    # beyond-paper: upload {seeds, coefficients} instead of dense deltas
    delta_compression: str = "dense"  # dense | seed
    # algorithm strategy (core/strategy.py): fedzo (paper) | fedavg |
    # fedprox | feddyn | scaffold — the registry's composable round
    # decomposition. The engine, server, and sweeps all resolve this field
    # unless an explicit strategy= is passed.
    strategy: str = "fedzo"
    # ZO-FedProx proximal weight: local loss + (prox_mu/2)·‖x − x_t‖².
    # 0 reduces to FedZO bit-exactly (the penalty is statically elided).
    prox_mu: float = 0.0
    # ZO-FedDyn regularizer α (Acar et al. 2021): local loss
    # − ⟨h_i, x⟩ + (α/2)·‖x − x_t‖² with per-client duals h_i and the
    # server correction x ← x̄ − h/α. 0 reduces to FedZO bit-exactly.
    dyn_alpha: float = 0.0
    # trajectory-informed surrogate estimator (direction_conv="surrogate",
    # FedZOO-style, arXiv 2308.04077): per local iterate only
    # ceil(b2·surrogate_fraction) fresh ZO queries are paid; the update
    # direction is the EW blend g ← β·g + (1−β)·g_fresh over the iterate
    # history. Requires cfg.batch_directions (the wide phase).
    surrogate_beta: float = 0.5
    surrogate_fraction: float = 0.5
