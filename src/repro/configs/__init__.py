"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import (FedZOConfig, INPUT_SHAPES, MLAConfig,
                                ModelConfig, ShapeConfig)

_ARCH_MODULES = {
    "rwkv6-7b": "rwkv6_7b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "hymba-1.5b": "hymba_1_5b",
    "qwen3-4b": "qwen3_4b",
    "qwen1.5-32b": "qwen15_32b",
    "gemma-2b": "gemma_2b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen2-0.5b": "qwen2_0_5b",
}

ARCH_IDS = tuple(_ARCH_MODULES)
SHAPE_IDS = tuple(INPUT_SHAPES)


def get_config(arch: str) -> ModelConfig:
    if arch.endswith("-smoke"):
        return get_config(arch[: -len("-smoke")]).reduced()
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(shape: str) -> ShapeConfig:
    if shape not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {shape!r}; choose from {SHAPE_IDS}")
    return INPUT_SHAPES[shape]


__all__ = ["ModelConfig", "MLAConfig", "ShapeConfig", "FedZOConfig",
           "INPUT_SHAPES", "ARCH_IDS", "SHAPE_IDS", "get_config", "get_shape"]
