"""RWKV-6 "Finch" 7B — attention-free SSM with data-dependent decay.

[arXiv:2404.05892] 32L, d_model=4096, d_ff=14336, vocab=65536; head_dim=64
(64 WKV heads), low-rank data-dependent decay (ddlerp), per-head bonus u.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", source="arXiv:2404.05892 (RWKV-6 Finch)",
    n_layers=32, d_model=4096, d_ff=14336, vocab=65536,
    n_heads=64, n_kv_heads=64, head_dim=64,
    ssm_kind="rwkv6", ssm_state=64,
    act="relu_sq",  # RWKV channel-mix uses squared ReLU
    norm="layernorm",
)
