"""Hymba-1.5B — hybrid parallel attention + Mamba heads in every layer.

[arXiv:2411.13676] 32L, d_model=1600, 25H GQA kv=5, head_dim=64, d_ff=5504,
vocab=32001, ssm_state=16. Attention and Mamba branches run in parallel on the
same input and their (normalized) outputs are averaged.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", source="arXiv:2411.13676 (Hymba)",
    n_layers=32, d_model=1600, d_ff=5504, vocab=32001,
    n_heads=25, n_kv_heads=5, head_dim=64,
    ssm_kind="mamba", ssm_state=16,
    sliding_window=1024,  # Hymba uses SWA for most attention layers
)
