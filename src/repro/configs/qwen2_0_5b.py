"""Qwen2-0.5B — dense GQA with QKV bias.

[arXiv:2407.10671] 24L, d_model=896, 14H kv=2, head_dim=64, d_ff=4864,
vocab=151936, qkv bias, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense", source="arXiv:2407.10671 (Qwen2)",
    n_layers=24, d_model=896, d_ff=4864, vocab=151936,
    n_heads=14, n_kv_heads=2, head_dim=64,
    qkv_bias=True, tie_embeddings=True,
)
