"""Qwen3-4B — dense GQA with qk_norm.

[hf:Qwen/Qwen3-8B family, 4B per assignment] 36L, d_model=2560, 32H kv=8,
head_dim=128, d_ff=9728, vocab=151936, qk_norm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense", source="hf:Qwen/Qwen3-8B (4B per assignment)",
    n_layers=36, d_model=2560, d_ff=9728, vocab=151936,
    n_heads=32, n_kv_heads=8, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
)
