"""Qwen3-30B-A3B — 128-expert top-8 MoE, GQA.

[hf:Qwen/Qwen3-30B-A3B] 48L, d_model=2048, 32H kv=4, head_dim=128,
expert d_ff=768, vocab=151936, 128 experts top-8, no shared expert.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe", source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=48, d_model=2048, d_ff=768, vocab=151936,
    n_heads=32, n_kv_heads=4, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
    n_experts=128, top_k=8, n_shared_experts=0, moe_d_ff=768, n_dense_layers=0,
)
