"""SeamlessM4T-Large v2 text decoder + speech encoder backbone (enc-dec).

[arXiv:2308.11596] 24L encoder + 24L decoder, d_model=1024, 16H kv=16,
head_dim=64, d_ff=8192, vocab=256206. Audio frontend (mel + conv codec) is a
stub: input_specs() provides frame embeddings [B, n_frames, d_model].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    source="arXiv:2308.11596 (SeamlessM4T v2)",
    n_layers=24, d_model=1024, d_ff=8192, vocab=256206,
    n_heads=16, n_kv_heads=16, head_dim=64,
    encoder_layers=24, n_frontend_tokens=4096,
    act="gelu", norm="layernorm",
)
