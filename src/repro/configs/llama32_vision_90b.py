"""Llama-3.2-Vision 90B text backbone — cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision, scaled per assignment] 100L total
(80 self + 20 gated cross-attn, 1 cross per 5), d_model=8192, 64H GQA kv=8,
head_dim=128, d_ff=28672, vocab=128256. Vision frontend (ViT+projector) is a
stub: input_specs() provides post-projector patch embeddings [B, 1600, d_model].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision (90B-scale per assignment)",
    n_layers=100, d_model=8192, d_ff=28672, vocab=128256,
    n_heads=64, n_kv_heads=8, head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5, n_frontend_tokens=1600,
)
