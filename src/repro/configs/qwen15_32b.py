"""Qwen1.5-32B — dense with QKV bias, full MHA (kv == heads).

[hf:Qwen/Qwen1.5-0.5B family, 32B per assignment] 64L, d_model=5120,
40H kv=40, head_dim=128, d_ff=27392, vocab=152064, qkv bias.
Note: 40 heads is not divisible by the 16-way model axis; sharding rules fall
back to d_ff/d_model sharding for attention projections (launch/sharding.py).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense", source="hf:Qwen/Qwen1.5-0.5B (32B per assignment)",
    n_layers=64, d_model=5120, d_ff=27392, vocab=152064,
    n_heads=40, n_kv_heads=40, head_dim=128,
    qkv_bias=True,
)
