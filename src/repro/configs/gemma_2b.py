"""Gemma-2B — GeGLU, head_dim=256, MQA (1 KV head).

[arXiv:2403.08295] 18L, d_model=2048, 8H kv=1, head_dim=256, d_ff=16384
(GeGLU hidden), vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense", source="arXiv:2403.08295 (Gemma)",
    n_layers=18, d_model=2048, d_ff=16384, vocab=256000,
    n_heads=8, n_kv_heads=1, head_dim=256,
    act="geglu", tie_embeddings=True,
)
