"""Optimizers for the first-order baselines and server-side adaptivity.

FedZO itself is optimizer-free (the update is the estimator step); these are
used by FedAvg locally and optionally by the server on aggregated deltas
("FedOpt"-style server optimizer, off by default to stay paper-faithful).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_axpy, tree_zeros_like


class SGDState(NamedTuple):
    momentum: object


def sgd_init(params, momentum=0.0):
    return SGDState(tree_zeros_like(params) if momentum else None)


def sgd_apply(params, grads, state: SGDState, *, lr, momentum=0.0):
    if momentum and state.momentum is not None:
        m = jax.tree.map(lambda mo, g: momentum * mo + g, state.momentum, grads)
        return tree_axpy(-lr, m, params), SGDState(m)
    return tree_axpy(-lr, grads, params), state


class AdamState(NamedTuple):
    mu: object
    nu: object
    count: jnp.ndarray


def adam_init(params):
    return AdamState(tree_zeros_like(params), tree_zeros_like(params),
                     jnp.zeros((), jnp.int32))


def adam_apply(params, grads, state: AdamState, *, lr, b1=0.9, b2=0.999,
               eps=1e-8):
    c = state.count + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g, state.nu, grads)
    cf = c.astype(jnp.float32)
    s1, s2 = 1 - b1 ** cf, 1 - b2 ** cf
    upd = jax.tree.map(lambda m, n: (m / s1) / (jnp.sqrt(n / s2) + eps), mu, nu)
    return tree_axpy(-lr, upd, params), AdamState(mu, nu, c)


def cosine_lr(step, *, base_lr, total_steps, warmup=0):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0) if warmup else 1.0
    t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
    return base_lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * t))
