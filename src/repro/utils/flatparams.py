"""FlatParams: the whole parameter pytree as ONE padded 1-D buffer.

FedZO's hot loop (perturb → forward → transition → replay) is pure
HBM-bandwidth work over the parameter vector. Doing it leafwise costs one
XLA op dispatch per leaf per pass and blocks the Pallas streaming kernels
(kernels/zo_axpy.py), which want a single flat array. ``FlatSpec`` caches
everything needed to flatten once and then unflatten *views* for free:

- ``flat_spec(params)``       → cached static spec (treedef, shapes,
                                dtypes, offsets, padded length)
- ``flatten(params, spec)``   → fp32 [n_pad] buffer, zero-padded to a
                                kernel-block multiple
- ``unflatten(buf, spec)``    → pytree of reshaped slices cast back to the
                                original leaf dtypes (XLA slices of the
                                buffer — no copy until a consumer forces
                                layout)

The flat index of a scalar is its offset in leaf traversal order — this is
the index the counter-based direction convention (kernels/zo_axpy.py) is
keyed on, so a direction element is addressable identically from the flat
kernels and from the pytree reference path (DESIGN.md §7).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.zo_axpy import BLOCK_ROWS, LANES


@dataclass(frozen=True)
class FlatSpec:
    """Static description of a flattened pytree (hashable, jit-closure safe)."""
    treedef: object
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    d: int                      # total valid scalar count
    n_pad: int                  # padded buffer length (block multiple)
    block: int                  # pad granularity in elements
    buf_dtype: str = "float32"


_SPEC_CACHE: dict = {}


def flat_spec(params, *, block: int = 0, buf_dtype="float32") -> FlatSpec:
    """Build (or fetch from cache) the FlatSpec for a pytree's structure."""
    block = block or BLOCK_ROWS * LANES
    leaves, treedef = jax.tree.flatten(params)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(str(jnp.dtype(l.dtype)) for l in leaves)
    key = (treedef, shapes, dtypes, block, str(jnp.dtype(buf_dtype)))
    hit = _SPEC_CACHE.get(key)
    if hit is not None:
        return hit
    sizes, offsets, off = [], [], 0
    for shp in shapes:
        n = 1
        for s in shp:
            n *= s
        offsets.append(off)
        sizes.append(n)
        off += n
    n_pad = off + ((-off) % block)
    spec = FlatSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    offsets=tuple(offsets), sizes=tuple(sizes), d=off,
                    n_pad=n_pad, block=block,
                    buf_dtype=str(jnp.dtype(buf_dtype)))
    _SPEC_CACHE[key] = spec
    return spec


def flatten(params, spec: FlatSpec):
    """Pytree → [n_pad] buffer in spec.buf_dtype (pad region zeroed)."""
    leaves = jax.tree.leaves(params)
    dt = jnp.dtype(spec.buf_dtype)
    parts = [l.reshape(-1).astype(dt) for l in leaves]
    pad = spec.n_pad - spec.d
    if pad:
        parts.append(jnp.zeros((pad,), dt))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unflatten(buf, spec: FlatSpec):
    """[≥ d] buffer → pytree of views with the original shapes/dtypes."""
    out = []
    for shp, dt, off, n in zip(spec.shapes, spec.dtypes, spec.offsets,
                               spec.sizes):
        out.append(buf[off:off + n].reshape(shp).astype(jnp.dtype(dt)))
    return jax.tree.unflatten(spec.treedef, out)


def flat_geometry(params, block_rows: int = 0):
    """(spec, block_rows kwarg) for a given kernel-block-rows setting.

    THE one mapping from a block-rows config to flat-buffer geometry. The
    perturb end (fedzo) and the replay end (seedcomm) must derive identical
    geometry for counter-convention seed replay to be bit-exact — both call
    this. block_rows=0 means the kernel default.
    """
    spec = flat_spec(params, block=block_rows * LANES if block_rows else 0)
    return spec, (block_rows or None)
