"""Hardware constants for the roofline analysis (TPU v5e, the target platform).

The container runs on CPU; these constants are used only to convert the
dry-run's compiled cost analysis into roofline *seconds* per chip.
"""

# Peak dense bf16 matmul throughput per chip.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s

# HBM bandwidth per chip.
HBM_BW = 819e9  # B/s

# Inter-chip interconnect, per link. v5e has a 2D torus with 4 links/chip;
# we report the conservative single-link figure and note the 4-link upper
# bound in EXPERIMENTS.md where it changes the dominant term.
ICI_BW_PER_LINK = 50e9   # B/s
ICI_LINKS_PER_CHIP = 4

HBM_PER_CHIP = 16 * 1024 ** 3  # 16 GiB


def roofline_seconds(flops: float, hbm_bytes: float, coll_bytes: float,
                     chips: int, ici_links: int = 1):
    """Three roofline terms in seconds (per the assignment's formulas).

    flops / hbm_bytes / coll_bytes are *totals across the mesh*; cost_analysis
    on an SPMD-compiled module reports per-device numbers, in which case pass
    chips=1 here (callers document which convention they use).
    """
    return {
        "compute_s": flops / (chips * PEAK_FLOPS_BF16),
        "memory_s": hbm_bytes / (chips * HBM_BW),
        "collective_s": coll_bytes / (chips * ICI_BW_PER_LINK * ici_links),
    }
