"""Pytree helpers used across the framework.

FedZO's memory story depends on treating the whole parameter pytree as a
single flat vector that is perturbed / updated in a streaming fashion, so the
helpers here are the workhorses of core/fedzo.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_axpy(a, x_tree, y_tree):
    """y + a * x, leafwise. `a` is a scalar (traced ok)."""
    return jax.tree.map(lambda x, y: (y + a * x).astype(y.dtype), x_tree, y_tree)


def tree_add(x_tree, y_tree):
    return jax.tree.map(jnp.add, x_tree, y_tree)


def tree_sub(x_tree, y_tree):
    return jax.tree.map(jnp.subtract, x_tree, y_tree)


def tree_scale(a, tree):
    return jax.tree.map(lambda x: (a * x).astype(x.dtype), tree)


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_dot(x_tree, y_tree):
    """Global inner product <x, y> over all leaves (fp32 accumulation)."""
    parts = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)),
        x_tree, y_tree)
    return jnp.sum(jnp.stack(jax.tree.leaves(parts)))


def tree_sq_norm(tree):
    parts = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), tree)
    return jnp.sum(jnp.stack(jax.tree.leaves(parts)))


def tree_norm(tree):
    return jnp.sqrt(tree_sq_norm(tree))


def tree_stack(trees):
    """Stack a list of identically-structured pytrees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n):
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


# Leaves above this element count are generated chunk-by-chunk along their
# leading (stacked-layer) axis with fold_in(key, layer) sub-keys. The
# mechanism matters (§Perf iteration 3 history): an unrolled .at[j].add DUS
# chain is NOT aliased by the backend (5.4 TB temps); a lax.scan over the
# layer axis double-buffers properly but still measured 165 GB vs 45 GB for
# the single-shot form on this backend (the scan blocks rng+consumer fusion).
# All four chunking/rng variants were REFUTED by measurement — single-shot
# generation wins; chunking stays available behind this threshold.
CHUNK_ELEMS = 1 << 62


def _leaf_chunks(shape):
    n = 1
    for s in shape:
        n *= s
    if len(shape) < 3 or n < CHUNK_ELEMS or shape[0] < 2:
        return 0  # single-shot generation
    return shape[0]  # one chunk per stacked layer


def leaf_normal(key, shape, dtype):
    """N(0,1) of `shape` from `key`, chunk-consistently (see _leaf_chunks)."""
    k = _leaf_chunks(shape)
    if not k:
        return jax.random.normal(key, shape, dtype)

    def body(_, j):
        return None, jax.random.normal(jax.random.fold_in(key, j),
                                       shape[1:], dtype)

    _, out = jax.lax.scan(body, None, jnp.arange(k))
    return out


def add_leaf_normal(x, key, coef, dtype=jnp.float32):
    """x + coef · N(0,1)(key) — scan-streamed for big stacked leaves.

    Bit-identical to ``x + coef * leaf_normal(key, x.shape, dtype)``.
    """
    k = _leaf_chunks(x.shape)
    if not k:
        g = jax.random.normal(key, x.shape, dtype)
        return (x + coef * g).astype(x.dtype)

    def body(_, inp):
        xl, j = inp
        g = jax.random.normal(jax.random.fold_in(key, j), xl.shape, dtype)
        return None, (xl + coef * g).astype(xl.dtype)

    _, out = jax.lax.scan(body, None, (x, jnp.arange(k)))
    return out


def leaf_normal_sq_norm(key, shape, dtype=jnp.float32):
    """‖N(0,1)(key)‖² with the same chunking — no full-leaf buffer."""
    k = _leaf_chunks(shape)
    if not k:
        g = jax.random.normal(key, shape, dtype)
        return jnp.sum(jnp.square(g.astype(jnp.float32)))

    def body(acc, j):
        g = jax.random.normal(jax.random.fold_in(key, j), shape[1:], dtype)
        return acc + jnp.sum(jnp.square(g.astype(jnp.float32))), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), jnp.arange(k))
    return total


def normal_like_tree(rng, tree, dtype=None):
    """One i.i.d. N(0,1) sample per parameter, leafwise, from a single key.

    Keys are derived per-leaf with jax.random.fold_in so the sample for a leaf
    is independent of the tree traversal order of other leaves — this is what
    makes *seed replay* (regenerating v from the round key without storing it)
    exact. Large leaves are chunk-generated (see _leaf_chunks).
    """
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(rng, i)
        out.append(leaf_normal(k, leaf.shape, dtype or leaf.dtype))
    return jax.tree.unflatten(treedef, out)


def tree_random_sq_norm(rng, tree, dtype=jnp.float32):
    """‖normal_like_tree(rng, tree)‖² without materializing the tree."""
    leaves = jax.tree.leaves(tree)
    total = jnp.zeros((), jnp.float32)
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(rng, i)
        total = total + leaf_normal_sq_norm(k, leaf.shape, dtype)
    return total


def tree_add_normal(tree, rng, coef, dtype=jnp.float32):
    """tree + coef · g(rng) streaming-leafwise (never materializes g)."""
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(rng, i)
        out.append(add_leaf_normal(leaf, k, coef, dtype))
    return jax.tree.unflatten(treedef, out)


def sphere_like_tree(rng, tree, dtype=jnp.float32):
    """v ~ U(S^{d-1}) over the *global* flattened parameter vector (paper Eq. 2).

    Sampled as g/||g|| with g ~ N(0, I_d); the norm is the global norm across
    all leaves, matching the paper's d-dimensional unit sphere exactly.
    """
    g = normal_like_tree(rng, tree, dtype=dtype)
    inv = 1.0 / (tree_norm(g) + 1e-30)
    return tree_scale(inv, g)
