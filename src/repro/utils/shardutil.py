"""Sharding-constraint helper usable both at top level and inside
partial-manual shard_map regions (e.g. the multi-pod ``pod`` axis).

Inside a manual region, constraints must be expressed on the *context
abstract mesh* and may only reference auto axes — ``constrain`` detects the
context, strips manual axes from the spec, and otherwise falls back to the
concrete mesh passed by the caller. No-op when mesh is None (CPU smoke)."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def dp_axes(mesh):
    """The batch ('data-parallel') axes present in a mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _env_mesh(mesh):
    try:
        ctx = jax.sharding.get_abstract_mesh()
        if ctx is not None and ctx.axis_names:
            manual = {n for n, t in zip(ctx.axis_names, ctx.axis_types)
                      if "Manual" in str(t)}
            return ctx, manual
    except Exception:  # noqa: BLE001 — fall back to caller's mesh
        pass
    return mesh, set()


def constrain(x, mesh, *spec):
    """with_sharding_constraint(x, P(*spec)) with manual axes stripped.

    Spec entries may be axis names, tuples of axis names, or None.
    """
    if mesh is None:
        return x
    m, manual = _env_mesh(mesh)

    def strip(s):
        if s is None:
            return None
        if isinstance(s, (tuple, list)):
            t = tuple(a for a in s if a not in manual and a in m.axis_names)
            return t if t else None
        return None if (s in manual or s not in m.axis_names) else s

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*[strip(s) for s in spec])))


def constrain_batch(x, mesh):
    """Leading dim over (pod, data), rest replicated."""
    if mesh is None:
        return x
    return constrain(x, mesh, dp_axes(mesh), *([None] * (x.ndim - 1)))
