"""repro.obs — the observability substrate (DESIGN.md §14).

- ``sinks``  — MetricsSink protocol + JSONL / CSV / memory / fan-out sinks.
- ``taps``   — RoundTap: the host half of the engine's in-scan
  ``io_callback`` telemetry stream (opt-in ``tap_every=k``).
- ``trace``  — Tracer/span layer separating compile from execute time,
  with an optional ``jax.profiler`` trace-dir hook.
- ``ledger`` — CommsLedger: unified per-round wire/dense byte accounting
  and cumulative uplink/downlink totals for ``history()`` rows.
- ``manifest`` — run manifests (config hash, strategy, versions, git sha,
  topology, fault/divergence event stream) alongside checkpoints/results.
- ``kernel_timing`` — measured µs + HBM-pass model for the ZO kernels.
- ``bench``  — persisted per-suite ``results/BENCH_*.json`` snapshots.
"""
from __future__ import annotations

from repro.obs.bench import bench_path, load_benches, save_bench
from repro.obs.kernel_timing import KernelTiming, kernel_report, time_fn
from repro.obs.ledger import CommsLedger
from repro.obs.manifest import (MANIFEST_NAME, build_manifest, git_sha,
                                read_manifest, write_manifest)
from repro.obs.sinks import (CsvSink, JsonlSink, MemorySink, MetricsSink,
                             MultiSink, NullSink, read_jsonl)
from repro.obs.taps import RoundTap
from repro.obs.trace import Span, Tracer

__all__ = [
    "bench_path", "load_benches", "save_bench",
    "KernelTiming", "kernel_report", "time_fn",
    "CommsLedger",
    "MANIFEST_NAME", "build_manifest", "git_sha", "read_manifest",
    "write_manifest",
    "CsvSink", "JsonlSink", "MemorySink", "MetricsSink", "MultiSink",
    "NullSink", "read_jsonl",
    "RoundTap",
    "Span", "Tracer",
]
