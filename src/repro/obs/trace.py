"""Trace spans + profiling hooks (DESIGN.md §14).

A ``Tracer`` records a tree of wall-clock spans around the phases of a run
— ``compile`` (jit lowering + XLA compile, via the AOT ``lower().compile()``
path), ``execute``/``segment`` (device time of the compiled program),
``eval``, whatever the driver opens. Spans nest: the tracer keeps a stack,
every span records its depth and parent, and ``report()`` renders the tree.

The point is separating COMPILE time from EXECUTE time: a multi-thousand-
round engine run spends seconds in XLA before the first round executes, and
without spans that cost silently pollutes rounds/s numbers. Drivers that
take a ``tracer=`` (``sim.run_experiment``, ``sim.run_sweep``,
``FedServer.run``) compile through ``timed_compile`` so each static shape
reports exactly one ``compile`` span per program cache (the checkpointed
segment runner compiles once per chunk size and reuses the executable
across segments).

``Tracer(profile_dir=...)`` additionally wraps the run in a
``jax.profiler`` trace (one ``start_trace``/``stop_trace`` pair), so the
same handle that gives coarse spans can drop a full XLA profile for
perfetto/tensorboard when you need the microscope.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class Span:
    name: str
    start: float
    duration: float = 0.0
    depth: int = 0
    parent: Optional[int] = None   # index into Tracer.spans
    meta: dict = field(default_factory=dict)


class Tracer:
    """Hierarchical wall-clock span recorder + optional jax.profiler hook.

    Cheap enough to always pass: an un-entered tracer costs one attribute
    check per driver call. Not thread-safe — one tracer per driver.
    """

    def __init__(self, profile_dir: Optional[str] = None):
        self.spans: list = []
        self.profile_dir = profile_dir
        self._stack: list = []       # indices of open spans
        self._compiled: dict = {}    # static-shape key -> compiled program

    @contextmanager
    def span(self, name: str, **meta):
        idx = len(self.spans)
        s = Span(name=name, start=time.perf_counter(),
                 depth=len(self._stack),
                 parent=self._stack[-1] if self._stack else None,
                 meta=dict(meta))
        self.spans.append(s)
        self._stack.append(idx)
        try:
            yield s
        finally:
            s.duration = time.perf_counter() - s.start
            self._stack.pop()

    @contextmanager
    def profile(self):
        """Wrap a block in a jax.profiler trace when ``profile_dir`` is
        set; a plain no-op otherwise."""
        if not self.profile_dir:
            yield
            return
        import jax.profiler
        jax.profiler.start_trace(self.profile_dir)
        try:
            with self.span("jax_profile", trace_dir=self.profile_dir):
                yield
        finally:
            jax.profiler.stop_trace()

    # -- compile/execute separation ------------------------------------------
    def timed_compile(self, key, jitted, *args):
        """AOT-compile ``jitted`` for ``args`` under a ``compile`` span,
        ONCE per static-shape ``key``: repeat calls with the same key reuse
        the cached executable and record no new compile span. Returns the
        compiled program (call it with the same arg structure)."""
        if key not in self._compiled:
            with self.span("compile", key=str(key)):
                self._compiled[key] = jitted.lower(*args).compile()
        return self._compiled[key]

    def invalidate_compiled(self, key=None):
        """Drop cached executables (all, or one key) — the divergence-
        rollback path re-bakes the backed-off lr into a new program."""
        if key is None:
            self._compiled.clear()
        else:
            self._compiled.pop(key, None)

    # -- reporting -----------------------------------------------------------
    def named(self, name: str) -> list:
        return [s for s in self.spans if s.name == name]

    def total(self, name: str) -> float:
        """Summed seconds across all spans of one name."""
        return sum(s.duration for s in self.named(name))

    def totals(self) -> dict:
        out: dict = {}
        for s in self.spans:
            agg = out.setdefault(s.name, {"count": 0, "seconds": 0.0})
            agg["count"] += 1
            agg["seconds"] += s.duration
        return out

    def report(self) -> str:
        """The span tree as indented text, one line per span."""
        lines = []
        for s in self.spans:
            meta = (" " + " ".join(f"{k}={v}" for k, v in s.meta.items())
                    if s.meta else "")
            lines.append(f"{'  ' * s.depth}{s.name}: "
                         f"{s.duration * 1e3:.2f} ms{meta}")
        return "\n".join(lines)
