"""In-scan metric taps (DESIGN.md §14).

``RoundTap`` is the host half of the engine's opt-in telemetry stream: the
compiled round calls ``io_callback(tap.emit, ...)`` every ``tap.every``
rounds (gated by a ``lax.cond``, so non-tap rounds pay nothing), and
``emit`` normalizes the device scalars into a plain row and hands it to the
sink. With ``tap_every=None`` (the default everywhere) the tap never enters
the traced program and the engine keeps its one-host-sync property —
taps-off runs are bit-identical to pre-obs builds (pinned by the golden
fixtures).

The callback is UNORDERED (``ordered=False``): ordered io_callbacks are not
available under ``lax.cond``, and ordering is recovered for free because
every row carries its round index. Sinks receive rows in execution order in
practice on a single device; consumers that must be robust sort by
``row["round"]``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.obs.sinks import MetricsSink


def _scalar(v):
    """Device/numpy scalar -> python scalar, exactly. Floats widen to
    float64 (lossless from float32), ints to python int, bools to bool."""
    a = np.asarray(v)
    if a.dtype.kind == "b":
        return bool(a)
    if a.dtype.kind in "iu":
        return int(a)
    return float(a)


@dataclass
class RoundTap:
    """One tap stream: a sink plus the in-scan cadence.

    ``every`` is the ``tap_every=k`` of the engine API: the scan emits the
    round's metrics on rounds where ``t % every == 0``. ``emitted`` counts
    rows actually delivered (the ≥ R/k acceptance check reads it).
    """
    sink: MetricsSink
    every: int = 1
    meta: dict = field(default_factory=dict)
    emitted: int = 0

    def __post_init__(self):
        if int(self.every) < 1:
            raise ValueError(f"tap_every must be >= 1, got {self.every}")
        self.every = int(self.every)

    def emit(self, t, metrics: dict) -> None:
        """The io_callback target: one round's metrics -> one sink row."""
        row = {"round": int(np.asarray(t))}
        row.update({k: _scalar(v) for k, v in metrics.items()})
        if self.meta:
            row.update(self.meta)
        self.sink.write(row)
        self.emitted += 1

    def close(self) -> None:
        self.sink.close()
