"""Metric sinks: where in-scan telemetry taps land (DESIGN.md §14).

A sink consumes per-round metric ROWS — plain dicts of python scalars with
at least a ``"round"`` key — emitted from inside the compiled experiment
scan via ``jax.experimental.io_callback`` (sim/engine.py, opt-in
``tap_every=k``). Sinks are deliberately dumb host-side objects: no jax
types, no buffering policy beyond an explicit ``flush_every``, so a
``tail -f`` on a ``JsonlSink`` file IS the live view of a running
federation.

Row values are normalized to python floats/ints before they reach a sink,
and floats serialize via ``repr`` (shortest round-trip decimal), so a row
read back from JSONL compares bit-equal to the float64 widening of the
float32 metric the engine wrote — the property tests/test_obs.py pins.
"""
from __future__ import annotations

import json
import os
from typing import Optional


class MetricsSink:
    """Base/no-op sink — also the protocol every sink implements.

    ``write(row)`` consumes one per-round row; ``flush``/``close`` are
    lifecycle hooks (file sinks honor them, memory sinks no-op). Sinks
    support the context-manager protocol so ``with JsonlSink(p) as s:``
    always leaves a closed, fully-flushed file.
    """

    def write(self, row: dict) -> None:  # pragma: no cover - interface
        del row

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class NullSink(MetricsSink):
    """Swallow rows (the tap-overhead benchmark's sink: pays the
    io_callback + normalization cost, none of the I/O)."""

    def __init__(self):
        self.count = 0

    def write(self, row: dict) -> None:
        self.count += 1


class MemorySink(MetricsSink):
    """Accumulate rows in a host-side list (tests, notebooks)."""

    def __init__(self):
        self.rows: list = []

    def write(self, row: dict) -> None:
        self.rows.append(dict(row))


class JsonlSink(MetricsSink):
    """One JSON object per line, appended to ``path``.

    ``flush_every=1`` (default) flushes after every row so a concurrent
    ``tail -f path`` streams the run live; raise it to amortize syscalls
    on very hot taps. The file is opened lazily on the first row, so
    constructing a sink never touches disk.
    """

    def __init__(self, path: str, *, flush_every: int = 1):
        self.path = path
        self.flush_every = max(1, int(flush_every))
        self._f = None
        self._since_flush = 0

    def _file(self):
        if self._f is None:
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            self._f = open(self.path, "a")
        return self._f

    def write(self, row: dict) -> None:
        self._file().write(json.dumps(row) + "\n")
        self._since_flush += 1
        if self._since_flush >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        if self._f is not None:
            self._f.flush()
            self._since_flush = 0

    def close(self) -> None:
        if self._f is not None:
            self._f.flush()
            self._f.close()
            self._f = None


def read_jsonl(path: str) -> list:
    """Read a JsonlSink file back into a list of row dicts."""
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


class CsvSink(MetricsSink):
    """Wide-format CSV: the header is fixed by the FIRST row's keys; later
    rows missing a column write an empty cell, extra keys are dropped (the
    tap emits a fixed metric set per run, so in practice every row
    matches). Good for spreadsheet-side consumption of a single run."""

    def __init__(self, path: str):
        self.path = path
        self._f = None
        self._cols: Optional[list] = None

    def write(self, row: dict) -> None:
        if self._f is None:
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            self._f = open(self.path, "a")
            self._cols = list(row)
            self._f.write(",".join(self._cols) + "\n")
        self._f.write(",".join(
            "" if c not in row else repr(row[c]) if isinstance(row[c], float)
            else str(row[c]) for c in self._cols) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class MultiSink(MetricsSink):
    """Fan one tap stream out to several sinks (e.g. JSONL on disk + an
    in-memory tail for the driving process)."""

    def __init__(self, *sinks: MetricsSink):
        self.sinks = list(sinks)

    def write(self, row: dict) -> None:
        for s in self.sinks:
            s.write(row)

    def flush(self) -> None:
        for s in self.sinks:
            s.flush()

    def close(self) -> None:
        for s in self.sinks:
            s.close()
