"""Per-round communication ledger (DESIGN.md §14).

FedZO's value proposition IS communication efficiency (paper Sec. I), yet
wire/dense byte accounting used to be scattered per aggregation path
(``seedcomm.wire_bytes`` on the digital path, ``tree_bytes`` ad hoc in the
drivers). ``CommsLedger`` unifies it: one dtype-exact byte model per run —
per-client uplink bytes under the run's actual wire format (dense delta /
seed-compressed coefficients / analog AirComp symbols), per-client downlink
(the model broadcast), and the dense baseline — from which every per-round
and cumulative figure derives.

The ledger is deliberately DETERMINISTIC in the round index and the row's
own ``m_effective``: annotation never needs evicted ring state, so a
ring-limited ``history()`` and a full one produce identical rows, and the
host and engine drivers agree bitwise (the property tests/test_obs.py
pins). ``m_effective`` (channel truncation, faults) scales the *effective*
uplink — a masked client transmits nothing — while the nominal figures
track the provisioned cohort M for capacity planning.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.utils.tree import tree_bytes


def _uplink_mode(cfg) -> str:
    """The run's uplink wire format, resolved from the config the same way
    the aggregation paths resolve it."""
    if cfg.delta_compression == "seed":
        return "seed"
    if cfg.aircomp:
        return "aircomp"
    return "dense"


@dataclass(frozen=True)
class CommsLedger:
    """Static byte model of one experiment's communication pattern.

    All figures are bytes per ROUND unless suffixed ``_client``. ``m`` is
    the nominal cohort size M; the analog AirComp uplink is costed at its
    dense-equivalent symbol count (d float32 symbols per client) so the
    compression column stays honest about what the air interface carries.
    """
    m: int                       # nominal sampled cohort size per round
    uplink_client_bytes: int     # per-client uplink under the wire format
    downlink_client_bytes: int   # per-client model broadcast
    dense_client_bytes: int      # dense-delta baseline per client
    mode: str = "dense"          # dense | seed | aircomp
    # per-transmission energy debit under the wireless scenario model
    # (sim/channel.py — the normalized Eq.-15 budget a device provisions);
    # 0.0 = no energy accounting, rows get no energy columns
    tx_energy_client: float = 0.0

    @classmethod
    def from_run(cls, cfg, params, m: int = None,
                 channel=None) -> "CommsLedger":
        """Build the ledger for a run: ``params`` fixes the dense byte
        count (dtype-exact leaf nbytes), ``cfg`` the wire format and the
        seed-compression geometry (H·b2 coefficients + the 8-byte threefry
        key + the 4-byte lr — exactly ``seedcomm.wire_bytes``).
        ``channel`` (a ``sim.ChannelModel``) adds per-transmission energy
        accounting when its gating is active."""
        from repro.core import seedcomm

        dense = tree_bytes(params)
        mode = _uplink_mode(cfg)
        if mode == "seed":
            up = seedcomm.wire_bytes_model(cfg)
        else:
            up = dense
        tx = (float(channel.tx_cost)
              if channel is not None and channel.gated else 0.0)
        return cls(m=int(m if m is not None else cfg.n_participating),
                   uplink_client_bytes=int(up),
                   downlink_client_bytes=int(dense),
                   dense_client_bytes=int(dense), mode=mode,
                   tx_energy_client=tx)

    # -- per-round figures ---------------------------------------------------
    def round_uplink_bytes(self) -> int:
        return self.m * self.uplink_client_bytes

    def round_downlink_bytes(self) -> int:
        return self.m * self.downlink_client_bytes

    def round_dense_bytes(self) -> int:
        return self.m * self.dense_client_bytes

    def compression_ratio(self) -> float:
        """Dense-baseline bytes over actual wire bytes (≥ 1 on the seed
        path, 1.0 dense/aircomp)."""
        return self.round_dense_bytes() / max(1, self.round_uplink_bytes())

    # -- history annotation --------------------------------------------------
    def annotate(self, rows: list, staging: dict = None, *,
                 start_round: int = 0) -> list:
        """Add the ledger columns to history rows IN PLACE (and return
        them): per-round ``wire_bytes``/``dense_bytes``/``downlink_bytes``,
        cumulative ``wire_bytes_total``/``downlink_bytes_total`` (rounds
        0..t inclusive — a pure function of t, so ring eviction cannot skew
        it), ``compression_ratio``, and — when the row carries
        ``m_effective`` — ``wire_bytes_effective`` (only surviving clients
        transmit). Structured event rows (rollbacks) and eval-only rows
        (rounds whose ring metrics were evicted carry nothing but the eval
        buffer's columns — a contract tests/test_workloads.py pins) pass
        through untouched.

        ``staging`` (tiered runs — sim/tiered.py) maps run-local round
        index -> {"bucket_id", "staged_bytes"}; matching rows gain those
        columns so host→device staging is auditable in the SAME JSONL
        stream (``start_round`` undoes the offset ``history()`` applied
        to ``row["round"]``). Non-tiered runs pass ``staging=None`` and
        the rows are untouched — the PR 8 sink/row contract holds."""
        up, down = self.round_uplink_bytes(), self.round_downlink_bytes()
        for row in rows:
            # a row is annotatable when it carries ring metrics; eval-only
            # rows (evicted ring, eval buffer columns only) pass untouched
            if ("event" in row or "round" not in row
                    or not ("mean_local_loss" in row
                            or "m_effective" in row)):
                continue
            t = int(row["round"])
            row["wire_bytes"] = up
            row["dense_bytes"] = self.round_dense_bytes()
            row["downlink_bytes"] = down
            row["wire_bytes_total"] = (t + 1) * up
            row["downlink_bytes_total"] = (t + 1) * down
            row["compression_ratio"] = self.compression_ratio()
            if "m_effective" in row:
                row["wire_bytes_effective"] = int(
                    row["m_effective"] * self.uplink_client_bytes)
                if self.tx_energy_client > 0.0:
                    # energy actually spent this round: only transmitting
                    # (scheduled ∧ charged) clients pay the Eq.-15 budget —
                    # deterministic in the row like every ledger column
                    row["energy_spent"] = float(
                        row["m_effective"] * self.tx_energy_client)
            if staging is not None:
                srow = staging.get(t - start_round)
                if srow:
                    row.update(srow)
        return rows

    def manifest(self) -> dict:
        """The ledger as a manifest block (plain json types)."""
        d = dataclasses.asdict(self)
        d["round_uplink_bytes"] = self.round_uplink_bytes()
        d["round_downlink_bytes"] = self.round_downlink_bytes()
        d["compression_ratio"] = self.compression_ratio()
        return d
