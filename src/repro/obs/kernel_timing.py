"""Per-kernel timing harness (DESIGN.md §14): measured µs next to the
HBM-pass model for the ZO hot-path kernels.

The flat hot path's whole performance argument is HBM passes (DESIGN.md
§7): ``zo_walk`` regenerates directions in-kernel so a perturbation step
reads+writes the buffer ONCE (2 passes) instead of streaming 3.5, and
``zo_replay`` folds all b2 directions of an iterate into one pass pair.
This harness times each kernel and prints the pass model beside it, so a
kernel regression shows up as measured-µs drifting away from a CONSTANT
model column — and on real HBM the model converts to a projected µs at an
assumed bandwidth.

CPU numbers come from the Pallas interpreter (regression trackers, not TPU
projections — DESIGN.md §6); the model columns are platform-independent.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

# default projection bandwidth: TPU v5e HBM ~819 GB/s (the roofline
# constant benchmarks/roofline_report.py also uses)
HBM_GBPS = 819.0


def time_fn(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Steady-state µs per call (compile/warmup excluded, blocked)."""
    out = None
    for _ in range(max(1, warmup)):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(max(1, iters)):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / max(1, iters) * 1e6


@dataclass
class KernelTiming:
    """One kernel's measured time beside its HBM traffic model."""
    name: str
    measured_us: float
    hbm_passes: float       # full passes over the principal buffer
    hbm_bytes: int          # modeled bytes moved per call
    model_us: float = 0.0   # hbm_bytes at the projection bandwidth
    meta: dict = field(default_factory=dict)

    def rows(self):
        """As benchmark-harness (name, us, derived) tuples."""
        return [(f"{self.name}_us", self.measured_us, self.hbm_passes),
                (f"{self.name}_hbm_model_us", self.model_us,
                 self.hbm_bytes)]


def _model(nbytes: float, passes: float, gbps: float) -> float:
    return nbytes / (gbps * 1e9) * 1e6  # µs


def kernel_report(*, n: int = None, b2: int = 8, m: int = 8,
                  gbps: float = HBM_GBPS, interpret=None) -> list:
    """Time the three ZO hot-path kernels at a common working size.

    ``n`` is the flat buffer length (defaults to one kernel block),
    ``b2`` the direction count for the replay, ``m`` the cohort size for
    the AirComp reduce. Returns ``[KernelTiming, ...]`` for
    ``zo_walk`` / ``zo_replay`` / ``aircomp_reduce``.
    """
    from repro.kernels import ops
    from repro.kernels.zo_axpy import BLOCK

    if n is None:
        n = BLOCK
    f32 = jnp.dtype(jnp.float32).itemsize
    x = jax.random.normal(jax.random.key(0), (n,), jnp.float32)
    key2 = jax.random.key_data(jax.random.key(1))
    out = []

    # zo_walk: x read + x' written, directions regenerated in-kernel
    us = time_fn(lambda: ops.zo_walk(x, key2, [0, 1], [-0.1, 0.1],
                                     interpret=interpret))
    out.append(KernelTiming(
        name=f"zo_walk_n{n}", measured_us=us, hbm_passes=2.0,
        hbm_bytes=2 * n * f32, model_us=_model(2 * n * f32, 2.0, gbps),
        meta={"n": n}))

    # zo_replay: one read+write pass folds ALL b2 directions of an iterate
    coeffs = jnp.linspace(-1.0, 1.0, b2)
    us = time_fn(lambda: ops.zo_replay(x, key2, coeffs, interpret=interpret))
    out.append(KernelTiming(
        name=f"zo_replay_n{n}_b2{b2}", measured_us=us, hbm_passes=2.0,
        hbm_bytes=2 * n * f32, model_us=_model(2 * n * f32, 2.0, gbps),
        meta={"n": n, "b2": b2}))

    # aircomp_reduce: the [M, n] delta matrix read once, the mean written
    deltas = jax.random.normal(jax.random.key(2), (m, n), jnp.float32)
    scale = jnp.full((m,), 1.0 / m, jnp.float32)
    us = time_fn(lambda: ops.aircomp_reduce(deltas, scale, n,
                                            interpret=interpret))
    nbytes = (m + 1) * n * f32
    out.append(KernelTiming(
        name=f"aircomp_reduce_m{m}_n{n}", measured_us=us,
        hbm_passes=m + 1.0, hbm_bytes=nbytes,
        model_us=_model(nbytes, m + 1.0, gbps), meta={"m": m, "n": n}))
    return out
