"""Run manifests (DESIGN.md §14): one JSON document that says what ran.

A manifest pins everything needed to interpret (or re-run) a result file
found on disk months later: the config and its hash (the SAME
``checkpoint.config_hash`` the snapshot sidecars record, so a manifest and
a checkpoint from one run cross-check), the strategy name, the jax/python
versions, the git sha of the working tree, the device/mesh topology, the
communication ledger, the fault-model and wireless-scenario
configurations, and the structured event stream (divergence rollbacks)
the run produced.

``sim.run_experiment`` emits one alongside durable checkpoints
(``<checkpoint_dir>/manifest.json``) and next to a file-backed metric sink
(``<sink>.manifest.json``); benchmark snapshots embed the same provenance
block (obs/bench.py).
"""
from __future__ import annotations

import dataclasses
import datetime
import json
import os
import platform
import subprocess
from typing import Optional

import jax

MANIFEST_NAME = "manifest.json"


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    """Best-effort git sha of the source tree (None outside a checkout)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5,
            cwd=cwd or os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def device_topology() -> dict:
    """The visible device/mesh topology, host-side."""
    devs = jax.devices()
    return {"platform": devs[0].platform if devs else "none",
            "device_count": jax.device_count(),
            "local_device_count": jax.local_device_count(),
            "process_count": jax.process_count(),
            "devices": [str(d) for d in devs]}


def build_manifest(cfg=None, *, strategy: Optional[str] = None,
                   rounds: Optional[int] = None,
                   n_clients: Optional[int] = None, ledger=None,
                   faults=None, channel=None, events=None, mesh=None,
                   extra: Optional[dict] = None) -> dict:
    """Assemble a run manifest dict. Everything is optional so partial
    emitters (benchmarks) reuse the same provenance block."""
    from repro.checkpoint.checkpoint import config_hash

    md = {"created_at": datetime.datetime.now(
              datetime.timezone.utc).isoformat(),
          "jax_version": jax.__version__,
          "python_version": platform.python_version(),
          "git_sha": git_sha(),
          "topology": device_topology()}
    if cfg is not None:
        md["config_hash"] = config_hash(cfg)
        md["config"] = (dataclasses.asdict(cfg)
                        if dataclasses.is_dataclass(cfg) else dict(cfg))
    if strategy is not None:
        md["strategy"] = strategy
    if rounds is not None:
        md["rounds"] = int(rounds)
    if n_clients is not None:
        md["n_clients"] = int(n_clients)
    if ledger is not None:
        md["comms"] = ledger.manifest()
    if faults is not None:
        md["faults"] = faults.describe()
    if channel is not None:
        md["channel"] = channel.describe()
    if mesh is not None:
        md["mesh"] = {"axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
                      "devices": [str(d) for d in mesh.devices.ravel()]}
    md["events"] = [dict(e) for e in (events or [])]
    if extra:
        md.update(extra)
    return md


def write_manifest(path: str, manifest: dict) -> str:
    """Write a manifest dict as JSON. ``path`` may be a directory (the
    manifest lands as ``manifest.json`` inside it) or a full file path.
    Returns the file path written."""
    if os.path.isdir(path) or path.endswith(os.sep):
        os.makedirs(path, exist_ok=True)
        path = os.path.join(path, MANIFEST_NAME)
    else:
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1, default=str)
    os.replace(tmp, path)
    return path


def read_manifest(path: str) -> dict:
    """Read a manifest written by ``write_manifest`` (file or dir path)."""
    if os.path.isdir(path):
        path = os.path.join(path, MANIFEST_NAME)
    with open(path) as f:
        return json.load(f)
