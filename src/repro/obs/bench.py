"""Persisted per-suite benchmark snapshots (ROADMAP item 4's trajectory).

Every benchmark suite writes ``results/BENCH_<suite>.json`` through
``save_bench``: the current rows plus a provenance block (jax version, git
sha, UTC timestamp, optional config note). Re-saving a suite pushes the
previous snapshot onto a bounded ``history`` list inside the same file, so
the rounds/s trajectory ACCUMULATES per PR instead of being re-measured ad
hoc and forgotten — ``results/make_tables.py --bench`` renders it.
"""
from __future__ import annotations

import datetime
import glob
import json
import os
from typing import Optional

import jax

HISTORY_KEEP = 20


def results_dir(path: Optional[str] = None) -> str:
    """Default snapshot directory: the repo's ``results/`` (next to the
    committed ``make_tables.py``), overridable for tests."""
    if path:
        return path
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    cand = os.path.join(repo, "results")
    return cand if os.path.isdir(cand) else "results"


def bench_path(suite: str, out_dir: Optional[str] = None) -> str:
    return os.path.join(results_dir(out_dir), f"BENCH_{suite}.json")


def _rows_json(rows) -> list:
    """Normalize harness rows ((name, us, derived) tuples or dicts)."""
    out = []
    for r in rows:
        if isinstance(r, dict):
            out.append({"name": r["name"],
                        "us_per_call": float(r.get("us_per_call", 0.0)),
                        "derived": r.get("derived")})
        else:
            name, us, derived = r
            out.append({"name": name, "us_per_call": float(us),
                        "derived": derived})
    return out


def save_bench(suite: str, rows, *, config=None,
               out_dir: Optional[str] = None) -> str:
    """Snapshot one suite's rows to ``results/BENCH_<suite>.json``.

    The previous snapshot (if any) is appended to the file's ``history``
    (newest last, bounded to ``HISTORY_KEEP``), so successive runs build
    the perf trajectory in place. Returns the path written."""
    from repro.obs.manifest import git_sha

    path = bench_path(suite, out_dir)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            history = list(prev.get("history", []))
            history.append({k: prev.get(k) for k in
                            ("timestamp", "jax_version", "git_sha", "rows")})
            history = history[-HISTORY_KEEP:]
        except (OSError, ValueError, KeyError):
            history = []  # a corrupt snapshot never blocks a new one
    snap = {"suite": suite,
            "rows": _rows_json(rows),
            "jax_version": jax.__version__,
            "git_sha": git_sha(),
            "timestamp": datetime.datetime.now(
                datetime.timezone.utc).isoformat(),
            "config": config,
            "history": history}
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(snap, f, indent=2, default=str)
    os.replace(tmp, path)
    return path


def load_benches(out_dir: Optional[str] = None) -> dict:
    """All ``BENCH_*.json`` snapshots in a results dir, keyed by suite."""
    out = {}
    for p in sorted(glob.glob(os.path.join(results_dir(out_dir),
                                           "BENCH_*.json"))):
        try:
            with open(p) as f:
                snap = json.load(f)
        except (OSError, ValueError):
            continue
        suite = snap.get("suite") or \
            os.path.basename(p)[len("BENCH_"):-len(".json")]
        out[suite] = snap
    return out
