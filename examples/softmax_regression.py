"""Paper Sec V-B: softmax regression on a non-iid split — FedZO vs FedAvg,
with and without AirComp (Figs. 3-5 in one script).

    PYTHONPATH=src python examples/softmax_regression.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.data.synthetic import make_classification, noniid_shards
from repro.fed.server import FedServer
from repro.models.simple import softmax_accuracy, softmax_init, softmax_loss

x, y = make_classification(7000, 784, 10, seed=0)
clients = noniid_shards(x[:6000], y[:6000], 50)
test = {"x": jnp.asarray(x[6000:]), "y": jnp.asarray(y[6000:])}
ev = jax.jit(lambda p: softmax_accuracy(p, test))

runs = [
    ("FedZO  H=5 ", dict(algo="fedzo", local_iters=5)),
    ("FedZO  H=20", dict(algo="fedzo", local_iters=20)),
    ("FedAvg H=5 ", dict(algo="fedavg", local_iters=5)),
    ("FedZO  H=5 AirComp 0dB", dict(algo="fedzo", local_iters=5, aircomp=True,
                                    snr_db=0.0)),
]
for name, kw in runs:
    algo = kw.pop("algo")
    cfg = FedZOConfig(n_devices=50, n_participating=20, lr=1e-3, mu=1e-3,
                      b1=25, b2=20, **kw)
    srv = FedServer(softmax_loss, softmax_init(None), clients, cfg, algo=algo)
    srv.run(15)
    print(f"{name}: test acc {float(ev(srv.params)):.3f}")
