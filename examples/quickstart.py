"""Quickstart: FedZO (paper Algorithm 1) on non-iid softmax regression —
the WHOLE experiment as one compiled program (repro.sim, DESIGN.md §9).

    PYTHONPATH=src python examples/quickstart.py

50 clients, 10 sampled per round, H=5 local zeroth-order steps — reaches
~100% test accuracy on the synthetic separable problem in ~20 rounds without
ever computing a gradient. The client datasets live on-device in a
ClientStore; participation draws, minibatch sampling, all 20 rounds, and the
every-5-rounds eval run inside a single jit (≈5× the rounds/s of the
per-round Python loop — benchmarks/sim_bench.py).
"""
import jax

from repro import sim
from repro.configs.base import FedZOConfig
from repro.data.synthetic import make_classification, noniid_shards
from repro.fed.server import FedServer
from repro.models.simple import softmax_accuracy, softmax_init, softmax_loss

x, y = make_classification(7000, 784, 10, seed=0)
clients = noniid_shards(x[:6000], y[:6000], 50)
test = {"x": jax.numpy.asarray(x[6000:]), "y": jax.numpy.asarray(y[6000:])}

cfg = sim.fast_sim_config(
    FedZOConfig(n_devices=50, n_participating=10, local_iters=5,
                lr=1e-3, mu=1e-3, b1=25, b2=20))
server = FedServer(softmax_loss, softmax_init(None), clients, cfg,
                   store=sim.build_store(clients),
                   jit_eval=lambda p: {"test_acc": softmax_accuracy(p, test)},
                   eval_every=5)
server.run(20, log_every=5)   # ONE compiled scan — no per-round host sync
acc = float(jax.jit(softmax_accuracy)(server.params, test))
print(f"final test accuracy: {acc:.3f}")
