"""Quickstart: FedZO (paper Algorithm 1) on non-iid softmax regression.

    PYTHONPATH=src python examples/quickstart.py

50 clients, 10 sampled per round, H=5 local zeroth-order steps — reaches
~100% test accuracy on the synthetic separable problem in ~20 rounds without
ever computing a gradient.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.data.synthetic import make_classification, noniid_shards
from repro.fed.server import FedServer
from repro.models.simple import softmax_accuracy, softmax_init, softmax_loss

x, y = make_classification(7000, 784, 10, seed=0)
clients = noniid_shards(x[:6000], y[:6000], 50)
test = {"x": jnp.asarray(x[6000:]), "y": jnp.asarray(y[6000:])}

cfg = FedZOConfig(n_devices=50, n_participating=10, local_iters=5,
                  lr=1e-3, mu=1e-3, b1=25, b2=20)
ev = jax.jit(lambda p: softmax_accuracy(p, test))
server = FedServer(softmax_loss, softmax_init(None), clients, cfg,
                   eval_fn=lambda p: {"test_acc": float(ev(p))})
server.run(20, log_every=5)
print(f"final test accuracy: {server.history[-1]['test_acc']:.3f}")
