"""Survive a preemption: durable engine checkpoints + resume (DESIGN.md §12).

The compiled federation engine runs in k-round segments; after each segment
the FULL carry (params, momentum, PRNG key, fault chains, metrics ring,
eval buffer) is snapshotted ATOMICALLY to disk. Kill the process at any
instant — the latest snapshot is always consistent — and resume finishes
the run bit-identical to an uninterrupted one.

    # run 24 rounds, snapshot every 4
    PYTHONPATH=src python examples/resumable_run.py --dir /tmp/fedzo_ck

    # simulate a preemption: SIGKILL self after 2 segments...
    PYTHONPATH=src python examples/resumable_run.py --dir /tmp/fedzo_ck \
        --fresh --kill-after 2
    # ...then pick the run back up and verify against an uninterrupted one
    PYTHONPATH=src python examples/resumable_run.py --dir /tmp/fedzo_ck \
        --resume --reference-check

The run also injects client faults (a Gilbert-Elliott availability chain +
stragglers + corrupted uploads) to show the finite-guard and ``m_effective``
in action — the fault-chain state is part of the durable carry, so a resume
continues the same outage trajectory.
"""
import argparse
import os
import shutil
import signal
import sys

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro import sim                                     # noqa: E402
from repro.configs.base import FedZOConfig                # noqa: E402
from repro.data.synthetic import (make_classification,    # noqa: E402
                                  noniid_shards)
from repro.models.simple import (softmax_accuracy,        # noqa: E402
                                 softmax_init, softmax_loss)


def build():
    x, y = make_classification(2000, 64, 8, seed=0)
    clients = noniid_shards(x[:1600], y[:1600], 16)
    test = {"x": jax.numpy.asarray(x[1600:]), "y": jax.numpy.asarray(y[1600:])}
    cfg = sim.fast_sim_config(
        FedZOConfig(n_devices=16, n_participating=6, local_iters=3,
                    lr=5e-3, mu=1e-3, b1=16, b2=8))
    faults = sim.FaultModel(p_fail=0.1, p_recover=0.5, deadline=3.0,
                            p_corrupt=0.05)
    return (softmax_loss, softmax_init(None, 64, 8), sim.build_store(clients),
            cfg, faults, test)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--checkpoint-every", type=int, default=4)
    ap.add_argument("--dir", default="/tmp/fedzo_resumable")
    ap.add_argument("--fresh", action="store_true",
                    help="wipe the checkpoint dir before starting")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest snapshot in --dir")
    ap.add_argument("--kill-after", type=int, default=0, metavar="N",
                    help="SIGKILL this process after N snapshotted segments "
                         "(the preemption drill)")
    ap.add_argument("--reference-check", action="store_true",
                    help="rerun uninterrupted and assert the resumed "
                         "trajectory is bit-identical")
    args = ap.parse_args(argv)

    if args.fresh and os.path.isdir(args.dir):
        shutil.rmtree(args.dir)
    loss, p0, store, cfg, faults, test = build()

    def on_segment(t, total):
        print(f"  snapshot @ round {t}/{total} -> {args.dir}")
        if args.kill_after and t >= args.kill_after * args.checkpoint_every:
            print("  simulating preemption: SIGKILL")
            sys.stdout.flush()
            os.kill(os.getpid(), signal.SIGKILL)

    res = sim.run_experiment(
        loss, p0, store, cfg, args.rounds, faults=faults,
        eval_fn=lambda p: {"test_acc": softmax_accuracy(p, test)},
        eval_every=4, donate=False,
        checkpoint_every=args.checkpoint_every, checkpoint_dir=args.dir,
        resume=args.resume, segment_callback=on_segment)

    rows = sim.history(res)
    acc = [r["test_acc"] for r in rows if "test_acc" in r]
    print(f"finished {res.rounds} rounds; m_effective last round: "
          f"{rows[-1].get('m_effective'):.0f}; "
          f"test_acc: {acc[-1] if acc else float('nan'):.3f}")

    if args.reference_check:
        ref = sim.run_experiment(loss, p0, store, cfg, args.rounds,
                                 faults=faults,
                                 eval_fn=lambda p: {
                                     "test_acc": softmax_accuracy(p, test)},
                                 eval_every=4, donate=False)
        for a, b in zip(jax.tree.leaves(res.params),
                        jax.tree.leaves(ref.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for k in ref.metrics:
            np.testing.assert_array_equal(np.asarray(res.metrics[k]),
                                          np.asarray(ref.metrics[k]),
                                          err_msg=k)
        print("reference check: resumed run is BIT-IDENTICAL to the "
              "uninterrupted one")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
