"""Scale past device memory: the tiered HostStore + cohort stream
(DESIGN.md §15).

The device-resident ``ClientStore`` pads every client to the global max and
holds the WHOLE [N, cap, ...] federation on the accelerator — fine for
N=50, fatal for the paper's N=10⁵-10⁶ regime. The tiered path keeps the
population in host (optionally memory-mapped) numpy, bucketed by size
quantile, and streams only each segment's sampled cohorts — plus ONE
prefetch buffer — to the device, bit-identical to the resident engine.

    # 50k clients on a laptop CPU, with the bitwise cross-check vs the
    # resident engine at the same scale (CI runs exactly this)
    PYTHONPATH=src python examples/tiered_scale.py --smoke

    # 100k clients, host-tier only (the resident cross-check is skipped
    # at sizes where the padded [N, cap] layout stops being comfortable)
    PYTHONPATH=src python examples/tiered_scale.py --clients 100000

The run prints the residency split (host bytes vs peak on-device segment
bytes) and the prefetch stall share — the % of wall time the main loop
spent waiting on staging that double buffering failed to hide.
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax                                                # noqa: E402

from repro import sim                                     # noqa: E402
from repro.configs.base import FedZOConfig                # noqa: E402
from repro.data.synthetic import make_classification      # noqa: E402
from repro.models.simple import softmax_init, softmax_loss  # noqa: E402


def ragged_population(n_clients, lo=6, hi=13, seed=1):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(lo, hi, size=n_clients)
    x, y = make_classification(int(sizes.sum()), 24, 4, seed=seed)
    clients, off = [], 0
    for s in sizes:
        clients.append({"x": x[off:off + s], "y": y[off:off + s]})
        off += s
    return clients


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--clients", type=int, default=100_000)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="50k clients + bitwise tiered-vs-resident assert")
    ap.add_argument("--no-crosscheck", action="store_true",
                    help="skip the resident bitwise cross-check")
    args = ap.parse_args(argv)
    n = 50_000 if args.smoke else args.clients

    print(f"building N={n} ragged federation ...")
    clients = ragged_population(n)
    host = sim.build_host_store(clients, n_buckets=4)
    cfg = sim.fast_sim_config(FedZOConfig(
        n_devices=n, n_participating=32, local_iters=2, lr=1e-2, mu=1e-3,
        b1=4, b2=4, seed=7))
    p0 = softmax_init(None, 24, 4)
    caps = [(b.cap, len(b.ids)) for b in host.buckets]
    print(f"host store: {host.n_buckets} buckets (cap, n): {caps}, "
          f"{host.nbytes / 1e6:.1f} MB host-resident")

    tier = sim.run_experiment(softmax_loss, p0, host, cfg, args.rounds,
                              donate=False)
    pf = tier.prefetch
    print(f"tiered run: {tier.rounds} rounds, "
          f"{pf['wall_s'] / tier.rounds * 1e3:.1f} ms/round | "
          f"device segment peak {pf['device_segment_bytes_max'] / 1e6:.2f} "
          f"MB vs {pf['host_bytes'] / 1e6:.1f} MB host | "
          f"prefetch stall {pf['stall_pct']:.1f}%")
    loss = float(np.asarray(tier.metrics["mean_local_loss"])[-1])
    assert np.isfinite(loss), "diverged"
    print(f"final mean local loss: {loss:.4f}")

    if args.smoke and not args.no_crosscheck:
        # the central §15 acceptance, at scale: the streamed run must land
        # on EXACTLY the resident engine's bits
        print("cross-checking vs the device-resident engine ...")
        res = sim.run_experiment(softmax_loss, p0, sim.build_store(clients),
                                 cfg, args.rounds, donate=False)
        for k in res.metrics:
            np.testing.assert_array_equal(np.asarray(res.metrics[k]),
                                          np.asarray(tier.metrics[k]),
                                          err_msg=k)
        for la, lb in zip(jax.tree.leaves(res.params),
                          jax.tree.leaves(tier.params)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        np.testing.assert_array_equal(jax.random.key_data(res.key),
                                      jax.random.key_data(tier.key))
        print(f"bitwise tiered == resident at N={n}: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
