"""Simulate a city of moving devices: correlated fading + energy budgets
(DESIGN.md §16).

The paper's AirComp rounds draw a FRESH Rayleigh channel every round —
devices that teleport between rounds. ``sim.ChannelModel`` replaces that
with the scenario the hardware actually lives in:

- each of the N devices carries a time-correlated (AR(1)) fading chain,
  parameterized by a Doppler/mobility knob (``from_doppler``): pedestrians
  keep their channel for many rounds, vehicles decorrelate fast;
- each device has a battery, debited by the Eq.-15 transmit budget every
  round it transmits; drained devices drop out of the aggregate exactly
  like deep-fade ones, and ``m_effective`` reports the surviving cohort.

The whole scenario — fading chains, scheduling, battery ledger — advances
INSIDE the compiled round scan, rides durable checkpoints, and is
host-replayable bit-exactly (the tiered path stages it ahead of the
device; see DESIGN.md §16 for why the chain is integer fixed-point).

    PYTHONPATH=src python examples/wireless_scenario.py           # full demo
    PYTHONPATH=src python examples/wireless_scenario.py --smoke   # CI-sized
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax                                                # noqa: E402

from repro import sim                                     # noqa: E402
from repro.configs.base import FedZOConfig                # noqa: E402
from repro.data.synthetic import make_classification      # noqa: E402
from repro.models.simple import softmax_init, softmax_loss  # noqa: E402
from repro.sim import channel as channel_lib              # noqa: E402


def population(n_clients, n=4000, seed=0):
    x, y = make_classification(n, 24, 4, seed=seed)
    per = n // n_clients
    return [{"x": x[i * per:(i + 1) * per], "y": y[i * per:(i + 1) * per]}
            for i in range(n_clients)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run + bitwise engine/tiered/legacy "
                         "asserts")
    args = ap.parse_args(argv)
    n = 16 if args.smoke else args.clients
    rounds = 10 if args.smoke else args.rounds

    clients = population(n, n=80 * n)
    store = sim.build_store(clients)
    p0 = softmax_init(None, 24, 4)

    # a pedestrian city block: fd·T = 0.02 → the channel stays coherent
    # for ~8 rounds; every device starts with a finite transmit budget
    city = sim.ChannelModel.from_doppler(0.02, battery=float(rounds) * 0.6,
                                         tx_cost=1.0)
    print(f"scenario: rho={city.rho:.3f} "
          f"(coherence ≈ {city.coherence_rounds:.1f} rounds), "
          f"battery covers {city.battery / city.tx_cost:.0f} transmissions")

    cfg = sim.fast_sim_config(FedZOConfig(
        n_devices=n, n_participating=max(4, n // 4), local_iters=2,
        lr=1e-2, mu=1e-3, b1=8, b2=4, seed=11,
        channel_schedule=True, h_min=0.3, channel_model=city))
    res = sim.run_experiment(softmax_loss, p0, store, cfg, rounds,
                             donate=False)

    hist = sim.history(res)
    m_eff = np.asarray(res.metrics["m_effective"])
    batt = np.asarray(channel_lib.battery(res.channel_state))
    print(f"m_effective per round: {m_eff.astype(int).tolist()}")
    print(f"energy ledger: {sum(r['energy_spent'] for r in hist):.0f} "
          f"units spent, fleet charge left {batt.sum():.0f} "
          f"({(batt >= city.tx_cost).mean():.0%} of devices can still "
          f"transmit)")
    loss = [r["mean_local_loss"] for r in hist]
    print(f"mean local loss: {loss[0]:.4f} -> {loss[-1]:.4f}")
    assert all(np.isfinite(v) for v in loss)

    if args.smoke:
        # 1. the energy ledger balances EXACTLY (integer Q.16 accounting
        # under the hood): every unit the history rows report as spent is
        # a unit missing from the fleet's remaining charge
        spent = sum(r["energy_spent"] for r in hist)
        assert spent == float(n) * city.battery - float(batt.sum()), \
            (spent, batt.sum())
        print(f"energy ledger balances: {spent:.0f} spent == "
              f"{n}x{city.battery:.0f} initial - {batt.sum():.0f} left")

        # 2. the §16 acceptance triangle: tiered streaming lands on the
        # resident engine's exact bits, chain and batteries included
        host = sim.build_host_store(clients, n_buckets=2)
        tier = sim.run_experiment(softmax_loss, p0, host, cfg, rounds,
                                  donate=False)
        for la, lb in zip(jax.tree.leaves(res.params),
                          jax.tree.leaves(tier.params)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        for la, lb in zip(jax.tree.leaves(res.channel_state),
                          jax.tree.leaves(tier.channel_state)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        print("bitwise tiered == resident with the scenario on: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
