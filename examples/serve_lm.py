"""Batched serving example (prefill + streaming decode with ring KV caches).

    PYTHONPATH=src python examples/serve_lm.py [--arch hymba-1.5b-smoke]
"""
import subprocess
import sys

cmd = [sys.executable, "-m", "repro.launch.serve",
       "--arch", "qwen2-0.5b-smoke", "--batch", "4",
       "--prompt-len", "32", "--gen", "16"] + sys.argv[1:]
print("running:", " ".join(cmd))
sys.exit(subprocess.call(cmd))
