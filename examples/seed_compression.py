"""Beyond-paper: seed-compressed FedZO uplink (DESIGN.md §3.4).

Each client uploads (PRNG key, H×b2 coefficients) instead of a dense model
delta; the server replays the seeds. Bit-exact vs the dense round, with a
~75× smaller uplink even for the tiny softmax model (×10^10 for 671B).

    PYTHONPATH=src python examples/seed_compression.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedZOConfig
from repro.data.synthetic import (make_classification, noniid_shards,
                                  sample_local_batches)
from repro.fed.server import run_seed_compressed_round
from repro.models.simple import softmax_init, softmax_loss

x, y = make_classification(4000, 784, 10, seed=0)
clients = noniid_shards(x, y, 10)
cfg = FedZOConfig(local_iters=5, lr=1e-3, mu=1e-3, b1=25, b2=20)
params = softmax_init(None)
rng = np.random.default_rng(0)
key = jax.random.key(0)
for t in range(5):
    batches = [jax.tree.map(jnp.asarray,
               sample_local_batches(clients[i], rng, cfg.local_iters, cfg.b1))
               for i in range(4)]
    key, *ks = jax.random.split(key, 5)
    params, wire, dense = run_seed_compressed_round(
        softmax_loss, params, batches, ks, cfg)
    full = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    print(f"round {t}: loss {float(softmax_loss(params, full)):.4f} "
          f"uplink {wire} B vs dense {dense} B ({dense/wire:.0f}x smaller)")
