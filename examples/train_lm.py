"""End-to-end LM training driver example (deliverable b): a ~100M-param
decoder-only model trained on a synthetic token stream.

Default runs a quick FedZO demo on the smoke model; pass --full for the
~100M config / --algo fedavg for the first-order baseline:

    PYTHONPATH=src python examples/train_lm.py               # quick
    PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""
import subprocess
import sys

args = sys.argv[1:]
full = "--full" in args
if full:
    args.remove("--full")
cmd = [sys.executable, "-m", "repro.launch.train",
       "--arch", "qwen2-0.5b-smoke", "--batch", "8", "--seq", "128",
       "--algo", "fedavg", "--opt", "adam", "--lr", "3e-3",
       "--steps", "60", "--log-every", "10"]
if full:
    # ~100M params: the full qwen2-0.5b config is 0.5B; the smoke config is
    # tiny — use a mid-size variant via the train driver's arch override.
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen2-0.5b", "--batch", "4", "--seq", "256",
           "--algo", "fedavg", "--opt", "adam", "--lr", "1e-3",
           "--steps", "300", "--log-every", "10"]
cmd += args
print("running:", " ".join(cmd))
sys.exit(subprocess.call(cmd))
