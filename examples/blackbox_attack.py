"""Federated black-box attack (paper Sec V-A): FedZO finds a shared
adversarial perturbation querying only classifier outputs (CW loss, Eq. 21).

    PYTHONPATH=src python examples/blackbox_attack.py
"""
import sys
sys.path.insert(0, ".")

import jax
import jax.numpy as jnp

from benchmarks.common import attack_loss_fn, attack_setup
from repro.configs.base import FedZOConfig
from repro.fed.server import FedServer
from repro.models.simple import attack_success

cls_params, clients, cls_acc, (xi, yi) = attack_setup()
print(f"black-box classifier accuracy: {cls_acc:.3f}")
loss = attack_loss_fn(cls_params)

cfg = FedZOConfig(n_devices=10, n_participating=10, local_iters=20,
                  lr=1e-3, mu=1e-3, b1=25, b2=20)
pert0 = {"x": jnp.zeros((32 * 32 * 3,), jnp.float32)}
ev = jax.jit(lambda p: attack_success(p["x"], {"x": xi, "y": yi}, cls_params))
server = FedServer(loss, pert0, clients, cfg,
                   eval_fn=lambda p: {"attack_success": float(ev(p))})
server.run(20, log_every=5)
print(f"attack success rate: {server.history[-1]['attack_success']:.3f} "
      f"(loss {server.history[-1]['mean_local_loss']:.4f})")
