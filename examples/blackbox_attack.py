"""Federated black-box attack (paper Sec V-A), engine-native: FedZO finds a
shared adversarial perturbation querying only classifier outputs (CW loss,
Eq. 21). The whole experiment — store-driven rounds, in-scan attack-success
eval — runs as ONE compiled program (repro.workloads.attack, DESIGN.md §10),
then an SNR × seed AirComp sweep reproduces the Fig.-4-style curve family
as long-format CSV in results/.

    PYTHONPATH=src python examples/blackbox_attack.py
    PYTHONPATH=src python examples/blackbox_attack.py --smoke   # CI-sized
"""
import argparse
import os

from repro import sim
from repro.workloads import attack

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=20)
ap.add_argument("--sweep-rounds", type=int, default=10)
ap.add_argument("--smoke", action="store_true",
                help="CI-sized task + round counts (seconds, not minutes)")
ap.add_argument("--no-sweep", action="store_true")
args = ap.parse_args()

if args.smoke:
    task = attack.make_task(n_train=400, n_attack=96, n_clients=5,
                            train_steps=120)
    cfg = attack.default_config(task, local_iters=3, b2=6, b1=8)
    args.rounds, args.sweep_rounds = min(args.rounds, 4), 2
else:
    task = attack.make_task()
    cfg = attack.default_config(task)
print(f"black-box classifier accuracy: {task.clean_accuracy:.3f} "
      f"(client sizes {[len(c['y']) for c in task.clients]})")

res = attack.run(task, sim.fast_sim_config(cfg), args.rounds, eval_every=5,
                 donate=False)
hist = sim.history(res)
for h in hist:
    if "attack_success" in h:
        print(f"round {h['round']:3d}  attack_success "
              f"{h['attack_success']:.3f}  loss "
              f"{h.get('mean_local_loss', float('nan')):.4f}")
# headline number from the FINAL perturbation (the last in-scan eval can be
# rounds old depending on the eval cadence)
final = attack.attack_eval(task)(res.params)
print(f"attack success rate: {float(final['attack_success']):.3f} "
      f"(loss {hist[-1]['mean_local_loss']:.4f})")

if not args.no_sweep:
    out = os.path.join("results", "attack_snr_curve.csv")
    os.makedirs("results", exist_ok=True)
    recs = attack.run_sweep(task, sim.fast_sim_config(cfg),
                            snr_dbs=(-10.0, 0.0, 10.0), seeds=(0, 1),
                            rounds=args.sweep_rounds, eval_every=2,
                            out_csv=out)
    print(f"SNR sweep: {len(recs)} scenarios x {args.sweep_rounds} rounds "
          f"-> {out}")
    for r in recs:
        s = r["scenario"]
        print(f"  snr_db={s['snr_db']:+.0f} seed={s['seed']}  "
              f"final attack_success {float(r['evals']['attack_success'][-1]):.3f}")
