"""Train a CNN with FedZO in one jit (the Sec. V-B neural track,
DESIGN.md §11).

    PYTHONPATH=src python examples/train_cnn.py [--smoke] [--task cnn]

A trainable LeNet-style SmallCNN on Dirichlet-label-skewed synthetic
image shards: the whole multi-round federation — participation draws,
minibatch sampling, the H·b2 forward-only ZO queries per client,
size-weighted aggregation, and the in-scan top-1 test-accuracy eval —
runs as ONE compiled program. ``--task softmax`` / ``--task transformer``
swap the model through the same bridge; no gradient of the model is ever
taken.
"""
import argparse

from repro import sim
from repro.workloads import neural

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true", help="CI-sized run")
ap.add_argument("--task", default="cnn",
                choices=("softmax", "cnn", "transformer"))
ap.add_argument("--rounds", type=int, default=0)
args = ap.parse_args()

if args.smoke:
    task = neural.make_task(args.task, n_train=400, n_test=96, n_clients=6,
                            n_classes=4, **({"image_shape": (12, 12, 1),
                                             "width": 4}
                                            if args.task == "cnn" else
                                            {"n_features": 32}))
    cfg = neural.default_config(task, local_iters=4, b1=16, b2=16,
                                lr=2e-2 if args.task == "cnn" else 5e-2)
    rounds = args.rounds or 10
else:
    task = neural.make_task(args.task, n_train=2000, n_test=512,
                            n_clients=10)
    cfg = neural.default_config(task, lr=5e-2)
    rounds = args.rounds or 30

# the true untrained baseline — the engine's in-scan eval at round 0 runs
# after the first round's update, so history()[0] already reflects training
acc0 = float(task.accuracy(neural.params_init(task, cfg.seed), task.test))
res = neural.run(task, cfg, rounds, eval_every=2)
evals = [row for row in sim.history(res) if "test_acc" in row]
for row in evals:
    print({k: round(v, 4) for k, v in row.items()})
print(f"final test accuracy: {evals[-1]['test_acc']:.3f} "
      f"(untrained: {acc0:.3f})")
