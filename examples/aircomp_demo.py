"""AirComp over-the-air aggregation demo (paper Sec IV): explicit complex
channel simulation vs the Eq. 17 closed form, FedZO training through the
noisy channel at several SNRs — the whole SNR curve family as ONE vmapped
jit (repro.sim.sweep) — and channel-truncation scheduling (Sec. IV-A) end
to end: per-round Rayleigh draws mask out clients with |h| < h_min, and the
round reports how many actually transmitted (m_effective).

    PYTHONPATH=src python examples/aircomp_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import sim
from repro.configs.base import FedZOConfig
from repro.core.aircomp import aircomp_simulate_channel, schedule_by_channel
from repro.data.synthetic import make_classification, noniid_shards
from repro.fed.server import FedServer
from repro.models.simple import softmax_accuracy, softmax_init, softmax_loss

# 1. channel anatomy
deltas = jnp.asarray(np.random.default_rng(0).normal(size=(8, 256)),
                     dtype=jnp.float32)
y, diag = aircomp_simulate_channel(deltas, jax.random.key(0), snr_db=0.0,
                                   h_min=0.8)
err = float(jnp.linalg.norm(y - deltas.mean(0)) / jnp.linalg.norm(deltas.mean(0)))
print(f"recovered Δ̄ with relative error {err:.3f} at 0 dB SNR")
h, mask = schedule_by_channel(jax.random.key(1), 1000, 0.8)
print(f"channel-threshold scheduling keeps {float(mask.mean()):.2%} "
      f"of devices (theory: {np.exp(-0.64):.2%})")

# 2. end-to-end: FedZO through the noisy channel. The SNR sweep runs as a
# single jitted, vmapped program — every scenario shares one compile, the
# per-scenario channel noise rides the stacked config axis (sim/sweep.py).
x, yl = make_classification(5000, 784, 10, seed=0)
clients = noniid_shards(x[:4000], yl[:4000], 50)
test = {"x": jnp.asarray(x[4000:]), "y": jnp.asarray(yl[4000:])}
store = sim.build_store(clients)
p0 = softmax_init(None)
ev = jax.jit(lambda p: softmax_accuracy(p, test))

base = sim.fast_sim_config(
    FedZOConfig(n_devices=50, n_participating=20, local_iters=5,
                lr=1e-3, mu=1e-3, b1=25, b2=20, aircomp=True, h_min=0.8))
recs = sim.run_sweep(softmax_loss, p0, store, base,
                     sim.scenario_grid(snr_db=(0.0, -5.0)), 15,
                     eval_fn=lambda p: {"acc": softmax_accuracy(p, test)},
                     eval_every=14)
noise_free = sim.run_experiment(
    softmax_loss, p0, store, sim.fast_sim_config(
        FedZOConfig(n_devices=50, n_participating=20, local_iters=5,
                    lr=1e-3, mu=1e-3, b1=25, b2=20)), 15, donate=False)
print(f"SNR noise-free: test acc {float(ev(noise_free.params)):.3f}")
for rec in recs:
    print(f"SNR {rec['scenario']['snr_db']:+5.0f} dB: "
          f"test acc {float(rec['evals']['acc'][-1]):.3f}")

# 3. channel-truncation scheduling end to end: of the M sampled clients,
# only those with |h_i| >= h_min transmit each round (mask applied to both
# the mean and Δ_max); the engine runs all 8 rounds in one scan and the
# fused one-pass kernel aggregates the [M, n_pad] delta matrix. Reduced
# scale: interpret-mode Pallas on CPU makes this a correctness demo, the
# compiled TPU path is the perf target (DESIGN.md §8-9).
cfg = FedZOConfig(n_devices=50, n_participating=10, local_iters=5,
                  lr=1e-3, mu=1e-3, b1=25, b2=10, aircomp=True, snr_db=0.0,
                  h_min=0.8, channel_schedule=True, flat_params=True)
srv = FedServer(softmax_loss, softmax_init(None), clients, cfg, store=store)
hist = srv.run(8)
m_eff = [m["m_effective"] for m in hist]
print(f"channel-truncated AirComp: test acc {float(ev(srv.params)):.3f}, "
      f"m_effective per round min/mean/max = "
      f"{min(m_eff):.0f}/{np.mean(m_eff):.1f}/{max(m_eff):.0f} of 10 "
      f"(theory keeps {np.exp(-0.64):.0%})")
