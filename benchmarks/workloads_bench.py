"""Host-loop vs engine rounds/s for the gradient-free workloads
(repro.workloads, DESIGN.md §10).

Rows:

- ``workloads/attack_host_us_per_round``   — the Sec. V-A black-box attack
  on the per-round Python ``FedServer.run`` loop (how
  examples/blackbox_attack.py ran before the engine port). Eval-free, as
  is the engine row, so the speedup ratio compares identical round work.
- ``workloads/attack_engine_us_per_round`` — the same attack as ONE
  compiled scan, steady state.
- ``workloads/attack_speedup_x``           — host / engine rounds-per-s
  ratio for the attack port.
- ``workloads/hypertune_host_us_per_round`` /
  ``workloads/hypertune_engine_us_per_round`` /
  ``workloads/hypertune_speedup_x`` — the federated HP-tuning workload
  (every loss query inner-trains a head) on both drivers.

Regime note (DESIGN.md §10): the engine's ≥5× structural acceptance row
lives in sim_bench on the overhead-dominated softmax config. The CW attack
loss is CNN-forward-bound, and on the 2-core CPU container the two drivers
pay that conv equally — the attack speedup row hovers near parity here and
is tracked as a regression guard (the port's CPU value is the one-jit
SNR×seed sweep, the in-scan eval, and zero per-round host syncs; on
accelerators the wide plan's b2·b1-batched forwards pull ahead). The
hypertune round is overhead-heavier and shows ~2-3× on CPU.

The attack task is scale-reduced (CPU container): smaller surrogate
training run and fewer local iterates than the paper, identical structure.
CPU numbers are regression trackers, not TPU projections (DESIGN.md §6).
"""
from __future__ import annotations

import os
import time

import jax

ROUNDS = int(os.environ.get("WORKLOADS_BENCH_ROUNDS", "8"))


def _timed_engine(fn, args, rounds):
    out = fn(*args)                                   # compile
    jax.block_until_ready(jax.tree.leaves(out)[0])
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return (time.perf_counter() - t0) / rounds * 1e6


def run():
    from repro import sim
    from repro.fed.server import FedServer
    from repro.workloads import attack, hypertune

    rows = []
    task = attack.make_task(n_train=800, n_attack=128, n_clients=8,
                            train_steps=150)
    cfg = attack.default_config(task, local_iters=2, b2=16, b1=8)
    loss = attack.attack_loss(task)
    p0 = attack.pert_init()

    # -- host loop (the pre-engine examples/blackbox_attack.py round path:
    # numpy sampling, host batch stacking, per-round jit entry + metric
    # sync). Both drivers time eval-free rounds so the speedup rows compare
    # identical per-round work. --------------------------------------------
    srv = FedServer(loss, p0, task.clients, cfg)
    srv.run_round(0)                                  # compile
    t0 = time.perf_counter()
    srv.run(ROUNDS, driver="host")
    host_us = (time.perf_counter() - t0) / ROUNDS * 1e6
    rows.append(("workloads/attack_host_us_per_round", host_us, ROUNDS))

    # -- engine: store rounds as one compiled program ------------------------
    fcfg = sim.fast_sim_config(cfg)
    fn = sim.make_experiment_fn(loss, fcfg, ROUNDS, donate=False)
    eng_us = _timed_engine(
        fn, (p0, None, sim.experiment_key(fcfg), None, None, None,
             task.store), ROUNDS)
    rows.append(("workloads/attack_engine_us_per_round", eng_us, ROUNDS))
    rows.append(("workloads/attack_speedup_x", 0.0, host_us / eng_us))

    # -- hypertune workload: host loop vs engine -----------------------------
    ht = hypertune.make_task()
    hcfg = hypertune.default_config(ht)
    hloss, hp0 = hypertune.tune_loss(ht), hypertune.hp_init()
    hr = ROUNDS * 4                       # ms-scale rounds: amortize timing
    hsrv = FedServer(hloss, hp0, ht.clients, hcfg)
    hsrv.run_round(0)
    t0 = time.perf_counter()
    hsrv.run(hr, driver="host")
    ht_host_us = (time.perf_counter() - t0) / hr * 1e6
    rows.append(("workloads/hypertune_host_us_per_round", ht_host_us, hr))

    hfcfg = sim.fast_sim_config(hcfg)
    hfn = sim.make_experiment_fn(hloss, hfcfg, hr, donate=False)
    ht_us = _timed_engine(
        hfn, (hp0, None, sim.experiment_key(hfcfg), None, None, None,
              ht.store), hr)
    rows.append(("workloads/hypertune_engine_us_per_round", ht_us, hr))
    rows.append(("workloads/hypertune_speedup_x", 0.0, ht_host_us / ht_us))
    return rows
