"""One benchmark per paper table/figure (Sec. V), reduced scale.

Each ``fig*`` function returns rows (name, us_per_round, derived_metric).
The derived metric is the figure's y-axis quantity at the end of the run
(attack loss / attack success rate / train loss / test accuracy), so the
figure's ordering claims can be read directly off the CSV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (attack_loss_fn, attack_setup,
                               run_fedzo_rounds, softmax_setup)
from repro.configs.base import FedZOConfig
from repro.core import baselines, estimator
from repro.data.synthetic import sample_local_batches
from repro.fed.server import FedServer
from repro.models import simple
from repro.models.simple import (attack_success, softmax_accuracy,
                                 softmax_init, softmax_loss)

ROUNDS = 15


def _pert0():
    return {"x": jnp.zeros((32 * 32 * 3,), jnp.float32)}


def fig1a_h_sweep():
    """Fig 1a: attack loss vs rounds for H ∈ {1, 5, 10, 20}, N=M=10."""
    cls_params, clients, cls_acc, _ = attack_setup()
    loss = attack_loss_fn(cls_params)
    rows = [("fig1a/classifier_acc", 0.0, cls_acc)]
    for h in (1, 5, 10, 20):
        cfg = FedZOConfig(n_devices=10, n_participating=10, local_iters=h,
                          lr=2e-2, mu=1e-3, b1=25, b2=20, seed=h)
        p, hist, us = run_fedzo_rounds(loss, _pert0(), clients, cfg, ROUNDS)
        rows.append((f"fig1a/fedzo_H{h}_attack_loss", us,
                     hist[-1]["mean_local_loss"]))
    return rows


def fig1a_baselines():
    """Fig 1a overlay: DZOPA and ZONE-S under the same loss."""
    cls_params, clients, _, _ = attack_setup()
    loss = attack_loss_fn(cls_params)
    rng = np.random.default_rng(0)
    rows = []

    # DZOPA: one ZO update + consensus mixing per round, all agents
    cfg = FedZOConfig(lr=5e-2, mu=1e-3, b2=20)
    cp = jax.tree.map(lambda x: jnp.tile(x, (10, 1)), _pert0())
    last = None
    import time
    t0 = time.perf_counter()
    for t in range(ROUNDS):
        batches = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[sample_local_batches(c, rng, 1, 25) for c in clients])
        batches = jax.tree.map(lambda x: x[:, 0], batches)
        rngs = jax.random.split(jax.random.key(t), 10)
        cp, last = baselines.dzopa_round(loss, cp, batches, rngs, cfg)
    us = (time.perf_counter() - t0) / ROUNDS * 1e6
    rows.append(("fig1a/dzopa_attack_loss", us, float(last)))

    # ZONE-S: one sampled agent per round, penalty rho=500
    p = _pert0()
    t0 = time.perf_counter()
    for t in range(ROUNDS * 10):  # iteration count matched to FedZO queries
        i = int(rng.integers(0, 10))
        b = sample_local_batches(clients[i], rng, 1, 25)
        b = jax.tree.map(lambda x: x[0], b)
        p, l = baselines.zone_s_round(loss, p, b, jax.random.key(1000 + t),
                                      rho=500.0, mu=1e-3, b2=20)
    us = (time.perf_counter() - t0) / ROUNDS * 1e6
    rows.append(("fig1a/zones_attack_loss", us, float(loss(p, {
        "x": jnp.stack([c["x"] for c in clients[:1]][0][:25]),
        "y": jnp.stack([c["y"] for c in clients[:1]][0][:25])}))))
    return rows


def fig1b_m_sweep():
    """Fig 1b: effect of participating devices M ∈ {2, 5, 10}, N=10, H=10."""
    cls_params, clients, _, _ = attack_setup()
    loss = attack_loss_fn(cls_params)
    rows = []
    for m in (2, 5, 10):
        cfg = FedZOConfig(n_devices=10, n_participating=m, local_iters=10,
                          lr=2e-2, mu=1e-3, b1=25, b2=20, seed=m)
        p, hist, us = run_fedzo_rounds(loss, _pert0(), clients, cfg, ROUNDS)
        rows.append((f"fig1b/fedzo_M{m}_attack_loss", us,
                     hist[-1]["mean_local_loss"]))
    return rows


def fig1c_snr_sweep():
    """Fig 1c: AirComp-assisted FedZO at SNR ∈ {-10, -5, 0} dB vs noise-free."""
    cls_params, clients, _, _ = attack_setup()
    loss = attack_loss_fn(cls_params)
    rows = []
    for snr in (None, 0.0, -5.0, -10.0):
        cfg = FedZOConfig(n_devices=10, n_participating=10, local_iters=10,
                          lr=2e-2, mu=1e-3, b1=25, b2=20, seed=5,
                          aircomp=snr is not None,
                          snr_db=snr if snr is not None else 0.0, h_min=0.8)
        p, hist, us = run_fedzo_rounds(loss, _pert0(), clients, cfg, ROUNDS)
        tag = "noise_free" if snr is None else f"snr{int(snr)}dB"
        rows.append((f"fig1c/fedzo_{tag}_attack_loss", us,
                     hist[-1]["mean_local_loss"]))
    return rows


def fig2_attack_accuracy():
    """Fig 2: attack success rate (fraction of flipped predictions)."""
    cls_params, clients, _, (xi, yi) = attack_setup()
    loss = attack_loss_fn(cls_params)
    rows = []
    for h in (5, 20):
        cfg = FedZOConfig(n_devices=10, n_participating=10, local_iters=h,
                          lr=2e-2, mu=1e-3, b1=25, b2=20, seed=h)
        p, hist, us = run_fedzo_rounds(loss, _pert0(), clients, cfg, ROUNDS)
        succ = float(attack_success(p["x"], {"x": xi, "y": yi}, cls_params))
        rows.append((f"fig2/fedzo_H{h}_attack_success", us, succ))
    return rows


def fig3_softmax_h():
    """Fig 3: softmax regression, FedZO H ∈ {5, 20} vs FedAvg H=5 (N=50, M=20)."""
    clients, test = softmax_setup()
    rows = []
    ev = jax.jit(lambda p: softmax_accuracy(p, test))
    for h in (5, 20):
        cfg = FedZOConfig(n_devices=50, n_participating=20, local_iters=h,
                          lr=1e-3, mu=1e-3, b1=25, b2=20, seed=h)
        p, hist, us = run_fedzo_rounds(softmax_loss, softmax_init(None),
                                       clients, cfg, ROUNDS)
        rows.append((f"fig3/fedzo_H{h}_test_acc", us, float(ev(p))))
    cfg = FedZOConfig(n_devices=50, n_participating=20, local_iters=5,
                      lr=1e-3, seed=0)
    srv = FedServer(softmax_loss, softmax_init(None), clients, cfg,
                    algo="fedavg")
    import time
    t0 = time.perf_counter()
    srv.run(ROUNDS)
    us = (time.perf_counter() - t0) / ROUNDS * 1e6
    rows.append(("fig3/fedavg_H5_test_acc", us, float(ev(srv.params))))
    return rows


def fig4_softmax_m():
    """Fig 4: softmax regression M ∈ {10, 50}, H=5."""
    clients, test = softmax_setup()
    ev = jax.jit(lambda p: softmax_accuracy(p, test))
    rows = []
    for m in (10, 50):
        cfg = FedZOConfig(n_devices=50, n_participating=m, local_iters=5,
                          lr=1e-3, mu=1e-3, b1=25, b2=20, seed=m)
        p, hist, us = run_fedzo_rounds(softmax_loss, softmax_init(None),
                                       clients, cfg, ROUNDS)
        rows.append((f"fig4/fedzo_M{m}_test_acc", us, float(ev(p))))
    return rows


def fig5_softmax_snr():
    """Fig 5: AirComp softmax regression at SNR ∈ {-5, 0} dB vs noise-free."""
    clients, test = softmax_setup()
    ev = jax.jit(lambda p: softmax_accuracy(p, test))
    rows = []
    for snr in (None, 0.0, -5.0):
        cfg = FedZOConfig(n_devices=50, n_participating=20, local_iters=5,
                          lr=1e-3, mu=1e-3, b1=25, b2=20, seed=9,
                          aircomp=snr is not None,
                          snr_db=snr if snr is not None else 0.0, h_min=0.8)
        p, hist, us = run_fedzo_rounds(softmax_loss, softmax_init(None),
                                       clients, cfg, ROUNDS)
        tag = "noise_free" if snr is None else f"snr{int(snr)}dB"
        rows.append((f"fig5/fedzo_{tag}_test_acc", us, float(ev(p))))
    return rows


def table1_rate_scaling():
    """Table I: convergence improves with the M·H·T product (linear-speedup
    sanity: the loss after a fixed query budget decreases as M·H grows)."""
    clients, test = softmax_setup()
    rows = []
    losses = {}
    for (m, h) in ((5, 1), (10, 5), (20, 10)):
        cfg = FedZOConfig(n_devices=50, n_participating=m, local_iters=h,
                          lr=1e-3, mu=1e-3, b1=25, b2=10, seed=1)
        p, hist, us = run_fedzo_rounds(softmax_loss, softmax_init(None),
                                       clients, cfg, 10)
        l = float(softmax_loss(p, test))
        losses[(m, h)] = l
        rows.append((f"table1/loss_M{m}_H{h}", us, l))
    ordered = [losses[(5, 1)], losses[(10, 5)], losses[(20, 10)]]
    rows.append(("table1/monotone_in_MH", 0.0,
                 float(ordered[0] >= ordered[1] >= ordered[2])))
    return rows
