"""Sec. V-B figure harness on the simulation engine (DESIGN.md §11).

Each figure is ONE ``sim.run_sweep`` scenario grid over a neural FedZO task
(``repro.workloads.neural``): the shape-static axes {H, M, aircomp} group
per compile, the {snr_db, seed} axes vmap over a stacked config axis, and
every scenario's per-round metrics + in-scan test-accuracy curve land in
``results/`` as long-format CSV — the raw material for the paper's plots.

- **fig1** — baseline overlay (paper Figs. 1/2): DZOPA and ZONE-S on the
  same task/loss vs the FedZO engine run (reported for context).
- **fig2** — effect of local iterates H (paper Figs. 2/3): larger H makes
  more progress per communication round.
- **fig3** — effect of participating devices M (paper Fig. 4): larger M
  reduces update variance, converging faster at equal rounds.
- **fig4** — AirComp SNR family (paper Figs. 5/6): lower SNR injects more
  Eq.-17 noise and degrades convergence vs the noise-free channel.
- **table1** — rate scaling: the final loss at a fixed round budget
  improves as M·H grows (the linear-speedup claim, qualitatively).

Every figure closes with a qualitative-ordering row (final test loss,
averaged over seeds) so the paper's claims can be read straight off the
CSV; ``main`` exits non-zero if an ordering is violated.

CLI:  python benchmarks/paper_figures.py --smoke          # CI-sized
      python benchmarks/paper_figures.py --task cnn       # full CNN grids
``run()`` serves the same rows to ``benchmarks.run``.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

from repro import sim
from repro.workloads import neural

# ---------------------------------------------------------------------------
# scales: smoke is CI-sized (seconds); full is the slow-job grid

SMOKE = dict(
    task_kw=dict(n_train=640, n_test=192, n_clients=10, n_features=32,
                 n_classes=4, alpha=0.5),
    cfg_kw=dict(b1=16, b2=8, lr=5e-2, mu=1e-3, local_iters=2,
                n_participating=4, weight_by_size=True),
    rounds=9, eval_every=2, seeds=(0, 1),
    hs=(1, 4), ms=(2, 8), snrs=(-10.0, 20.0),
)

FULL = dict(
    task_kw=dict(n_train=4000, n_test=512, n_clients=20, n_features=784,
                 n_classes=10, alpha=0.5),
    cfg_kw=dict(b1=25, b2=10, lr=2e-2, mu=1e-3, local_iters=5,
                n_participating=10, weight_by_size=True),
    rounds=21, eval_every=4, seeds=(0, 1),
    hs=(1, 5, 10), ms=(2, 10, 20), snrs=(-10.0, 0.0, 20.0),
)

# per-mode overrides for the conv/transformer tracks: smoke stays CI-sized,
# full shrinks the data only enough to keep the grids minutes on CPU
TASK_KW = {
    "smoke": {
        "cnn": dict(image_shape=(12, 12, 1), width=4, n_train=400,
                    n_test=96),
        "transformer": dict(n_patches=4, d_model=16, d_ff=32, n_heads=2),
    },
    "full": {
        "cnn": dict(image_shape=(14, 14, 1), width=4, n_train=1200,
                    n_test=256),
        "transformer": dict(n_features=64, n_patches=8, d_model=16, d_ff=32,
                            n_heads=2, n_train=1200, n_test=256),
    },
}


def _scale(smoke: bool, task: str) -> dict:
    sc = {k: v for k, v in (SMOKE if smoke else FULL).items()}
    sc["task_kw"] = dict(sc["task_kw"])
    sc["cfg_kw"] = dict(sc["cfg_kw"])
    sc["task_kw"].update(TASK_KW["smoke" if smoke else "full"].get(task, {}))
    if task == "cnn":
        # image shape defines the feature count; lr retuned for the conv net
        sc["task_kw"].pop("n_features", None)
        sc["cfg_kw"]["lr"] = 5e-2
    return sc


def _final(rec, metric="test_loss") -> float:
    """Final in-scan eval value of one sweep record (the eval cadence is
    chosen so the last eval lands on the last round)."""
    return float(rec["evals"][metric][-1])


def _mean_by(recs, axis: str, metric="test_loss") -> dict:
    """Final ``metric`` averaged over seeds, keyed by the scenario's
    ``axis`` value."""
    acc: dict = {}
    for r in recs:
        acc.setdefault(r["scenario"][axis], []).append(_final(r, metric))
    return {k: float(np.mean(v)) for k, v in sorted(acc.items())}


def _rows(tag, by, us, *, ordering, ok):
    rows = [(f"{tag}_{k}", us, v) for k, v in by.items()]
    rows.append((f"{tag.rsplit('/', 1)[0]}/{ordering}", 0.0, float(ok)))
    return rows


# ---------------------------------------------------------------------------
# figures


def fig2_local_iterates(task, sc, out_csv=None):
    """Larger H converges faster at equal rounds (paper Figs. 2/3)."""
    cfg = neural.default_config(task, **sc["cfg_kw"])
    scen = sim.scenario_grid(local_iters=sc["hs"], seed=sc["seeds"])
    t0 = time.perf_counter()
    recs = neural.run_sweep(task, cfg, scen, sc["rounds"],
                            eval_every=sc["eval_every"],
                            eval_rows=sc["task_kw"]["n_test"],
                            out_csv=out_csv)
    us = (time.perf_counter() - t0) / len(scen) * 1e6
    by = _mean_by(recs, "local_iters")
    losses = list(by.values())  # keyed by H ascending
    return _rows(f"fig2/{task.name}_final_test_loss_H", by, us,
                 ordering="larger_H_converges_faster",
                 ok=all(a > b for a, b in zip(losses, losses[1:])))


def fig3_participation(task, sc, out_csv=None):
    """Larger M converges faster at equal rounds (paper Fig. 4)."""
    cfg = neural.default_config(task, **sc["cfg_kw"])
    scen = sim.scenario_grid(n_participating=sc["ms"], seed=sc["seeds"])
    t0 = time.perf_counter()
    recs = neural.run_sweep(task, cfg, scen, sc["rounds"],
                            eval_every=sc["eval_every"],
                            eval_rows=sc["task_kw"]["n_test"],
                            out_csv=out_csv)
    us = (time.perf_counter() - t0) / len(scen) * 1e6
    by = _mean_by(recs, "n_participating")
    losses = list(by.values())  # keyed by M ascending
    return _rows(f"fig3/{task.name}_final_test_loss_M", by, us,
                 ordering="larger_M_converges_faster",
                 ok=all(a > b for a, b in zip(losses, losses[1:])))


def fig4_aircomp_snr(task, sc, out_csv=None):
    """Lower SNR degrades AirComp convergence vs noise-free (Figs. 5/6)."""
    cfg = neural.default_config(task, **sc["cfg_kw"])
    scen = (sim.scenario_grid(seed=sc["seeds"]) +                # noise-free
            sim.scenario_grid(aircomp=(True,), snr_db=sc["snrs"],
                              seed=sc["seeds"]))
    t0 = time.perf_counter()
    recs = neural.run_sweep(task, cfg, scen, sc["rounds"],
                            eval_every=sc["eval_every"],
                            eval_rows=sc["task_kw"]["n_test"],
                            out_csv=out_csv)
    us = (time.perf_counter() - t0) / len(scen) * 1e6
    nf = float(np.mean([_final(r) for r in recs
                        if not r["scenario"].get("aircomp")]))
    by = _mean_by([r for r in recs if r["scenario"].get("aircomp")],
                  "snr_db")
    losses = list(by.values())  # keyed by SNR ascending: worst first
    # monotone in SNR, and the noisiest channel strictly worse than the
    # noise-free baseline (at high SNR AirComp ≈ noise-free, so no strict
    # ordering is claimed there)
    ok = all(a > b for a, b in zip(losses, losses[1:])) and losses[0] > nf
    rows = [(f"fig4/{task.name}_final_test_loss_noise_free", us, nf)]
    rows += _rows(f"fig4/{task.name}_final_test_loss_snr", by, us,
                  ordering="lower_SNR_degrades_aircomp", ok=ok)
    return rows


def fig1_baselines(task, sc, out_csv=None):
    """Fig. 1 overlay: the decentralized ZO baselines (DZOPA, ZONE-S) on
    the same task/loss vs the FedZO engine run. The baselines are reported
    for context (no cross-method ordering is asserted — too stochastic at
    reduced scale); the acceptance row pins that FedZO actually trains."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core import baselines
    from repro.data.synthetic import sample_local_batches

    cfg = neural.default_config(task, **sc["cfg_kw"])
    rounds, n = sc["rounds"], len(task.clients)
    p0 = neural.params_init(task, cfg.seed)
    test = jax.tree.map(lambda a: a[:sc["task_kw"]["n_test"]], task.test)
    rng = np.random.default_rng(cfg.seed)
    # the true untrained baseline — the in-scan eval at round 0 runs AFTER
    # the first round's step, so evals[0] would understate the improvement
    fz0 = float(task.loss(p0, test))

    t0 = time.perf_counter()
    res = neural.run(task, cfg, rounds, eval_every=sc["eval_every"],
                     eval_rows=sc["task_kw"]["n_test"], donate=False)
    fz = float(res.evals["test_loss"][-1])
    us_fz = (time.perf_counter() - t0) * 1e6

    # DZOPA: one ZO update + fully-connected consensus mixing per round,
    # all N agents (H=1 by construction)
    dz_round = jax.jit(lambda cp, b, r: baselines.dzopa_round(
        task.loss, cp, b, r, dataclasses.replace(cfg, local_iters=1)))
    cp = jax.tree.map(lambda x: jnp.stack([x] * n), p0)
    t0 = time.perf_counter()
    for t in range(rounds):
        b = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[sample_local_batches(c, rng, 1, cfg.b1) for c in task.clients])
        b = jax.tree.map(lambda x: x[:, 0], b)
        cp, _ = dz_round(cp, b, jax.random.split(jax.random.key(t), n))
    dz = float(task.loss(jax.tree.map(lambda x: x[0], cp), test))
    us_dz = (time.perf_counter() - t0) * 1e6

    # ZONE-S: one sampled agent per iteration, penalty ρ=500; iteration
    # count matched to FedZO's rounds × participating clients
    zs_round = jax.jit(lambda p, b, r: baselines.zone_s_round(
        task.loss, p, b, r, rho=500.0, mu=cfg.mu, b2=cfg.b2))
    p = p0
    t0 = time.perf_counter()
    for t in range(rounds * cfg.n_participating):
        i = int(rng.integers(0, n))
        b = jax.tree.map(lambda x: x[0],
                         sample_local_batches(task.clients[i], rng, 1,
                                              cfg.b1))
        p, _ = zs_round(p, b, jax.random.key(1000 + t))
    zs = float(task.loss(p, test))
    us_zs = (time.perf_counter() - t0) * 1e6

    if out_csv:
        with open(out_csv, "w") as f:
            f.write("scenario,round,metric,value\n")
            for tag, v in (("fedzo", fz), ("dzopa", dz), ("zone_s", zs)):
                f.write(f"method={tag},{rounds - 1},final_test_loss,{v}\n")
    return [(f"fig1/{task.name}_final_test_loss_fedzo", us_fz, fz),
            (f"fig1/{task.name}_final_test_loss_dzopa", us_dz, dz),
            (f"fig1/{task.name}_final_test_loss_zone_s", us_zs, zs),
            ("fig1/fedzo_trains", 0.0, float(fz < fz0))]


def table1_rate_scaling(task, sc, out_csv=None):
    """Table I sanity: at a fixed round budget the final loss improves as
    the M·H product grows (the linear-speedup claim, qualitatively)."""
    cfg = neural.default_config(task, **sc["cfg_kw"])
    ms, hs = sc["ms"], sc["hs"]
    pairs = list(zip(sorted(ms)[:len(hs)], sorted(hs)))     # (M, H) ascending
    scen = [dict(n_participating=m, local_iters=h, seed=s)
            for (m, h) in pairs for s in sc["seeds"]]
    t0 = time.perf_counter()
    recs = neural.run_sweep(task, cfg, scen, sc["rounds"],
                            eval_every=sc["eval_every"],
                            eval_rows=sc["task_kw"]["n_test"],
                            out_csv=out_csv)
    us = (time.perf_counter() - t0) / len(scen) * 1e6
    by: dict = {}
    for r in recs:
        mh = (r["scenario"]["n_participating"], r["scenario"]["local_iters"])
        by.setdefault(mh, []).append(_final(r))
    losses = [float(np.mean(by[mh])) for mh in sorted(by)]   # M·H ascending
    rows = [(f"table1/{task.name}_final_test_loss_M{m}_H{h}", us,
             float(np.mean(by[(m, h)]))) for (m, h) in sorted(by)]
    rows.append(("table1/monotone_in_MH", 0.0,
                 float(all(a > b for a, b in zip(losses, losses[1:])))))
    return rows


FIGURES = {"fig1": fig1_baselines, "fig2": fig2_local_iterates,
           "fig3": fig3_participation, "fig4": fig4_aircomp_snr,
           "table1": table1_rate_scaling}


# the boolean acceptance rows: every figure's qualitative claim
ORDERING_ROWS = ("/fedzo_trains", "_converges_faster", "_degrades_aircomp",
                 "/monotone_in_MH")


# ---------------------------------------------------------------------------
# drivers


def run_figures(task_name="softmax", *, smoke=True, figures=None,
                outdir="results"):
    """Run the requested figures on one task; returns benchmark rows."""
    sc = _scale(smoke, task_name)
    task = neural.make_task(task_name, **sc["task_kw"])
    os.makedirs(outdir, exist_ok=True)
    mode = "smoke" if smoke else "full"
    rows = []
    for fig in figures or sorted(FIGURES):
        out = os.path.join(outdir, f"{fig}_{task_name}_{mode}.csv")
        rows += FIGURES[fig](task, sc, out_csv=out)
    return rows


def run():
    """benchmarks.run entry: the smoke-scale softmax grids."""
    return run_figures("softmax", smoke=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grids (seconds, small synthetic task)")
    ap.add_argument("--task", default="softmax",
                    choices=("softmax", "cnn", "transformer"))
    ap.add_argument("--figures", default="",
                    help="comma list from {fig1, fig2, fig3, fig4, table1}; "
                         "default all")
    ap.add_argument("--out", default="results")
    args = ap.parse_args(argv)
    figures = [f.strip() for f in args.figures.split(",") if f.strip()] \
        or None
    if figures and not set(figures) <= set(FIGURES):
        ap.error(f"unknown figure(s) {sorted(set(figures) - set(FIGURES))}; "
                 f"choose from {sorted(FIGURES)}")
    rows = run_figures(args.task, smoke=args.smoke, figures=figures,
                       outdir=args.out)
    print("name,us_per_call,derived")
    bad = []
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
        if name.endswith(ORDERING_ROWS) and not derived:
            bad.append(name)
    if bad:
        print(f"ordering violated: {bad}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
