"""Roofline summary rows derived from the dry-run artifacts (results/*.jsonl).

derived = dominant-term seconds; us_per_call = compile seconds (per-combo
compile cost of the production program)."""
from __future__ import annotations

import glob
import json
import os

RESULTS_GLOB = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun_*.jsonl")


def load_records():
    best = {}
    for f in sorted(glob.glob(RESULTS_GLOB)):
        for line in open(f):
            r = json.loads(line)
            k = (r["arch"], r["shape"], r["multi_pod"], r.get("algo", "fedzo"))
            if "error" not in r or k not in best:
                best[k] = r
    return best


def run():
    rows = []
    recs = load_records()
    for (arch, shape, mp, algo), r in sorted(recs.items()):
        if "error" in r or mp:
            continue
        roof = r["roofline_s"]
        dom = r["dominant_term"]
        rows.append((f"roofline/{arch}/{shape}/{dom}",
                     r["compile_s"] * 1e6, roof[dom]))
    if not rows:
        rows.append(("roofline/no_dryrun_artifacts_found", 0.0, 0.0))
    return rows
