"""Benchmark harness: one entry per paper table/figure (DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV. ``--quick`` runs the kernel,
ZO-path, round-engine, and roofline benches; default additionally runs the
paper-figure suites (≈10-20 min on CPU).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (kernels_bench, roofline_report, round_bench,
                            sim_bench, workloads_bench, zo_path_bench)
    suites = [("kernels", kernels_bench.run),
              ("zo_path", zo_path_bench.run),
              ("round", round_bench.run),
              ("sim", sim_bench.run),
              ("algos", sim_bench.run_algos),
              ("workloads", workloads_bench.run),
              ("roofline", roofline_report.run)]
    if not args.quick:
        # the Sec. V-B figure harness (one vmapped sweep per figure; CSVs
        # land in results/) at smoke scale — the full grids run via the
        # slow-marked test / the paper_figures CLI
        from benchmarks import paper_figures as pf
        suites = [("figures", pf.run)] + suites

    print("name,us_per_call,derived")
    failed = False
    for tag, fn in suites:
        if args.only and args.only != tag:
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            failed = True
            print(f"{tag}/ERROR,0,nan", flush=True)
            traceback.print_exc(file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
