"""Benchmark harness: one entry per paper table/figure (DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV. ``--quick`` runs the kernel,
ZO-path, round-engine, and roofline benches; default additionally runs the
paper-figure suites (≈10-20 min on CPU).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (kernels_bench, roofline_report, round_bench,
                            sim_bench, workloads_bench, zo_path_bench)
    suites = [("kernels", kernels_bench.run),
              ("zo_path", zo_path_bench.run),
              ("round", round_bench.run),
              ("sim", sim_bench.run),
              ("workloads", workloads_bench.run),
              ("roofline", roofline_report.run)]
    if not args.quick:
        from benchmarks import paper_figures as pf
        suites = [
            ("fig1a", pf.fig1a_h_sweep), ("fig1a_b", pf.fig1a_baselines),
            ("fig1b", pf.fig1b_m_sweep), ("fig1c", pf.fig1c_snr_sweep),
            ("fig2", pf.fig2_attack_accuracy), ("fig3", pf.fig3_softmax_h),
            ("fig4", pf.fig4_softmax_m), ("fig5", pf.fig5_softmax_snr),
            ("table1", pf.table1_rate_scaling),
        ] + suites

    print("name,us_per_call,derived")
    failed = False
    for tag, fn in suites:
        if args.only and args.only != tag:
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            failed = True
            print(f"{tag}/ERROR,0,nan", flush=True)
            traceback.print_exc(file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
