"""Benchmark harness: one entry per paper table/figure (DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV, and persists every suite's rows
to ``results/BENCH_<suite>.json`` (``obs.save_bench``): the previous
snapshot is pushed onto the file's bounded ``history`` list, so the perf
trajectory accumulates per run — render it with
``python results/make_tables.py --bench``. ``--no-save`` keeps a run
print-only; ``--out-dir`` redirects the snapshots.

``--quick`` runs the kernel, ZO-path, round-engine, and roofline benches;
default additionally runs the paper-figure suites (≈10-20 min on CPU).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--no-save", action="store_true",
                    help="don't snapshot rows to results/BENCH_*.json")
    ap.add_argument("--out-dir", default=None,
                    help="snapshot directory (default: results/)")
    args = ap.parse_args()

    from benchmarks import (kernels_bench, roofline_report, round_bench,
                            sim_bench, workloads_bench, zo_path_bench)
    suites = [("kernels", kernels_bench.run),
              ("zo_path", zo_path_bench.run),
              ("round", round_bench.run),
              ("sim", sim_bench.run),
              ("algos", sim_bench.run_algos),
              ("scenario", sim_bench.run_scenario),
              ("tiered", sim_bench.run_tiered),
              ("workloads", workloads_bench.run),
              ("roofline", roofline_report.run)]
    if not args.quick:
        # the Sec. V-B figure harness (one vmapped sweep per figure; CSVs
        # land in results/) at smoke scale — the full grids run via the
        # slow-marked test / the paper_figures CLI
        from benchmarks import paper_figures as pf
        suites = [("figures", pf.run)] + suites

    print("name,us_per_call,derived")
    failed = False
    for tag, fn in suites:
        if args.only and args.only != tag:
            continue
        try:
            rows = list(fn())
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            failed = True
            print(f"{tag}/ERROR,0,nan", flush=True)
            traceback.print_exc(file=sys.stderr)
            continue
        if not args.no_save:
            try:
                from repro import obs
                obs.save_bench(tag, rows, out_dir=args.out_dir,
                               config={"quick": args.quick})
            except Exception:  # noqa: BLE001 — a snapshot failure must
                traceback.print_exc(file=sys.stderr)  # not fail the bench
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
