"""Shared timing helper for the benchmark suites.

The figure-specific setups that used to live here moved into the workload
layer: the attack task builder is ``repro.workloads.attack.make_task``, the
neural classification tasks are ``repro.workloads.neural.make_task``
(benchmarks/paper_figures.py drives them).
"""
from __future__ import annotations

import time

import jax


def timed(fn, *args, n=1):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / n * 1e6  # µs
