"""Shared setup for the paper-figure benchmarks.

Scales are reduced (CPU container) but keep every structural element of the
paper's experiments: the CW attack loss on a trained conv classifier over
synthetic CIFAR-like images (Sec V-A), and softmax regression on a synthetic
Fashion-MNIST-like non-iid split (Sec V-B).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedZOConfig
from repro.data.synthetic import (make_classification, noniid_shards,
                                  random_partition)
from repro.models import simple


def timed(fn, *args, n=1):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / n * 1e6  # µs


@functools.lru_cache(maxsize=1)
def attack_setup(n_train=2000, n_attack=512, n_clients=10, seed=0):
    """Train the black-box CNN on synthetic CIFAR-like data, then build the
    federated attack problem over the correctly-classified images."""
    x, y = make_classification(n_train + 512, 32 * 32 * 3, 10, seed=seed,
                               scale=0.35, image_shape=(32, 32, 3))
    xtr, ytr = jnp.asarray(x[:n_train]), jnp.asarray(y[:n_train])
    params = simple.cnn_init(jax.random.key(seed))

    @jax.jit
    def sgd_step(p, xb, yb):
        loss, g = jax.value_and_grad(simple.cnn_loss)(p, {"x": xb, "y": yb})
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g), loss

    rng = np.random.default_rng(seed)
    for step in range(300):
        idx = rng.integers(0, n_train, 64)
        params, loss = sgd_step(params, xtr[idx], ytr[idx])

    pred = jnp.argmax(simple.cnn_logits(params, jnp.asarray(x)), -1)
    correct = np.asarray(pred == jnp.asarray(y))
    acc = correct[:n_train].mean()
    xi, yi = x[correct], y[correct]
    xi, yi = xi[:n_attack], yi[:n_attack]
    clients = random_partition(xi.reshape(len(yi), -1), yi, n_clients,
                               seed=seed)
    for c in clients:
        c["x"] = c["x"].reshape(-1, 32, 32, 3)
    return params, clients, float(acc), (jnp.asarray(xi), jnp.asarray(yi))


def attack_loss_fn(classifier_params):
    # c=0.3 keeps the paper's margin-vs-distortion trade-off but weights the
    # attack term enough to make visible progress at reduced round counts.
    def loss(pert_params, batch):
        return simple.cw_attack_loss(pert_params["x"], batch,
                                     classifier_params, c=0.3)
    return loss


@functools.lru_cache(maxsize=1)
def softmax_setup(n=4000, n_clients=50, seed=0):
    x, y = make_classification(n + 1000, 784, 10, seed=seed)
    clients = noniid_shards(x[:n], y[:n], n_clients)
    test = {"x": jnp.asarray(x[n:]), "y": jnp.asarray(y[n:])}
    return clients, test


def run_fedzo_rounds(loss_fn, params0, clients, cfg: FedZOConfig, rounds,
                     eval_fn=None):
    from repro.fed.server import FedServer
    srv = FedServer(loss_fn, params0, clients, cfg, eval_fn=eval_fn)
    t0 = time.perf_counter()
    hist = srv.run(rounds)
    us = (time.perf_counter() - t0) / rounds * 1e6
    return srv.params, hist, us
