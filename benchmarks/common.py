"""Shared setup for the paper-figure benchmarks.

Scales are reduced (CPU container) but keep every structural element of the
paper's experiments: the CW attack loss on a trained conv classifier over
synthetic CIFAR-like images (Sec V-A), and softmax regression on a synthetic
Fashion-MNIST-like non-iid split (Sec V-B).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.configs.base import FedZOConfig
from repro.data.synthetic import make_classification, noniid_shards
from repro.models import simple


def timed(fn, *args, n=1):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / n * 1e6  # µs


def attack_setup(n_train=2000, n_attack=512, n_clients=10, seed=0):
    """Legacy tuple view of the attack workload (the canonical builder now
    lives in ``repro.workloads.attack`` and caches the trained surrogate)."""
    from repro.workloads import attack
    task = attack.make_task(n_train=n_train, n_attack=n_attack,
                            n_clients=n_clients, seed=seed)
    return (task.classifier, task.clients, task.clean_accuracy,
            (task.eval_batch["x"], task.eval_batch["y"]))


def attack_loss_fn(classifier_params):
    from repro.workloads.attack import CW_C

    def loss(pert_params, batch):
        return simple.cw_attack_loss(pert_params["x"], batch,
                                     classifier_params, c=CW_C)
    return loss


@functools.lru_cache(maxsize=1)
def softmax_setup(n=4000, n_clients=50, seed=0):
    x, y = make_classification(n + 1000, 784, 10, seed=seed)
    clients = noniid_shards(x[:n], y[:n], n_clients)
    test = {"x": jnp.asarray(x[n:]), "y": jnp.asarray(y[n:])}
    return clients, test


def run_fedzo_rounds(loss_fn, params0, clients, cfg: FedZOConfig, rounds,
                     eval_fn=None):
    from repro.fed.server import FedServer
    srv = FedServer(loss_fn, params0, clients, cfg, eval_fn=eval_fn)
    t0 = time.perf_counter()
    hist = srv.run(rounds)
    us = (time.perf_counter() - t0) / rounds * 1e6
    return srv.params, hist, us
