"""Host-loop vs in-jit federation engine (DESIGN.md §9) on the quickstart
softmax-regression experiment (N=50, M=10, H=5, b1=25, b2=20, d=7850).

Rows:

- ``sim/host_loop_us_per_round``   — the per-round Python ``FedServer.run``
  loop as it ships (numpy sampling, host batch stacking, one jit entry per
  round, per-round metric sync), measured over SIM_BENCH_ROUNDS rounds.
- ``sim/engine_us_per_round``      — the same experiment as ONE compiled
  scan (``sim.run_experiment`` under ``sim.fast_sim_config``: in-jit
  store sampling, batched-direction local phases, donated carry), steady
  state (compile excluded).
- ``sim/engine_loop_est_us_per_round`` — the engine scanning the UNCHANGED
  loop-estimator round: isolates the structural scan/store gain from the
  batched-direction gain (measured over fewer rounds; per-round metric).
- ``sim/engine_speedup_x``         — host loop / fast engine (the ≥5×
  acceptance row).
- ``sim/engine_tap_us_per_round`` / ``sim/tap_overhead_pct`` — the engine
  with a worst-case in-scan telemetry tap (``tap_every=1`` into a
  NullSink, one io_callback per round) vs taps-off (<10% acceptance).
- ``sim/sharded_dev{n}_us_per_round`` — the clients-axis shard_map round
  inside the engine on a forced n-device host platform (subprocess), n ∈
  {1, 2}: the device-scaling story at laptop scale.
- ``tiered/*`` (``run_tiered``, snapshot ``BENCH_tiered.json``) — the
  host-resident HostStore streaming engine vs the resident scan on the
  same experiment, plus an N=100k-client CPU run with prefetch-stall and
  host/device residency accounting (DESIGN.md §15).

CPU numbers are regression trackers, not TPU projections (§6).
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import numpy as np

ROUNDS = int(os.environ.get("SIM_BENCH_ROUNDS", "50"))


def _quickstart_setup():
    import jax.numpy as jnp
    from repro.configs.base import FedZOConfig
    from repro.data.synthetic import make_classification, noniid_shards

    x, y = make_classification(7000, 784, 10, seed=0)
    clients = noniid_shards(x[:6000], y[:6000], 50)
    cfg = FedZOConfig(n_devices=50, n_participating=10, local_iters=5,
                      lr=1e-3, mu=1e-3, b1=25, b2=20)
    del jnp
    return clients, cfg


def _sharded_subprocess_row(n_dev: int):
    """Time the sharded engine round on a forced n-device host platform.
    XLA flags must be set before jax init, so this runs out-of-process."""
    code = f"""
import time
import jax
from repro import sim
from repro.configs.base import FedZOConfig
from repro.data.synthetic import make_classification, noniid_shards
from repro.models.simple import softmax_init, softmax_loss

x, y = make_classification(7000, 784, 10, seed=0)
clients = noniid_shards(x[:6000], y[:6000], 50)
cfg = sim.fast_sim_config(FedZOConfig(n_devices=50, n_participating=10,
                                      local_iters=5, lr=1e-3, mu=1e-3,
                                      b1=25, b2=20))
store = sim.build_store(clients)
mesh = sim.make_clients_mesh()
rf = sim.make_sharded_round(softmax_loss, cfg, mesh)
R = 10
fn = sim.make_experiment_fn(softmax_loss, cfg, R, round_fn=rf, donate=False)
key = sim.experiment_key(cfg)
p = softmax_init(None)
out = fn(p, None, key, None, None, None, store)
jax.block_until_ready(out[0])
t0 = time.perf_counter()
out = fn(p, None, key, None, None, None, store)
jax.block_until_ready(out[0])
print("US_PER_ROUND", (time.perf_counter() - t0) / R * 1e6)
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_dev}")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src"),
         env.get("PYTHONPATH", "")])
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1200, env=env)
    if res.returncode != 0:
        raise RuntimeError(f"sharded bench (n_dev={n_dev}) failed:\n"
                           f"{res.stderr[-2000:]}")
    for line in res.stdout.splitlines():
        if line.startswith("US_PER_ROUND"):
            return float(line.split()[1])
    raise RuntimeError("sharded bench printed no timing")


def run():
    from repro import sim
    from repro.fed.server import FedServer
    from repro.models.simple import softmax_init, softmax_loss

    rows = []
    clients, cfg = _quickstart_setup()

    # -- host loop (the reference FedServer.run python path) ------------------
    srv = FedServer(softmax_loss, softmax_init(None), clients, cfg)
    srv.run_round(0)                                  # compile
    t0 = time.perf_counter()
    srv.run(ROUNDS, driver="host")
    host_us = (time.perf_counter() - t0) / ROUNDS * 1e6
    rows.append(("sim/host_loop_us_per_round", host_us, ROUNDS))

    # -- in-jit engine, fast execution plan -----------------------------------
    store = sim.build_store(clients)
    fcfg = sim.fast_sim_config(cfg)
    fn = sim.make_experiment_fn(softmax_loss, fcfg, ROUNDS, donate=False)
    key = sim.experiment_key(fcfg)
    p0 = softmax_init(None)
    out = fn(p0, None, key, None, None, None, store)  # compile
    jax.block_until_ready(out[0])
    t0 = time.perf_counter()
    out = fn(p0, None, key, None, None, None, store)
    jax.block_until_ready(out[0])
    eng_us = (time.perf_counter() - t0) / ROUNDS * 1e6
    rows.append(("sim/engine_us_per_round", eng_us, ROUNDS))
    rows.append(("sim/engine_speedup_x", 0.0, host_us / eng_us))

    # -- engine scanning the UNCHANGED loop-estimator round -------------------
    r_loop = max(2, ROUNDS // 10)
    fn2 = sim.make_experiment_fn(softmax_loss, cfg, r_loop, donate=False)
    out = fn2(p0, None, sim.experiment_key(cfg), None, None, None, store)
    jax.block_until_ready(out[0])
    t0 = time.perf_counter()
    out = fn2(p0, None, sim.experiment_key(cfg), None, None, None, store)
    jax.block_until_ready(out[0])
    rows.append(("sim/engine_loop_est_us_per_round",
                 (time.perf_counter() - t0) / r_loop * 1e6, r_loop))

    # -- in-scan tap overhead (acceptance: <10% on µs/round) ------------------
    # tap_every=1 (every round fires the io_callback) into a NullSink is
    # the worst case; real cadences (tap_every=10+) amortize further
    from repro import obs
    tap = obs.RoundTap(obs.NullSink(), 1)
    fnt = sim.make_experiment_fn(softmax_loss, fcfg, ROUNDS, donate=False,
                                 tap=tap)
    out = fnt(p0, None, key, None, None, None, store)  # compile
    jax.block_until_ready(out[0])
    t0 = time.perf_counter()
    out = fnt(p0, None, key, None, None, None, store)
    jax.block_until_ready(out[0])
    tap_us = (time.perf_counter() - t0) / ROUNDS * 1e6
    rows.append(("sim/engine_tap_us_per_round", tap_us, ROUNDS))
    rows.append(("sim/tap_overhead_pct", 0.0,
                 (tap_us / eng_us - 1.0) * 100.0))

    # -- fault-injection layer overhead (acceptance: <5% on rounds/s) ---------
    faults = sim.FaultModel(p_fail=0.05, p_recover=0.4, deadline=2.0,
                            p_corrupt=0.02)
    fstate = faults.init_state(store.n_clients)
    fnf = sim.make_experiment_fn(softmax_loss, fcfg, ROUNDS, faults=faults,
                                 donate=False)
    out = fnf(p0, None, key, fstate, None, None, store)  # compile
    jax.block_until_ready(out[0])
    t0 = time.perf_counter()
    out = fnf(p0, None, key, fstate, None, None, store)
    jax.block_until_ready(out[0])
    faults_us = (time.perf_counter() - t0) / ROUNDS * 1e6
    rows.append(("sim/engine_faults_us_per_round", faults_us, ROUNDS))
    rows.append(("sim/faults_overhead_pct", 0.0,
                 (faults_us / eng_us - 1.0) * 100.0))

    # -- device scaling of the sharded round ----------------------------------
    dev_counts = [1] + ([2] if (os.cpu_count() or 1) >= 2 else [])
    for n_dev in dev_counts:
        try:
            us = _sharded_subprocess_row(n_dev)
            rows.append((f"sim/sharded_dev{n_dev}_us_per_round", us, n_dev))
        except Exception as e:  # noqa: BLE001 — report, don't sink the suite
            rows.append((f"sim/sharded_dev{n_dev}_ERROR", 0.0, repr(e)[:60]))
    return rows


# strategy-name -> config overrides on top of the fast engine plan; every
# variant runs the SAME experiment shape so overhead-vs-fedzo is pure
# algorithm cost (loss wrap, state gather/scatter, server correction)
ALGO_VARIANTS = (
    ("fedzo", {}),
    ("fedprox", {"strategy": "fedprox", "prox_mu": 0.01}),
    ("feddyn", {"strategy": "feddyn", "dyn_alpha": 0.01}),
    ("scaffold", {"strategy": "scaffold"}),
    ("fedzo_surrogate", {"direction_conv": "surrogate"}),
)


def run_algos():
    """Per-strategy engine cost: µs/round for each registered ZO strategy
    (+ the surrogate estimator) on the quickstart experiment under the fast
    engine plan, plus its overhead vs plain FedZO in %. (The harness —
    benchmarks/run.py — snapshots these rows to ``results/BENCH_algos.json``
    via ``obs.save_bench``, same as every other suite.)"""
    import dataclasses

    from repro import sim
    from repro.models.simple import softmax_init, softmax_loss

    rows = []
    clients, cfg = _quickstart_setup()
    store = sim.build_store(clients)
    rounds = max(2, ROUNDS // 2)
    base_us = None
    for name, overrides in ALGO_VARIANTS:
        acfg = dataclasses.replace(sim.fast_sim_config(cfg), **overrides)
        fn = sim.make_experiment_fn(softmax_loss, acfg, rounds, donate=False)
        key = sim.experiment_key(acfg)
        p0 = softmax_init(None)
        from repro.core import strategy as strategy_mod
        zstate = strategy_mod.get(acfg.strategy).init_state(p0, acfg,
                                                            store.n_clients)
        out = fn(p0, None, key, None, None, zstate, store)  # compile
        jax.block_until_ready(out[0])
        t0 = time.perf_counter()
        out = fn(p0, None, key, None, None, zstate, store)
        jax.block_until_ready(out[0])
        us = (time.perf_counter() - t0) / rounds * 1e6
        rows.append((f"algos/{name}_us_per_round", us, rounds))
        if name == "fedzo":
            base_us = us
        else:
            rows.append((f"algos/{name}_overhead_vs_fedzo_pct", 0.0,
                         (us / base_us - 1.0) * 100.0))
    return rows


def run_scenario():
    """Wireless-scenario engine cost (DESIGN.md §16): the correlated-fading
    chain and energy-gated participation vs the channel-off i.i.d. draw on
    the quickstart experiment under channel scheduling. Rows (snapshot
    ``results/BENCH_scenario.json`` via the harness):

    - ``scenario/channel_off_us_per_round`` — i.i.d. per-round draw (the
      paper's Sec. IV-A baseline) under the fast engine plan.
    - ``scenario/fading_us_per_round`` / ``_overhead_pct`` — the AR(1)
      chain (ρ=0.9) carried through the scan.
    - ``scenario/gated_us_per_round`` / ``_overhead_pct`` — fading plus
      battery gating with a budget that drains mid-run, and
      ``scenario/gated_m_effective_mean`` — the mean surviving cohort the
      drain produces (the row that shows the gate actually bites)."""
    import dataclasses

    from repro import sim
    from repro.models.simple import softmax_init, softmax_loss

    rows = []
    clients, cfg = _quickstart_setup()
    store = sim.build_store(clients)
    p0 = softmax_init(None)
    rounds = max(4, ROUNDS // 2)
    base = dataclasses.replace(sim.fast_sim_config(cfg),
                               channel_schedule=True, h_min=0.3)

    def timed(c):
        from repro.sim import channel as channel_lib
        fn = sim.make_experiment_fn(softmax_loss, c, rounds, donate=False)
        key = sim.experiment_key(c)
        cm = c.channel_model
        cstate = (cm.init_state(store.n_clients, channel_lib.init_key(key))
                  if cm is not None else None)
        out = fn(p0, None, key, None, cstate, None, store)  # compile
        jax.block_until_ready(out[0])
        t0 = time.perf_counter()
        out = fn(p0, None, key, None, cstate, None, store)
        jax.block_until_ready(out[0])
        return (time.perf_counter() - t0) / rounds * 1e6, out

    off_us, _ = timed(base)
    rows.append(("scenario/channel_off_us_per_round", off_us, rounds))

    fad_us, _ = timed(dataclasses.replace(
        base, channel_model=sim.ChannelModel(rho=0.9)))
    rows.append(("scenario/fading_us_per_round", fad_us, rounds))
    rows.append(("scenario/fading_overhead_pct", 0.0,
                 (fad_us / off_us - 1.0) * 100.0))

    gm = sim.ChannelModel(rho=0.9, battery=float(max(2, rounds // 2)),
                          tx_cost=1.0)
    gat_us, out = timed(dataclasses.replace(base, channel_model=gm))
    ring = out[6]
    rows.append(("scenario/gated_us_per_round", gat_us, rounds))
    rows.append(("scenario/gated_overhead_pct", 0.0,
                 (gat_us / off_us - 1.0) * 100.0))
    rows.append(("scenario/gated_m_effective_mean", 0.0,
                 round(float(np.mean(np.asarray(ring["m_effective"]))), 2)))
    return rows


def _ragged_population(n_clients, lo, hi, n_features=24, n_classes=4,
                       seed=0):
    """A size-skewed synthetic federation at arbitrary N — the tiered
    store's regime. Row counts are drawn uniform [lo, hi); features come
    from one make_classification pool sliced per client."""
    from repro.data.synthetic import make_classification

    rng = np.random.default_rng(seed)
    sizes = rng.integers(lo, hi, size=n_clients)
    x, y = make_classification(int(sizes.sum()), n_features, n_classes,
                               seed=seed)
    clients, off = [], 0
    for s in sizes:
        clients.append({"x": x[off:off + s], "y": y[off:off + s]})
        off += s
    return clients


def run_tiered():
    """Tiered HostStore vs resident engine (DESIGN.md §15).

    Quickstart-scale rows measure the streaming overhead against the
    device-resident scan on the SAME (bitwise-identical) experiment:
    ``tiered/engine_us_per_round`` + ``tiered/overhead_vs_resident_pct``,
    plus the prefetch-stall and memory-residency accounting
    (``prefetch_stall_pct``, ``host_bytes``, ``device_bytes`` — the staged
    segment + one prefetch buffer is ALL the population data on device).

    The ``tiered/scale100k_*`` rows run N=100k clients (ragged, bucketed)
    on CPU — far past what the resident store's [N, cap] layout would
    admit alongside itself — and report the same stall/residency numbers
    (``TIERED_BENCH_CLIENTS`` scales N)."""
    from repro import sim
    from repro.models.simple import softmax_init, softmax_loss

    rows = []
    clients, cfg = _quickstart_setup()
    fcfg = sim.fast_sim_config(cfg)
    p0 = softmax_init(None)
    rounds = max(4, ROUNDS // 2)

    store = sim.build_store(clients)
    res = sim.run_experiment(softmax_loss, p0, store, fcfg, rounds,
                             donate=False)            # compile
    jax.block_until_ready(res.params["w"])
    t0 = time.perf_counter()
    res = sim.run_experiment(softmax_loss, p0, store, fcfg, rounds,
                             donate=False)
    jax.block_until_ready(res.params["w"])
    res_us = (time.perf_counter() - t0) / rounds * 1e6
    rows.append(("tiered/resident_us_per_round", res_us, rounds))

    host = sim.build_host_store(clients, n_buckets=4)
    tier = sim.run_experiment(softmax_loss, p0, host, fcfg, rounds,
                              donate=False)           # compile
    jax.block_until_ready(tier.params["w"])
    t0 = time.perf_counter()
    tier = sim.run_experiment(softmax_loss, p0, host, fcfg, rounds,
                              donate=False)
    jax.block_until_ready(tier.params["w"])
    tier_us = (time.perf_counter() - t0) / rounds * 1e6
    pf = tier.prefetch
    rows.append(("tiered/engine_us_per_round", tier_us, rounds))
    rows.append(("tiered/overhead_vs_resident_pct", 0.0,
                 (tier_us / res_us - 1.0) * 100.0))
    rows.append(("tiered/prefetch_stall_pct", 0.0,
                 round(pf["stall_pct"], 2)))
    rows.append(("tiered/host_bytes", 0.0, pf["host_bytes"]))
    rows.append(("tiered/device_bytes", 0.0,
                 pf["device_segment_bytes_max"]))

    # -- N=100k: the regime the resident tier cannot reach ---------------
    n_big = int(os.environ.get("TIERED_BENCH_CLIENTS", "100000"))
    big = _ragged_population(n_big, 6, 13, seed=1)
    import dataclasses
    bcfg = dataclasses.replace(fcfg, n_devices=n_big, n_participating=32,
                               b1=4, local_iters=2)
    bstore = sim.build_host_store(big, n_buckets=4)
    del big
    b_rounds = 6
    bp0 = softmax_init(None, 24, 4)
    bres = sim.run_experiment(softmax_loss, bp0, bstore, bcfg, b_rounds,
                              donate=False)
    jax.block_until_ready(bres.params["w"])
    bpf = bres.prefetch
    rows.append(("tiered/scale100k_us_per_round",
                 bpf["wall_s"] / b_rounds * 1e6, n_big))
    rows.append(("tiered/scale100k_prefetch_stall_pct", 0.0,
                 round(bpf["stall_pct"], 2)))
    rows.append(("tiered/scale100k_host_bytes", 0.0, bpf["host_bytes"]))
    rows.append(("tiered/scale100k_device_bytes", 0.0,
                 bpf["device_segment_bytes_max"]))
    return rows
