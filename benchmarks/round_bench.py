"""Old-vs-new round-engine benchmark: the per-leaf pytree round vs the
flat-buffer round (DESIGN.md §8) on the softmax-regression model (d = 7850).

Two kinds of rows, as in zo_path_bench:

- ``*_us_per_round`` — measured wall time of one jitted ``round_simulated``
  over M clients (interpret-mode Pallas on CPU: regression tracking, not a
  TPU projection). Reported for the plain-mean and the AirComp round.
- ``*_agg_hbm_passes`` / ``*_agg_bytes`` — the analytic HBM-traffic model
  of the *aggregation* step over the [M, d] stacked-delta matrix
  (1 matrix pass = one read of M·d fp32 words). The pytree AirComp path
  reads the matrix twice (per-row norms, then the per-leaf einsum mean)
  plus a read+write of the d-sized mean for the noise; the fused kernel
  (kernels/zo_aircomp.py) reads the matrix ONCE — norms and masked mean
  in the same sweep — and pays the same d-sized noise pass (zo_walk).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.configs.base import FedZOConfig
from repro.core import fedzo
from repro.data.synthetic import make_classification, noniid_shards, \
    sample_local_batches
from repro.models.simple import softmax_init, softmax_loss
from repro.utils.tree import tree_size

import numpy as np


def agg_traffic_model(M: int, d: int, *, flat: bool):
    """Aggregation-step HBM traffic: (passes over the [M, d] delta matrix,
    total fp32 words moved including the d-sized noise read+write)."""
    if flat:
        matrix_passes = 1.0                # fused norms + masked mean
    else:
        matrix_passes = 2.0                # _delta_sq_norms, then einsum
    words = matrix_passes * M * d + 3 * d  # + mean write, noise read+write
    return matrix_passes, int(words * 4)


def run():
    rows = []
    M, H, b2 = 4, 2, 4
    x, y = make_classification(640, 784, 10, seed=0)
    clients = noniid_shards(x, y, M)
    nprng = np.random.default_rng(0)
    per = [sample_local_batches(clients[i], nprng, H, 16) for i in range(M)]
    batches = jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(v)
                                                  for v in xs]), *per)
    params = softmax_init(None)
    d = tree_size(params)
    rngs = jax.random.split(jax.random.key(0), M)
    kc = jax.random.key(1)

    base = FedZOConfig(local_iters=H, b2=b2, lr=1e-3, mu=1e-3)
    for air in (False, True):
        cfg_old = dataclasses.replace(base, aircomp=air, snr_db=10.0,
                                      channel_schedule=air)
        cfg_new = dataclasses.replace(cfg_old, flat_params=True)
        tag = "aircomp" if air else "mean"

        r_old = jax.jit(lambda p, b, r, c, cfg=cfg_old: fedzo.round_simulated(
            softmax_loss, p, b, r, cfg, channel_rng=c)[0])
        r_new = jax.jit(lambda p, b, r, c, cfg=cfg_new: fedzo.round_simulated(
            softmax_loss, p, b, r, cfg, channel_rng=c)[0])
        _, us_old = timed(lambda: r_old(params, batches, rngs, kc), n=3)
        _, us_new = timed(lambda: r_new(params, batches, rngs, kc), n=3)
        rows.append((f"round/pytree_{tag}_us_per_round_M{M}_d{d}",
                     us_old, us_old))
        rows.append((f"round/flat_{tag}_us_per_round_M{M}_d{d}",
                     us_new, us_new))

    p_old, b_old = agg_traffic_model(M, d, flat=False)
    p_new, b_new = agg_traffic_model(M, d, flat=True)
    rows.append(("round/pytree_agg_hbm_passes_over_Mxd", 0.0, p_old))
    rows.append(("round/flat_agg_hbm_passes_over_Mxd", 0.0, p_new))
    rows.append(("round/pytree_agg_bytes", 0.0, b_old))
    rows.append(("round/flat_agg_bytes", 0.0, b_new))
    rows.append(("round/agg_traffic_reduction_x", 0.0, b_old / b_new))
    return rows
