"""Old-vs-new ZO hot-path benchmark: pytree estimator loop vs the flat-buffer
fused path (DESIGN.md §7), on the softmax-regression model (d = 7850).

Two kinds of rows:

- ``*_us_per_direction`` — measured wall time of one jitted local iterate
  divided by b2 (interpret-mode Pallas on CPU: regression tracking, not a
  TPU projection).
- ``*_hbm_passes*`` / ``*_param_bytes_per_iter`` — the analytic HBM-traffic
  model. One *pass* = one full read+write sweep of the d-sized fp32
  parameter buffer (2·4·d bytes). Counted per direction:

  pytree path (sphere): materialize v (normal-gen write d, norm read d,
  scale read+write 2d → 2.0 passes) + tree_axpy x+μv (read x, read v,
  write → 1.5 passes) = 3.5 passes/direction, and the update replays b2
  more axpy passes (3.5 each). The fused flat path regenerates directions
  in VMEM: zo_walk = read x + write x = 1.0 pass/direction (≤ 2 by a 2×
  margin), and zo_replay folds the whole b2-direction update into 1.0
  pass total.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.configs.base import FedZOConfig
from repro.core import fedzo
from repro.data.synthetic import make_classification
from repro.models.simple import softmax_init, softmax_loss
from repro.utils.tree import tree_size


def traffic_model(d: int, b2: int, *, flat: bool, kind: str = "sphere"):
    """Analytic HBM traffic in parameter passes (1 pass = read+write of d
    fp32 words). Returns (passes_per_direction, update_passes_total)."""
    if flat:
        # zo_walk: read x + write x, directions live in VMEM only
        per_direction = 1.0
        # zo_replay: read x + write x once for all b2 directions
        update_total = 1.0
    else:
        # materialize direction: gen write (0.5) [+ norm read 0.5 + scale
        # read/write 1.0 for sphere] then axpy: read x + read v + write (1.5)
        gen = 2.0 if kind == "sphere" else 0.5
        per_direction = gen + 1.5
        update_total = b2 * (gen + 1.5)
    return per_direction, update_total


def run():
    rows = []
    x, y = make_classification(512, 784, 10, seed=0)
    batch = {"x": jnp.asarray(x[:256]), "y": jnp.asarray(y[:256])}
    params = softmax_init(None)
    d = tree_size(params)
    b2 = 20

    cfg_old = FedZOConfig(b2=b2, lr=1e-3, mu=1e-3)
    cfg_new = dataclasses.replace(cfg_old, flat_params=True)

    step_old = jax.jit(fedzo.make_train_step(softmax_loss, cfg_old))
    step_new = jax.jit(fedzo.make_train_step(softmax_loss, cfg_new))
    rng = jax.random.key(0)

    _, us_old = timed(lambda: step_old(params, batch, rng)[0], n=3)
    _, us_new = timed(lambda: step_new(params, batch, rng)[0], n=3)
    rows.append((f"zo_path/pytree_us_per_direction_d{d}", us_old / b2,
                 us_old))
    rows.append((f"zo_path/flat_us_per_direction_d{d}", us_new / b2,
                 us_new))

    per_old, upd_old = traffic_model(d, b2, flat=False)
    per_new, upd_new = traffic_model(d, b2, flat=True)
    pass_bytes = 2 * 4 * d
    rows.append(("zo_path/pytree_hbm_passes_per_direction", 0.0, per_old))
    rows.append(("zo_path/flat_hbm_passes_per_direction", 0.0, per_new))
    rows.append(("zo_path/pytree_update_hbm_passes_total", 0.0, upd_old))
    rows.append(("zo_path/flat_update_hbm_passes_total", 0.0, upd_new))
    rows.append(("zo_path/pytree_param_bytes_per_iter", 0.0,
                 int((b2 * per_old + upd_old) * pass_bytes)))
    rows.append(("zo_path/flat_param_bytes_per_iter", 0.0,
                 int((b2 * per_new + upd_new) * pass_bytes)))
    rows.append(("zo_path/traffic_reduction_x", 0.0,
                 (b2 * per_old + upd_old) / (b2 * per_new + upd_new)))
    return rows
