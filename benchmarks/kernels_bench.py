"""Pallas kernel microbenchmarks (interpret mode on CPU — µs numbers are for
regression tracking, not TPU projections) + seed-compression wire-size bench."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.kernels import ops
from repro.kernels.zo_axpy import BLOCK


def run():
    rows = []
    n = 4 * BLOCK
    x = jax.random.normal(jax.random.key(0), (n,), jnp.float32)
    u = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (n,), jnp.float32)
    _, us = timed(lambda: ops.axpy2(x, u, v, 0.1, -0.2), n=3)
    rows.append((f"kernels/zo_axpy2_n{n}", us, n * 4 * 4 / max(us, 1e-9)))  # B/µs

    # flat hot-path kernels: same math, directions regenerated in-kernel —
    # HBM bytes drop from 4 streams (axpy2) to 2 (walk/replay read+write x).
    # The obs timing harness prints measured µs NEXT TO the HBM-pass model
    # per kernel (kernels/<name>_us + kernels/<name>_hbm_model_us), so a
    # kernel regression shows as drift from a constant model column.
    from repro.obs import kernel_timing
    for kt in kernel_timing.kernel_report(n=n, b2=20, m=8):
        rows.extend((f"kernels/{name}", us, derived)
                    for name, us, derived in kt.rows())
    key2 = jax.random.key_data(jax.random.key(0))
    _, us = timed(lambda: ops.zo_dirnorms(key2, n - 7, b2=20, n_pad=n), n=3)
    rows.append((f"kernels/zo_dirnorms_n{n}_b2_20", us, 20 * 4 / max(us, 1e-9)))

    q = jax.random.normal(jax.random.key(0), (1, 512, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (1, 512, 2, 64), jnp.float32)
    vv = jax.random.normal(jax.random.key(2), (1, 512, 2, 64), jnp.float32)
    _, us = timed(lambda: ops.attention(q, k, vv, causal=True), n=2)
    flops = 4 * 512 * 512 * 4 * 64 / 2  # causal half
    rows.append(("kernels/flash_attention_512", us, flops / max(us, 1e-9)))

    x2 = jax.random.normal(jax.random.key(3), (4096, 1024), jnp.float32)
    s2 = jnp.ones((1024,))
    _, us = timed(lambda: ops.rmsnorm(x2, s2), n=3)
    rows.append(("kernels/rmsnorm_4096x1024", us, x2.size * 4 / max(us, 1e-9)))

    # seed-compression wire bytes vs dense upload for one round (H=5, b2=20)
    from repro.core import seedcomm
    from repro.configs.base import FedZOConfig
    cfg = FedZOConfig(local_iters=5, b2=20)
    msg = seedcomm.compress(jax.random.key(0),
                            jnp.zeros((5, 20), jnp.float32), cfg)
    dense = 7850 * 4  # softmax-regression d
    rows.append(("seedcomm/wire_bytes_round", 0.0, seedcomm.wire_bytes(msg)))
    rows.append(("seedcomm/compression_vs_dense_softmax", 0.0,
                 dense / seedcomm.wire_bytes(msg)))
    rows.append(("seedcomm/compression_vs_dense_671b", 0.0,
                 671e9 * 4 / seedcomm.wire_bytes(msg)))
    return rows
